"""Chaos benchmark: kill a cache root mid-workload and measure the cost
of degrading around it — the acceptance scenario of the failure-domain
PR.

Three phases, each on a fresh store seeded with the same working set:

* **Healthy** — stage the working set into the node cache, then read it
  warm. This is the baseline the degraded run is compared against.
* **EIO kill** — same staging, but partway through the measured read
  pass every touch of the cache root starts failing with ``EIO``
  (injected through the unified fault plane at the ``seafs.open`` /
  ``seafs.write`` / ``transfer.chunk`` sites, path-scoped to the root).
  Every read must still return bit-exact bytes (served degraded from
  the base tier), no open may surface the fault to the application, and
  the root's circuit breaker must be OPEN by the end. The plane is then
  lifted and probe writes re-admit the root: ``readmitted`` gates that
  the breaker actually closed again.
* **Hung I/O** — a copy onto the cache root stalls forever
  (``transfer.chunk:delay``); with ``transfer_deadline_s`` set the
  watchdog must abort it within the deadline (plus scheduling grace),
  release its admission reservation, and trip the breaker.

The fault schedule is seeded: ``SEA_CHAOS_SEED`` pins it, otherwise a
random seed is drawn and printed so any run can be replayed.

``PYTHONPATH=src python -m benchmarks.chaos_bench [--json PATH]``
prints the same ``name,value,derived`` CSV as the other benches;
``--json`` dumps rows + derived ratios for ``benchmarks.check_regression``
(the ``chaos`` section).
"""

from __future__ import annotations

import hashlib
import json
import os
import random
import shutil
import sys
import tempfile
import time

from repro.core import Sea, SeaConfig, TierSpec, faults
from repro.core.faults import FaultPlane
from repro.core.health import CLOSED, OPEN
from repro.core.transfer import TransferDeadlineError

_N_FILES = 16
_FILE_BYTES = 256 << 10
_KILL_AFTER = _N_FILES // 3       # files read before the root dies
_DEADLINE_S = 0.3                 # hung-copy watchdog deadline
_RECOVERY_TIMEOUT_S = 10.0
_MAX_DEGRADED_OVERHEAD_X = 10.0   # degraded read pass vs healthy warm pass
_MAX_DEADLINE_GRACE_S = 2.0       # scheduling slop on top of the deadline

SEED = int(os.environ.get("SEA_CHAOS_SEED", "0") or "0") or (
    random.SystemRandom().randrange(1 << 30)
)


def _key(i: int) -> str:
    return f"chaos_{i:05d}.bin"


def _config(workdir: str, **kw) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(name="cache", roots=(os.path.join(workdir, "c0"),)),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),),
                persistent=True,
            ),
        ],
        max_file_size=2 * _FILE_BYTES,
        # breaker tuned fast so recovery fits in a bench run
        health_window_s=5.0,
        health_min_events=4,
        # the seeding writes sit in the same stats window as the kill's
        # failures, so a dead root plateaus around ~40% error rate here;
        # 0.3 keeps the breaker fail-fast for the bench
        health_error_threshold=0.3,
        health_open_s=0.2,
        fault_seed=SEED,
        **kw,
    )


def _seed_via_fs(fs) -> dict[str, str]:
    """Write the working set through Sea (replica on the cache root) and
    persist each file (replica on base — degradation has a target), then
    drop the resolver cache so reads route through the cache replica
    rather than the location ``persist`` just noted."""
    rng = random.Random(SEED)
    digests: dict[str, str] = {}
    for i in range(_N_FILES):
        blob = rng.randbytes(_FILE_BYTES)
        p = os.path.join(fs.mount, _key(i))
        with fs.open(p, "wb") as f:
            f.write(blob)
        fs.persist(p)
        digests[_key(i)] = hashlib.sha256(blob).hexdigest()
    fs.resolver.invalidate_all()
    return digests


def _read_pass(fs, on_file=None) -> tuple[float, dict[str, str], int]:
    """Read the whole set; returns (elapsed, digests, open_failures).
    ``on_file(i)`` runs before file i — the kill switch hook. Each read
    invalidates its resolver entry first so every open re-resolves
    through the (possibly dead) cache replica; both the healthy and the
    degraded pass pay this, so the overhead ratio stays like-for-like."""
    digests: dict[str, str] = {}
    failures = 0
    t0 = time.perf_counter()
    for i in range(_N_FILES):
        if on_file is not None:
            on_file(i)
        p = os.path.join(fs.mount, _key(i))
        fs.resolver.invalidate(fs.key_of(p))
        try:
            with fs.open(p, "rb") as f:
                digests[_key(i)] = hashlib.sha256(f.read()).hexdigest()
        except OSError:
            failures += 1
    return time.perf_counter() - t0, digests, failures


def _fresh_store(tmp: str, tag: str, **cfg_kw) -> tuple[Sea, dict[str, str]]:
    sea = Sea(_config(os.path.join(tmp, tag), **cfg_kw))
    return sea, _seed_via_fs(sea.fs)


def bench_chaos(tmp: str) -> tuple[list[dict], dict]:
    # ---------------------------------------------------------- healthy
    sea, expected = _fresh_store(tmp, "healthy")
    fs = sea.fs
    try:
        healthy_s, digests, _ = _read_pass(fs)  # cache-served
        if digests != expected:
            raise RuntimeError("healthy run returned corrupt data")
    finally:
        sea.shutdown()

    # --------------------------------------------------------- EIO kill
    sea, expected = _fresh_store(tmp, "eio")
    fs = sea.fs
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    kill_spec = ";".join(
        f"{site}:errno=EIO,path={root}/*"
        for site in ("seafs.open", "seafs.write", "transfer.chunk")
    )
    try:
        def _kill(i: int) -> None:
            if i == _KILL_AFTER:
                faults.activate(FaultPlane.from_spec(kill_spec, seed=SEED))

        degraded_s, digests, open_failures = _read_pass(fs, on_file=_kill)
        torn = sum(1 for k, d in digests.items() if expected[k] != d)
        snap = fs.telemetry.snapshot()
        breaker_open = fs.health.breaker_state(root) == OPEN

        # lift the fault; probe writes must re-admit the root
        faults.deactivate()
        t0 = time.perf_counter()
        readmitted = False
        probe = 0
        while time.perf_counter() - t0 < _RECOVERY_TIMEOUT_S:
            if fs.health.breaker_state(root) == CLOSED:
                readmitted = True
                break
            time.sleep(fs.config.health_open_s / 2)
            with fs.open(os.path.join(fs.mount, f"probe_{probe}.bin"),
                         "wb") as f:
                f.write(b"p" * 4096)
            probe += 1
        recovery_s = time.perf_counter() - t0
        sea.flusher.drain()
        reservation_leaked = tier.reserved_bytes(root)
    finally:
        faults.deactivate()
        sea.shutdown()

    # ---------------------------------------------------------- hung I/O
    sea = Sea(_config(os.path.join(tmp, "hung"),
                      transfer_deadline_s=_DEADLINE_S))
    fs = sea.fs
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    try:
        faults.activate(
            FaultPlane.from_spec("transfer.chunk:delay=60,n=1", seed=SEED)
        )
        src = os.path.join(tmp, "hung", "pfs", "hung.bin")
        with open(src, "wb") as f:
            f.write(b"h" * _FILE_BYTES)
        t0 = time.perf_counter()
        aborted = False
        try:
            fs.transfer.copy(
                src, os.path.join(root, "hung.bin"),
                src_tier=fs.hierarchy.base, dst_tier=tier, dst_root=root,
                key="hung.bin", admit="require",
            )
        except TransferDeadlineError:
            aborted = True
        deadline_abort_s = time.perf_counter() - t0
        hung_snap = fs.telemetry.snapshot()
        hung_leaked = tier.reserved_bytes(root)
    finally:
        faults.deactivate()
        sea.shutdown()

    derived = {
        "seed": SEED,
        "healthy_s": round(healthy_s, 3),
        "degraded_s": round(degraded_s, 3),
        "degraded_overhead_x": round(degraded_s / max(healthy_s, 1e-9), 2),
        "torn_reads": torn,
        "open_failures": open_failures,
        "degraded_reads": snap["degraded_reads"],
        "breaker_opens": snap["breaker_opens"],
        "breaker_open_after_kill": int(breaker_open),
        "readmitted": int(readmitted),
        "recovery_s": round(recovery_s, 3),
        "reservation_leaked": int(reservation_leaked + hung_leaked),
        "deadline_s": _DEADLINE_S,
        "deadline_abort_s": round(deadline_abort_s, 3),
        "deadline_aborted": int(aborted),
        "deadline_aborts": hung_snap["deadline_aborts"],
    }
    rows = [
        {
            "name": f"chaos_healthy_warm_{_N_FILES}x{_FILE_BYTES >> 10}KiB",
            "value": round(healthy_s * 1e6 / _N_FILES, 2),
            "derived": "us_per_file cache-served",
        },
        {
            "name": f"chaos_degraded_read_{_N_FILES}x{_FILE_BYTES >> 10}KiB",
            "value": round(degraded_s * 1e6 / _N_FILES, 2),
            "derived": (
                f"us_per_file overhead={derived['degraded_overhead_x']}x"
                f" degraded_reads={derived['degraded_reads']}"
            ),
        },
        {
            "name": "chaos_breaker_recovery",
            "value": round(recovery_s * 1e3, 1),
            "derived": f"ms_to_readmit readmitted={derived['readmitted']}",
        },
        {
            "name": "chaos_hung_copy_abort",
            "value": round(deadline_abort_s * 1e3, 1),
            "derived": (
                f"ms_to_abort deadline={_DEADLINE_S * 1e3:.0f}ms"
                f" leaked={derived['reservation_leaked']}"
            ),
        },
    ]
    return rows, derived


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: chaos_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]

    print(f"chaos seed: {SEED} (rerun with SEA_CHAOS_SEED={SEED})",
          file=sys.stderr)
    t_start = time.perf_counter()
    tmp = tempfile.mkdtemp(prefix="sea_chaos_bench_")
    try:
        print("name,value,derived")
        rows, derived = bench_chaos(tmp)
        for row in rows:
            print(f"{row['name']},{row['value']},{row['derived']}")
        print(
            f"acceptance_degraded_clean,"
            f"{derived['torn_reads'] + derived['open_failures']},"
            f"==0_required"
        )
        print(
            f"acceptance_readmitted,{derived['readmitted']},==1_required"
        )
        print(
            f"acceptance_deadline_abort,{derived['deadline_abort_s']},"
            f"<={_DEADLINE_S + _MAX_DEADLINE_GRACE_S}s_required"
        )
        ok = (
            derived["torn_reads"] == 0
            and derived["open_failures"] == 0
            and derived["degraded_reads"] > 0
            and derived["breaker_open_after_kill"] == 1
            and derived["readmitted"] == 1
            and derived["degraded_overhead_x"] <= _MAX_DEGRADED_OVERHEAD_X
            and derived["deadline_aborted"] == 1
            and derived["deadline_abort_s"]
            <= _DEADLINE_S + _MAX_DEADLINE_GRACE_S
            and derived["reservation_leaked"] == 0
        )
        if json_path:
            with open(json_path, "w") as f:
                json.dump(
                    {
                        "rows": rows,
                        **derived,
                        "elapsed_s": round(time.perf_counter() - t_start, 2),
                    },
                    f,
                    indent=2,
                )
        raise SystemExit(0 if ok else 1)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
