"""CI perf-regression gate for the placement/multiproc/resolve/transfer/
readahead/extent/federation/training/seacheck/chaos benchmarks.

Compares a freshly produced ``BENCH_pr10.json`` (written by
``placement_bench --json`` + ``multiproc_bench --json`` +
``resolve_bench --json`` + ``transfer_bench --json`` +
``readahead_bench --json`` + ``extent_bench --json`` +
``federation_bench --json`` + ``training_bench --json`` +
``seacheck_bench --json`` + ``chaos_bench --json``, merged by the CI
workflow) against the committed ``benchmarks/BENCH_baseline.json``.

The structural gates are machine-independent and strict:
  * select() must stay O(1)-flat: ledger select cost at the largest
    population <= FLATNESS_X times its cost at the smallest,
  * ledger end-to-end open speedup over the walk at 10k files >= 5x,
  * multi-process run never over-committed the capped root,
  * multi-process aggregate throughput did not collapse (>= 0.5x 1-proc),
  * cached resolution at 3 tiers x 4 roots >= 10x faster than the seed's
    probe cascade, with the hit path flat across root counts,
  * transfer engine moves a large file at parity with shutil.copyfile
    (ratio >= MIN_TRANSFER_RATIO) and pooled prefetch staging overlaps
    > MIN_OVERLAP_SPEEDUP x over serial copies. (Transfer gates are
    pure ratios — absolute throughputs are machine-dependent, so no
    baseline comparison is applied to them.)
  * seacheck: the SEACHECK=1 runtime lock-order detector keeps the
    instrumented tier-1 subset under MAX_SEACHECK_OVERHEAD_X x the
    uninstrumented wall-clock (both legs are real pytest subprocesses),
  * predictive readahead: cold sequential block reads >= MIN_SEQ_SPEEDUP x
    faster with readahead on (modelled tier bandwidths: deterministic),
    wasted-prefetch bytes < MAX_WASTED_RATIO of staged bytes on a
    random-access permutation, and the read-hit open fast path cuts
    per-call overhead >= MIN_FASTPATH_REDUCTION vs the PR-4 open path.
  * extent plane: cold time-to-first-cached-byte on a large file
    >= MIN_TTFB_SPEEDUP x faster with the extent map than whole-file
    staging (both paced by the same token-bucket cap: deterministic),
    and a scan of a file 4x the cache tier stays bit-exact, never
    over-commits the ledger, actually punches cold extents, and keeps
    >= MIN_HOT_CHUNK_RATIO of chunks served from staged extents.
  * federation: a second node reading a sibling-staged working set is
    >= MIN_PEER_SPEEDUP x faster than the identical cold-from-base run
    (modelled tier bandwidths, real peer->cache token-bucket cap),
    every warm read is a peer hit, and with peers killed mid-pull every
    read still returns bit-exact base content with zero partial or tmp
    files left in the puller's cache.
  * training I/O: blocking checkpoint saves (the seed path,
    ``checkpoint_workers=1``) cost >= MIN_BLOCKING_OVERHEAD x the
    no-checkpoint step loop while async saves of the same modelled
    bytes stay under MAX_ASYNC_OVERHEAD x (the write disappeared behind
    compute), the double-buffered device feed beats the unbuffered
    put-then-compute loop >= MIN_FEED_SPEEDUP x, and a sharded save
    writes each shard exactly once (unique manifest files, payload
    within MAX_SHARDED_RATIO of the logical bytes, bit-exact restore).

Every failure message is prefixed with its ``[section]`` so CI logs
name the benchmark that tripped the gate, and sections reporting an
``elapsed_s`` get their wall-clock printed so slow gates are
attributable.

Absolute timings vary with runner hardware, so against the baseline only a
gross regression fails: any ledger-path metric more than ABS_TOLERANCE_X
slower than the committed number.

``python -m benchmarks.check_regression BENCH_pr10.json [baseline.json]``
"""

from __future__ import annotations

import json
import os
import sys

FLATNESS_X = 3.0      # ledger select at 10k files vs at 100 files
MIN_OPEN_SPEEDUP = 5.0
MIN_SCALING = 0.5     # multiproc aggregate vs single-process
ABS_TOLERANCE_X = 5.0  # gross-regression multiplier vs committed baseline
MIN_RESOLVE_SPEEDUP = 10.0  # cached resolution vs seed cascade at 3x4
RESOLVE_FLATNESS_X = 3.0    # cached hit path: widest layout vs narrowest
MIN_TRANSFER_RATIO = 0.85   # engine vs shutil.copyfile large-file parity:
                            # both bottom out at the same zero-copy syscalls,
                            # so a genuine chunk-loop regression measures
                            # 0.6-0.75 while runner noise stays within ±0.1
MIN_OVERLAP_SPEEDUP = 1.5   # pooled staging vs serial copies (latency-bound)
MIN_SEQ_SPEEDUP = 2.0       # cold sequential reads: readahead on vs off
MAX_WASTED_RATIO = 0.20     # wasted / staged speculative bytes, random access
MIN_FASTPATH_REDUCTION = 0.30  # read-hit open overhead cut vs PR-4 path
MIN_TTFB_SPEEDUP = 5.0      # cold TTFB: one-extent fault vs whole-file stage
MIN_HOT_CHUNK_RATIO = 0.5   # bigger-than-tier scan chunks served hot
MIN_PEER_SPEEDUP = 2.0      # warm-peer read vs cold-from-base, same caps
MIN_BLOCKING_OVERHEAD = 2.0  # blocking-save step loop vs no-ckpt loop
MAX_ASYNC_OVERHEAD = 1.15   # async-save step loop vs no-ckpt loop
MIN_FEED_SPEEDUP = 1.5      # double-buffered device feed vs unbuffered
MAX_SHARDED_RATIO = 1.01    # ckpt payload / logical state bytes (npy headers)
MAX_SEACHECK_OVERHEAD_X = 2.0  # SEACHECK=1 tier-1 subset vs uninstrumented
MAX_DEGRADED_OVERHEAD_X = 10.0  # killed-root read pass vs healthy warm pass
MAX_DEADLINE_GRACE_S = 2.0  # scheduling slop on the hung-copy abort

_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_baseline.json")


def _row(rows: list[dict], name: str) -> dict | None:
    return next((r for r in rows if r["name"] == name), None)


def check(current: dict, baseline: dict | None) -> list[str]:
    failures: list[str] = []

    def fail(section: str, msg: str) -> None:
        failures.append(f"[{section}] {msg}")

    rows = current["placement"]["rows"]

    sizes = sorted(
        int(r["name"].rsplit("_", 1)[1][:-1])
        for r in rows
        if r["name"].startswith("placement_select_ledger_")
    )
    small, big = sizes[0], sizes[-1]
    s_small = _row(rows, f"placement_select_ledger_{small}f")["us_per_call"]
    s_big = _row(rows, f"placement_select_ledger_{big}f")["us_per_call"]
    if s_big > FLATNESS_X * s_small:
        fail(
            "placement",
            f"select() not O(1)-flat: {s_big}us at {big} files vs "
            f"{s_small}us at {small} (allowed {FLATNESS_X}x)",
        )

    speedup = current["placement"]["open_speedup"]
    if speedup < MIN_OPEN_SPEEDUP:
        fail(
            "placement",
            f"ledger open speedup {speedup}x at {big} files "
            f"< required {MIN_OPEN_SPEEDUP}x",
        )

    for scale in current["multiproc"]["scales"]:
        if scale["overcommitted"]:
            fail(
                "multiproc",
                f"capped root over-committed at {scale['n_procs']} procs: "
                f"{scale['cache_used_bytes']} > {scale['capacity']}",
            )
        if scale["files_placed"] != scale["files_written"]:
            fail(
                "multiproc",
                f"lost files at {scale['n_procs']} procs: "
                f"{scale['files_written'] - scale['files_placed']}",
            )
    top = current["multiproc"]["scales"][-1]
    if top["scaling_vs_1proc"] < MIN_SCALING:
        fail(
            "multiproc",
            f"throughput collapsed: {top['scaling_vs_1proc']}x "
            f"at {top['n_procs']} procs < {MIN_SCALING}x",
        )

    resolver = current.get("resolver")
    if resolver is None:
        fail("resolver", "section missing (resolve_bench not run)")
    else:
        speedup = resolver["resolve_speedup"]
        if speedup < MIN_RESOLVE_SPEEDUP:
            fail(
                "resolver",
                f"cached resolution speedup {speedup}x at the widest layout "
                f"< required {MIN_RESOLVE_SPEEDUP}x",
            )
        flatness = resolver["hit_flatness"]
        if flatness > RESOLVE_FLATNESS_X:
            fail(
                "resolver",
                f"hit path not flat across root counts: "
                f"{flatness}x (allowed {RESOLVE_FLATNESS_X}x)",
            )

    transfer = current.get("transfer")
    if transfer is None:
        fail("transfer", "section missing (transfer_bench not run)")
    else:
        ratio = transfer["large_ratio"]
        if ratio < MIN_TRANSFER_RATIO:
            fail(
                "transfer",
                f"engine large-file throughput {ratio}x of shutil "
                f"< required {MIN_TRANSFER_RATIO}x parity",
            )
        overlap = transfer["overlap_speedup"]
        if overlap <= MIN_OVERLAP_SPEEDUP:
            fail(
                "transfer",
                f"concurrent-prefetch overlap {overlap}x <= required "
                f"{MIN_OVERLAP_SPEEDUP}x over serial staging",
            )

    readahead = current.get("readahead")
    if readahead is None:
        fail("readahead", "section missing (readahead_bench not run)")
    else:
        seq = readahead["cold_seq_speedup"]
        if seq < MIN_SEQ_SPEEDUP:
            fail(
                "readahead",
                f"cold sequential readahead speedup {seq}x "
                f"< required {MIN_SEQ_SPEEDUP}x",
            )
        wasted = readahead["wasted_ratio"]
        if wasted >= MAX_WASTED_RATIO:
            fail(
                "readahead",
                f"wasted-prefetch ratio {wasted} on random access "
                f">= allowed {MAX_WASTED_RATIO}",
            )
        cut = readahead["fastpath_overhead_reduction"]
        if cut < MIN_FASTPATH_REDUCTION:
            fail(
                "readahead",
                f"open fast-path overhead reduction {cut} "
                f"< required {MIN_FASTPATH_REDUCTION}",
            )

    extent = current.get("extent")
    if extent is None:
        fail("extent", "section missing (extent_bench not run)")
    else:
        ttfb = extent["ttfb_speedup"]
        if ttfb < MIN_TTFB_SPEEDUP:
            fail(
                "extent",
                f"cold-TTFB speedup {ttfb}x < required {MIN_TTFB_SPEEDUP}x",
            )
        if not extent["scan_bitexact"]:
            fail(
                "extent", "bigger-than-tier extent scan returned corrupted bytes"
            )
        if extent["scan_overcommitted"]:
            fail(
                "extent",
                "bigger-than-tier extent scan over-committed the cache tier",
            )
        if extent["scan_extents_punched"] <= 0:
            fail(
                "extent",
                "bigger-than-tier extent scan never punched a cold extent",
            )
        hot = extent["scan_hot_chunk_ratio"]
        if hot < MIN_HOT_CHUNK_RATIO:
            fail(
                "extent",
                f"bigger-than-tier scan hot-chunk ratio {hot} "
                f"< required {MIN_HOT_CHUNK_RATIO}",
            )

    federation = current.get("federation")
    if federation is None:
        fail("federation", "section missing (federation_bench not run)")
    else:
        peer = federation["peer_speedup"]
        if peer < MIN_PEER_SPEEDUP:
            fail(
                "federation",
                f"warm-peer read speedup {peer}x over cold base "
                f"< required {MIN_PEER_SPEEDUP}x",
            )
        hits = federation["peer_hits"]
        if federation.get("warm_torn_reads", 0) or hits <= 0:
            fail(
                "federation",
                f"warm run not served from peers: hits={hits} "
                f"torn={federation.get('warm_torn_reads', 0)}",
            )
        if federation["fault_torn_reads"]:
            fail(
                "federation",
                f"peer death mid-pull returned corrupted reads: "
                f"{federation['fault_torn_reads']} files",
            )
        if federation["fault_cache_residue"]:
            fail(
                "federation",
                f"peer death mid-pull leaked partial/tmp files: "
                f"{federation['fault_cache_residue']}",
            )
        if federation["fault_fallbacks"] <= 0:
            fail(
                "federation",
                "fault run recorded no peer_fallbacks "
                "(injection did not reach the pull path)",
            )

    training = current.get("training")
    if training is None:
        fail("training", "section missing (training_bench not run)")
    else:
        blocking = training["blocking_overhead_x"]
        if blocking < MIN_BLOCKING_OVERHEAD:
            fail(
                "training",
                f"blocking-save overhead {blocking}x vs no-ckpt loop "
                f"< required {MIN_BLOCKING_OVERHEAD}x (the modelled "
                f"checkpoint bytes are too cheap to gate overlap)",
            )
        async_x = training["async_overhead_x"]
        if async_x > MAX_ASYNC_OVERHEAD:
            fail(
                "training",
                f"async-save overhead {async_x}x vs no-ckpt loop "
                f"> allowed {MAX_ASYNC_OVERHEAD}x (writes not hidden "
                f"behind compute)",
            )
        feed = training["feed_speedup"]
        if feed < MIN_FEED_SPEEDUP:
            fail(
                "training",
                f"double-buffered device feed {feed}x over unbuffered "
                f"< required {MIN_FEED_SPEEDUP}x",
            )
        if not training["sharded_unique_files"]:
            fail("training", "sharded save wrote a shard file twice")
        ratio = training["sharded_write_ratio"]
        if not 1.0 <= ratio <= MAX_SHARDED_RATIO:
            fail(
                "training",
                f"sharded save payload/logical ratio {ratio} outside "
                f"[1.0, {MAX_SHARDED_RATIO}] (shards duplicated or lost)",
            )
        if not training["sharded_roundtrip_ok"]:
            fail("training", "sharded checkpoint did not restore bit-exact")

    seacheck = current.get("seacheck")
    if seacheck is None:
        fail("seacheck", "section missing (seacheck_bench not run)")
    else:
        overhead = seacheck["overhead_x"]
        if overhead >= MAX_SEACHECK_OVERHEAD_X:
            fail(
                "seacheck",
                f"SEACHECK=1 instrumentation overhead {overhead}x "
                f">= allowed {MAX_SEACHECK_OVERHEAD_X}x (the instrumented "
                f"matrix leg is only viable while detection stays cheap)",
            )

    chaos = current.get("chaos")
    if chaos is None:
        fail("chaos", "section missing (chaos_bench not run)")
    else:
        seed = chaos.get("seed", "?")
        if chaos["torn_reads"]:
            fail(
                "chaos",
                f"killed-root run returned corrupted reads: "
                f"{chaos['torn_reads']} files (seed={seed})",
            )
        if chaos["open_failures"]:
            fail(
                "chaos",
                f"{chaos['open_failures']} opens surfaced the dead root "
                f"to the application instead of degrading (seed={seed})",
            )
        if chaos["degraded_reads"] <= 0 or not chaos["breaker_open_after_kill"]:
            fail(
                "chaos",
                f"kill did not register: degraded_reads="
                f"{chaos['degraded_reads']} breaker_open="
                f"{chaos['breaker_open_after_kill']} (seed={seed})",
            )
        overhead = chaos["degraded_overhead_x"]
        if overhead > MAX_DEGRADED_OVERHEAD_X:
            fail(
                "chaos",
                f"degraded-mode read overhead {overhead}x vs healthy "
                f"> allowed {MAX_DEGRADED_OVERHEAD_X}x (seed={seed})",
            )
        if not chaos["readmitted"]:
            fail(
                "chaos",
                f"breaker never re-admitted the recovered root within "
                f"{chaos.get('recovery_s', '?')}s (seed={seed})",
            )
        limit = chaos["deadline_s"] + MAX_DEADLINE_GRACE_S
        if not chaos["deadline_aborted"] or chaos["deadline_abort_s"] > limit:
            fail(
                "chaos",
                f"hung copy abort took {chaos['deadline_abort_s']}s "
                f"(aborted={chaos['deadline_aborted']}) > deadline "
                f"{chaos['deadline_s']}s + {MAX_DEADLINE_GRACE_S}s grace "
                f"(seed={seed})",
            )
        if chaos["reservation_leaked"]:
            fail(
                "chaos",
                f"failure paths leaked {chaos['reservation_leaked']} "
                f"reserved bytes (seed={seed})",
            )

    if baseline is not None:
        base_rows = baseline["placement"]["rows"]
        for r in rows:
            if "ledger" not in r["name"]:
                continue  # walk timings are the baseline being beaten
            b = _row(base_rows, r["name"])
            if b and r["us_per_call"] > ABS_TOLERANCE_X * b["us_per_call"]:
                fail(
                    "placement",
                    f"{r['name']}: {r['us_per_call']}us > "
                    f"{ABS_TOLERANCE_X}x baseline {b['us_per_call']}us",
                )
        base_resolver = baseline.get("resolver")
        if resolver is not None and base_resolver is not None:
            for r in resolver["rows"]:
                if "cached" not in r["name"] and "verified" not in r["name"]:
                    continue  # seed timings are the baseline being beaten
                b = _row(base_resolver["rows"], r["name"])
                if b and r["us_per_call"] > ABS_TOLERANCE_X * b["us_per_call"]:
                    fail(
                        "resolver",
                        f"{r['name']}: {r['us_per_call']}us > "
                        f"{ABS_TOLERANCE_X}x baseline {b['us_per_call']}us",
                    )
    return failures


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: check_regression.py BENCH_pr10.json [baseline.json]")
        raise SystemExit(2)
    with open(argv[0]) as f:
        current = json.load(f)
    baseline_path = argv[1] if len(argv) > 1 else _BASELINE
    baseline = None
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            baseline = json.load(f)
    else:
        print(f"note: no baseline at {baseline_path}; structural gates only")
    timed = [
        (name, section["elapsed_s"])
        for name, section in current.items()
        if isinstance(section, dict) and "elapsed_s" in section
    ]
    for name, secs in sorted(timed, key=lambda t: -t[1]):
        print(f"timing: [{name}] {secs}s")
    failures = check(current, baseline)
    for msg in failures:
        print(f"REGRESSION: {msg}")
    if not failures:
        print("perf gate passed")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
