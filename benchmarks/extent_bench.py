"""Extent-plane benchmark: time-to-first-byte on a cold large file.

Two acceptance targets for the extent-granular data plane (ISSUE 6):

* **Cold TTFB** — the time until the first application chunk of a cold
  PFS-resident file is served *from the cache tier*. Whole-file mode
  must stage the entire file (``stage_to_cache``) before a single
  cached byte exists; extent mode faults exactly one block and serves
  it. Both paths move bytes through the same real ``TransferEngine``
  under the same token-bucket bandwidth cap
  (``transfer_bandwidth_caps``), so the ratio is modelled-deterministic
  and hardware-independent: TTFB speedup >= 5x required (median of 3
  cold runs each; the expected ratio is ~= the extent count).
* **Bigger-than-tier streaming** — a file 4x the cache tier's capacity
  is scanned end-to-end through the extent plane with LRU punch-hole
  eviction. The ledger-tracked usage must never exceed capacity, cold
  extents must actually be punched, reads must be bit-exact, and the
  majority of application chunks must still be served hot (each
  extent is faulted once, then read hot chunk-by-chunk).

``PYTHONPATH=src python -m benchmarks.extent_bench [--json PATH]``
prints the same ``name,us_per_call,derived`` CSV as the other benches;
``--json`` dumps rows + derived ratios for ``benchmarks.check_regression``.
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

from repro.core import SeaConfig, SeaFS, TierSpec

_FILE_BYTES = 32 << 20        # one cold model-checkpoint-sized input
_EXTENT_BYTES = 2 << 20       # 16 extents per file
_APP_CHUNK = 256 << 10        # application read granularity
_BW_STAGE = 64e6              # staging cap (token-bucket, real): whole-file
                              # staging costs ~0.5s, one extent rides the
                              # burst allowance — the gap under test
_TTFB_RUNS = 3                # median-of
_MIN_TTFB_SPEEDUP = 5.0
_TIER_CAP = 8 << 20           # scan target: file is 4x this capacity
_MIN_HOT_CHUNK_RATIO = 0.5    # scan chunks served from staged extents


def _config(workdir: str, *, extent: bool, capacity: int | None = None,
            lru_evict: bool = False) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="fast",
                roots=(os.path.join(workdir, "fast"),),
                capacity=capacity,
            ),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        max_file_size=_FILE_BYTES,
        extent_map=extent,
        extent_bytes=_EXTENT_BYTES,
        lru_evict=lru_evict,
        transfer_bandwidth_caps={"pfs->*": _BW_STAGE},
    )


def _seed(workdir: str, key: str, nbytes: int) -> None:
    root = os.path.join(workdir, "pfs")
    os.makedirs(os.path.dirname(os.path.join(root, key)), exist_ok=True)
    with open(os.path.join(root, key), "wb") as f:
        f.write(os.urandom(nbytes))


def _ttfb_whole(workdir: str, key: str) -> float:
    """Cold cached read, whole-file plane: the full file must land on the
    cache tier before the first cached chunk can be served."""
    shutil.rmtree(os.path.join(workdir, "fast"), ignore_errors=True)
    fs = SeaFS(_config(workdir, extent=False))
    fs.prefetcher.stop()
    p = os.path.join(fs.mount, key)
    t0 = time.perf_counter()
    staged = fs.stage_to_cache(key)
    with fs.open(p, "rb") as f:
        chunk = f.read(_APP_CHUNK)
        tier = f.sea_tier
    dt = time.perf_counter() - t0
    assert staged == _FILE_BYTES and len(chunk) == _APP_CHUNK
    assert tier == "fast"
    fs.transfer.close()
    return dt


def _ttfb_extent(workdir: str, key: str) -> float:
    """Cold cached read, extent plane: the first read faults exactly one
    block through the same capped engine and serves it from the cache."""
    shutil.rmtree(os.path.join(workdir, "fast"), ignore_errors=True)
    fs = SeaFS(_config(workdir, extent=True))
    fs.prefetcher.stop()  # no background readahead: pure one-extent fault
    p = os.path.join(fs.mount, key)
    t0 = time.perf_counter()
    with fs.open(p, "rb") as f:
        chunk = f.read(_APP_CHUNK)
    dt = time.perf_counter() - t0
    assert len(chunk) == _APP_CHUNK
    snap = fs.telemetry.snapshot()
    assert snap["extents_staged"] == 1, snap["extents_staged"]
    assert snap["extent_hits"] + snap["extent_misses"] >= 1
    fs.transfer.close()
    return dt


def bench_ttfb(workdir: str) -> tuple[list[dict], float]:
    key = "inputs/checkpoint.bin"
    _seed(workdir, key, _FILE_BYTES)
    whole: list[float] = []
    ext: list[float] = []
    for _ in range(_TTFB_RUNS):
        whole.append(_ttfb_whole(workdir, key))
        ext.append(_ttfb_extent(workdir, key))
    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    speedup = med(whole) / med(ext)
    n_ext = _FILE_BYTES // _EXTENT_BYTES
    rows = [
        {
            "name": f"ttfb_whole_file_{_FILE_BYTES >> 20}MiB",
            "us_per_call": round(med(whole) * 1e6, 2),
            "derived": "extent_map=off",
        },
        {
            "name": f"ttfb_extent_{_EXTENT_BYTES >> 20}MiB_of_{n_ext}",
            "us_per_call": round(med(ext) * 1e6, 2),
            "derived": f"extent_map=on speedup={speedup:.2f}x",
        },
    ]
    return rows, speedup


def bench_bigger_than_tier(workdir: str) -> tuple[list[dict], dict]:
    """Sequential scan of a file 4x the cache tier's capacity: extent
    admission + punch-hole eviction keep the ledger under the cap while
    most chunks are still served from staged extents."""
    key = "inputs/oversized.bin"
    _seed(workdir, key, _FILE_BYTES)
    shutil.rmtree(os.path.join(workdir, "fast"), ignore_errors=True)
    fs = SeaFS(_config(workdir, extent=True, capacity=_TIER_CAP, lru_evict=True))
    fs.prefetcher.stop()  # deterministic hit accounting: fault-then-read
    p = os.path.join(fs.mount, key)
    import hashlib

    h_sea, h_base = hashlib.sha256(), hashlib.sha256()
    t0 = time.perf_counter()
    chunks = 0
    with fs.open(p, "rb") as f:
        while True:
            chunk = f.read(_APP_CHUNK)
            if not chunk:
                break
            h_sea.update(chunk)
            chunks += 1
    dt = time.perf_counter() - t0
    with open(os.path.join(workdir, "pfs", key), "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h_base.update(chunk)
    snap = fs.telemetry.snapshot()
    tier = fs.hierarchy.cache_tiers[0]
    used = tier.used_bytes(tier.roots[0])
    scan_used = tier.scan_used_bytes(tier.roots[0])
    fs.transfer.close()
    hot_ratio = snap["extent_hits"] / max(1, chunks)
    derived = {
        "bitexact": h_sea.hexdigest() == h_base.hexdigest(),
        "ledger_used": used,
        "scan_used": scan_used,
        "capacity": _TIER_CAP,
        "overcommitted": used > _TIER_CAP or scan_used > _TIER_CAP,
        "extents_punched": snap["extents_punched"],
        "hot_chunk_ratio": round(hot_ratio, 3),
    }
    rows = [
        {
            "name": f"scan_4x_tier_{_FILE_BYTES >> 20}MiB",
            "us_per_call": round(dt * 1e6 / chunks, 2),
            "derived": (
                f"hot_ratio={hot_ratio:.2f} punched={snap['extents_punched']} "
                f"used={used}<=cap={_TIER_CAP}"
            ),
        }
    ]
    return rows, derived


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: extent_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]

    t_start = time.perf_counter()
    workdir = tempfile.mkdtemp(prefix="sea_extent_bench_")
    try:
        print("name,us_per_call,derived")
        ttfb_rows, speedup = bench_ttfb(workdir)
        scan_rows, scan = bench_bigger_than_tier(workdir)
        rows = ttfb_rows + scan_rows
        for row in rows:
            print(f"{row['name']},{row['us_per_call']},{row['derived']}")
        print(
            f"acceptance_ttfb_speedup,{speedup:.2f},>={_MIN_TTFB_SPEEDUP}x_required"
        )
        print(
            f"acceptance_scan_ok,{int(not scan['overcommitted'])},"
            f"bitexact={scan['bitexact']} hot_ratio={scan['hot_chunk_ratio']}"
        )
        ok = (
            speedup >= _MIN_TTFB_SPEEDUP
            and scan["bitexact"]
            and not scan["overcommitted"]
            and scan["extents_punched"] > 0
            and scan["hot_chunk_ratio"] >= _MIN_HOT_CHUNK_RATIO
        )
        if json_path:
            with open(json_path, "w") as f:
                json.dump(
                    {
                        "rows": rows,
                        "ttfb_speedup": round(speedup, 2),
                        "scan_bitexact": scan["bitexact"],
                        "scan_overcommitted": scan["overcommitted"],
                        "scan_extents_punched": scan["extents_punched"],
                        "scan_hot_chunk_ratio": scan["hot_chunk_ratio"],
                        "elapsed_s": round(
                            time.perf_counter() - t_start, 2
                        ),
                    },
                    f,
                    indent=2,
                )
        raise SystemExit(0 if ok else 1)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
