"""Cluster-federation benchmark: two "nodes", one shared base tier.

The acceptance scenario of the federation PR: node A (a real forked
process with its own ``SeaFS``) stages a working set into its node-local
cache and publishes the locations in the shared registry; node B then
reads the same working set. With federation on, B's opens resolve the
keys to A's cache and pull them peer-to-peer instead of re-reading the
cold base tier.

Storage speeds are *modelled* so the measurement is
hardware-independent and deterministic (same scheme as
``readahead_bench``): an application read pays ``bytes / BW`` of its
serving tier (slow PFS vs fast node-local cache), while peer pulls are
paced by the engine's real token-bucket throttle via the ``peer->*``
bandwidth-cap pair. Three gates:

* **Warm-peer speedup** — B reading the A-staged working set must be
  >= 2x faster than the identical cold-from-base run (same config, same
  caps, empty registry).
* **Fault tolerance** — with every peer pull killed mid-transfer
  (``TransferEngine.chunk_hook`` raising ``EIO``), every read must
  still return bit-exact content from the base tier, with zero partial
  or ``.sea_tmp`` files left in the puller's cache.
* **Accounting** — the warm run serves every file from a peer
  (``peer_hits == N``), the fault run records a fallback per failed
  candidate (``peer_fallbacks >= N``).

``PYTHONPATH=src python -m benchmarks.federation_bench [--json PATH]``
prints the same ``name,value,derived`` CSV as the other benches;
``--json`` dumps rows + derived ratios for ``benchmarks.check_regression``.
"""

from __future__ import annotations

import hashlib
import json
import multiprocessing as mp
import os
import shutil
import sys
import tempfile
import time

from repro.core import SeaConfig, SeaFS, TierSpec
from repro.core.ledger import LEDGER_DIRNAME

_N_FILES = 24
_FILE_BYTES = 1 << 20        # working-set file size
_APP_CHUNK = 256 << 10       # application read granularity
_BW_PFS = 16e6               # modelled cold base-tier app-read bandwidth
_BW_CACHE = 512e6            # modelled node-local cache app-read bandwidth
_BW_PEER = 256e6             # peer-pull stream cap (token-bucket, real)
_MIN_PEER_SPEEDUP = 2.0

_ctx = mp.get_context("fork")


def _key(i: int) -> str:
    return f"ws_{i:05d}.bin"


def _config(workdir: str, node: str, cache_dir: str) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(name="cache", roots=(os.path.join(workdir, cache_dir),)),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        max_file_size=2 * _FILE_BYTES,
        readahead=False,
        shared_ledger=True,
        ledger_reconcile_interval_s=1e9,
        federation=True,
        federation_node=node,
        federation_heartbeat_s=1.0,
        federation_node_ttl_s=120.0,  # nodes here are processes, not hosts
        transfer_retries=0,           # a killed pull fails over, not retries
        transfer_bandwidth_caps={"peer->*": _BW_PEER},
    )


def _seed_working_set(workdir: str) -> dict[str, str]:
    root = os.path.join(workdir, "pfs")
    os.makedirs(root, exist_ok=True)
    digests: dict[str, str] = {}
    for i in range(_N_FILES):
        blob = os.urandom(_FILE_BYTES)
        with open(os.path.join(root, _key(i)), "wb") as f:
            f.write(blob)
        digests[_key(i)] = hashlib.sha256(blob).hexdigest()
    return digests


def _sibling_node(workdir: str, staged_ev, done_ev) -> None:
    """Node A: stage + publish the working set, then stay alive (the
    registry's same-host liveness probe is the pid) until released."""
    fs = SeaFS(_config(workdir, "node-a", "cacheA"))
    try:
        for i in range(_N_FILES):
            fs.stage_to_cache(_key(i))
        staged_ev.set()
        done_ev.wait(timeout=600)
    finally:
        fs.transfer.close()


def _paced_read_all(fs: SeaFS) -> tuple[float, dict[str, str]]:
    """Read the whole working set at _APP_CHUNK granularity, sleeping
    out the modelled bandwidth of each file's serving tier."""
    digests: dict[str, str] = {}
    t0 = time.perf_counter()
    for i in range(_N_FILES):
        p = os.path.join(fs.mount, _key(i))
        with fs.open(p, "rb") as f:
            bw = _BW_PFS if f.sea_tier == "pfs" else _BW_CACHE
            h = hashlib.sha256()
            while True:
                chunk = f.read(_APP_CHUNK)
                if not chunk:
                    break
                h.update(chunk)
                time.sleep(len(chunk) / bw)
            digests[_key(i)] = h.hexdigest()
    return time.perf_counter() - t0, digests


def _cache_residue(workdir: str, cache_dir: str) -> list[str]:
    """Any file at all under a fault-injected puller's cache root is a
    leak: either a torn committed copy or an orphaned staging tmp."""
    residue: list[str] = []
    root = os.path.join(workdir, cache_dir)
    for dirpath, dirnames, files in os.walk(root):
        if LEDGER_DIRNAME in dirnames:
            dirnames.remove(LEDGER_DIRNAME)
        residue.extend(os.path.join(dirpath, fn) for fn in files)
    return residue


def bench_federation(workdir: str) -> tuple[list[dict], dict]:
    expected = _seed_working_set(workdir)

    # -- cold: fresh node, empty registry, reads paced at base bandwidth
    fs_cold = SeaFS(_config(workdir, "node-b-cold", "cacheCold"))
    cold_s, cold_digests = _paced_read_all(fs_cold)
    fs_cold.transfer.close()
    if cold_digests != expected:
        raise RuntimeError("cold run returned corrupt data")

    # -- node A stages + publishes, then idles as a live peer
    staged_ev = _ctx.Event()
    done_ev = _ctx.Event()
    sibling = _ctx.Process(
        target=_sibling_node, args=(workdir, staged_ev, done_ev)
    )
    sibling.start()
    try:
        if not staged_ev.wait(timeout=300):
            raise RuntimeError("sibling node failed to stage working set")

        # -- warm: same config/caps; opens should pull from node A
        fs_warm = SeaFS(_config(workdir, "node-b-warm", "cacheWarm"))
        warm_s, warm_digests = _paced_read_all(fs_warm)
        warm_snap = fs_warm.telemetry.snapshot()
        fs_warm.transfer.close()

        # -- fault: every peer pull dies mid-transfer; reads must fall
        #    back to base, bit-exact, leaving no partials behind
        fs_fault = SeaFS(_config(workdir, "node-b-fault", "cacheFault"))

        def _kill_pull(copied: int, total: int, dst: str) -> None:
            raise OSError(5, "injected peer death", dst)

        fs_fault.transfer.chunk_hook = _kill_pull
        _fault_s, fault_digests = _paced_read_all(fs_fault)
        fault_snap = fs_fault.telemetry.snapshot()
        fs_fault.transfer.close()
    finally:
        done_ev.set()
        sibling.join(timeout=60)
        if sibling.is_alive():
            sibling.terminate()
    if sibling.exitcode != 0:
        raise RuntimeError("sibling node crashed")

    residue = _cache_residue(workdir, "cacheFault")
    derived = {
        "cold_s": round(cold_s, 3),
        "warm_s": round(warm_s, 3),
        "peer_speedup": round(cold_s / warm_s, 2),
        "peer_hits": warm_snap["peer_hits"],
        "peer_pull_bytes": warm_snap["peer_pull_bytes"],
        "warm_torn_reads": sum(
            1 for k, d in warm_digests.items() if expected[k] != d
        ),
        "fault_fallbacks": fault_snap["peer_fallbacks"],
        "fault_torn_reads": sum(
            1 for k, d in fault_digests.items() if expected[k] != d
        ),
        "fault_cache_residue": len(residue),
    }
    rows = [
        {
            "name": f"fed_cold_base_{_N_FILES}x{_FILE_BYTES >> 20}MiB",
            "value": round(cold_s * 1e6 / _N_FILES, 2),
            "derived": "us_per_file federation-cold",
        },
        {
            "name": f"fed_warm_peer_{_N_FILES}x{_FILE_BYTES >> 20}MiB",
            "value": round(warm_s * 1e6 / _N_FILES, 2),
            "derived": (
                f"us_per_file peer_hits={derived['peer_hits']}"
                f" speedup={derived['peer_speedup']}x"
            ),
        },
        {
            "name": "fed_fault_peer_death",
            "value": derived["fault_fallbacks"],
            "derived": (
                f"fallbacks torn={derived['fault_torn_reads']}"
                f" residue={derived['fault_cache_residue']}"
            ),
        },
    ]
    return rows, derived


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: federation_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]

    t_start = time.perf_counter()
    workdir = tempfile.mkdtemp(prefix="sea_federation_bench_")
    try:
        print("name,value,derived")
        rows, derived = bench_federation(workdir)
        for row in rows:
            print(f"{row['name']},{row['value']},{row['derived']}")
        print(
            f"acceptance_peer_speedup,{derived['peer_speedup']},"
            f">={_MIN_PEER_SPEEDUP}x_required"
        )
        print(
            f"acceptance_peer_hits,{derived['peer_hits']},=={_N_FILES}_required"
        )
        print(
            f"acceptance_fault_clean,"
            f"{derived['fault_torn_reads'] + derived['fault_cache_residue']},"
            f"==0_required"
        )
        ok = (
            derived["peer_speedup"] >= _MIN_PEER_SPEEDUP
            and derived["peer_hits"] == _N_FILES
            and derived["warm_torn_reads"] == 0
            and derived["fault_torn_reads"] == 0
            and derived["fault_cache_residue"] == 0
            and derived["fault_fallbacks"] >= _N_FILES
        )
        if json_path:
            with open(json_path, "w") as f:
                json.dump(
                    {
                        "rows": rows,
                        **derived,
                        "elapsed_s": round(
                            time.perf_counter() - t_start, 2
                        ),
                    },
                    f,
                    indent=2,
                )
        raise SystemExit(0 if ok else 1)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
