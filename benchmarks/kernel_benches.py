"""Kernel benches: interpret-mode correctness + XLA-path latency probes.

Wall-clock on CPU is NOT the TPU number — these rows exist to (a) prove
the Pallas kernels validate against their oracles in the bench harness
and (b) track the XLA twin-path latency for regressions.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _row(name, us, derived):
    return {"name": name, "us_per_call": f"{us:.1f}", "derived": derived}


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_kernels(quick: bool = True) -> list[dict]:
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_ref

    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, Dh = 1, 256, 4, 64
    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(key, (B, S, 2, Dh), jnp.float32)
    v = jax.random.normal(key, (B, S, 2, Dh), jnp.float32)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - ref)))
    us = _time(lambda a, b, c: flash_attention(a, b, c, block_q=64, block_k=64,
                                               interpret=True), q, k, v)
    rows.append(_row("kernels/flash_attention", us, f"max_err_vs_ref={err:.2e}"))

    r = jax.random.normal(key, (1, 128, 2, 32))
    kk = jax.random.normal(key, (1, 128, 2, 32))
    vv = jax.random.normal(key, (1, 128, 2, 32))
    w = -jnp.exp(jax.random.uniform(key, (1, 128, 2, 32), minval=-6, maxval=0.5))
    u = jax.random.normal(key, (2, 32)) * 0.5
    o = wkv6(r, kk, vv, w, u, chunk=32, interpret=True)
    oref = wkv6_ref(r.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                    vv.transpose(0, 2, 1, 3), w.transpose(0, 2, 1, 3), u
                    ).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(o - oref)))
    us = _time(lambda *a: wkv6(*a, chunk=32, interpret=True), r, kk, vv, w, u)
    rows.append(_row("kernels/wkv6", us, f"max_err_vs_ref={err:.2e}"))

    x = jax.random.normal(key, (64, 512))
    s = jax.random.normal(key, (512,)) + 1
    o = rmsnorm(x, s, interpret=True)
    from repro.kernels.rmsnorm.ref import rmsnorm_ref

    err = float(jnp.max(jnp.abs(o - rmsnorm_ref(x, s))))
    us = _time(lambda *a: rmsnorm(*a, interpret=True), x, s)
    rows.append(_row("kernels/rmsnorm", us, f"max_err_vs_ref={err:.2e}"))
    return rows


ALL_KERNEL_BENCHES = [bench_kernels]
