"""Multi-process placement benchmark: N real processes, one capped hierarchy.

This is the acceptance scenario of the shared-ledger PR: ``n_procs``
independent ``multiprocessing`` workers hammer one capped root through
their own ``SeaFS`` (``shared_ledger=True``), and afterwards the root is
walk-verified against its capacity — the cross-process reservation
protocol must make joint over-commit impossible, not just unlikely.

Open throughput is measured at 1 / 2 / max workers. Scaling is reported
relative to the single-process run; the hard gate is *no collapse*
(aggregate throughput at max workers >= 0.5x single-process) because every
admission serializes through one fcntl critical section per root —
near-linear scaling needs the lock section to be small relative to the
I/O, which holds on real nodes but not on syscall-throttled CI sandboxes.
Anything below the collapse floor (or a single over-committed byte) fails.

``PYTHONPATH=src python -m benchmarks.multiproc_bench [--json PATH]``
prints the same ``name,value,derived`` CSV as the other benches.
"""

from __future__ import annotations

import json
import multiprocessing as mp
import os
import shutil
import sys
import tempfile
import time

from repro.core import SeaConfig, SeaFS, TierSpec
from repro.core.ledger import LEDGER_DIRNAME

N_PROCS = 8
FILES_PER_PROC = 150
FILE_SIZE = 1 << 12          # 4 KiB writes
CAPACITY = 1 << 22           # 4 MiB capped root -> spill is exercised
COLLAPSE_FLOOR = 0.5         # aggregate throughput vs single-process

_ctx = mp.get_context("fork")


def _config(workdir: str, n_procs: int) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="cache",
                roots=(os.path.join(workdir, "cache"),),
                capacity=CAPACITY,
            ),
            TierSpec(
                name="pfs",
                roots=(os.path.join(workdir, "pfs"),),
                persistent=True,
            ),
        ],
        max_file_size=FILE_SIZE,
        n_procs=n_procs,
        shared_ledger=True,
        ledger_reconcile_interval_s=1e9,  # pure cross-process delta tracking
    )


def _worker(workdir: str, n_procs: int, idx: int, barrier) -> None:
    fs = SeaFS(_config(workdir, n_procs))
    payload = b"x" * FILE_SIZE
    barrier.wait(timeout=60)
    for j in range(FILES_PER_PROC):
        p = os.path.join(fs.mount, f"w{idx}_{j}.bin")
        with fs.open(p, "wb") as f:
            f.write(payload)


def _walk_used(root: str) -> int:
    total = 0
    for dirpath, dirnames, files in os.walk(root):
        if LEDGER_DIRNAME in dirnames:
            dirnames.remove(LEDGER_DIRNAME)
        for fn in files:
            total += os.path.getsize(os.path.join(dirpath, fn))
    return total


def _run_scale(n_procs: int) -> dict:
    workdir = tempfile.mkdtemp(prefix="sea_multiproc_bench_")
    try:
        barrier = _ctx.Barrier(n_procs + 1)
        procs = [
            _ctx.Process(target=_worker, args=(workdir, n_procs, i, barrier))
            for i in range(n_procs)
        ]
        for p in procs:
            p.start()
        barrier.wait(timeout=60)
        t0 = time.perf_counter()
        for p in procs:
            p.join(timeout=600)
        dt = time.perf_counter() - t0
        if any(p.exitcode != 0 for p in procs):
            raise RuntimeError(f"worker crashed at scale {n_procs}")
        cache_root = os.path.join(workdir, "cache")
        used = _walk_used(cache_root)
        n_total = n_procs * FILES_PER_PROC
        # every file must exist somewhere in the hierarchy (cache or spill)
        placed = _count_placed(workdir)
        return {
            "n_procs": n_procs,
            "opens_per_s": round(n_total / dt, 1),
            "cache_used_bytes": used,
            "capacity": CAPACITY,
            "overcommitted": used > CAPACITY,
            "files_written": n_total,
            "files_placed": placed,
        }
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def _count_placed(workdir: str) -> int:
    n = 0
    for tier_dir in ("cache", "pfs"):
        root = os.path.join(workdir, tier_dir)
        for dirpath, dirnames, files in os.walk(root):
            if LEDGER_DIRNAME in dirnames:
                dirnames.remove(LEDGER_DIRNAME)
            n += sum(1 for fn in files if fn.endswith(".bin"))
    return n


def bench_multiproc(scales: tuple[int, ...] = (1, 2, N_PROCS)) -> dict:
    results = [_run_scale(n) for n in dict.fromkeys(scales)]
    base = results[0]["opens_per_s"]
    for r in results:
        r["scaling_vs_1proc"] = round(r["opens_per_s"] / base, 2)
    return {
        "params": {
            "files_per_proc": FILES_PER_PROC,
            "file_size": FILE_SIZE,
            "capacity": CAPACITY,
            "cpu_count": os.cpu_count(),
        },
        "scales": results,
    }


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: multiproc_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]
    t_start = time.perf_counter()
    out = bench_multiproc()
    print("name,value,derived")
    ok = True
    for r in out["scales"]:
        n = r["n_procs"]
        print(f"multiproc_open_{n}p,{r['opens_per_s']},x{r['scaling_vs_1proc']}")
        print(
            f"multiproc_cache_used_{n}p,{r['cache_used_bytes']},"
            f"cap={r['capacity']}"
        )
        if r["overcommitted"]:
            print(f"multiproc_OVERCOMMIT_{n}p,{r['cache_used_bytes']},FAIL")
            ok = False
        if r["files_placed"] != r["files_written"]:
            print(
                f"multiproc_LOST_FILES_{n}p,"
                f"{r['files_written'] - r['files_placed']},FAIL"
            )
            ok = False
    top = out["scales"][-1]
    print(
        f"acceptance_no_overcommit,{int(not top['overcommitted'])},required"
    )
    print(
        f"acceptance_scaling_{top['n_procs']}p,"
        f"{top['scaling_vs_1proc']},>={COLLAPSE_FLOOR}_required"
    )
    if top["scaling_vs_1proc"] < COLLAPSE_FLOOR:
        ok = False
    if json_path:
        out["elapsed_s"] = round(time.perf_counter() - t_start, 2)
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
