"""Benchmarks reproducing the paper's tables and figures.

One function per paper table/figure:
    bench_table2_storage   — measured container tier bandwidths (Table 2)
    bench_fig2a_nodes      — simulated: vary compute nodes
    bench_fig2b_disks      — simulated: vary local disks
    bench_fig2c_iterations — simulated: vary iterations (intermediate data)
    bench_fig2d_processes  — simulated: vary parallel processes
    bench_fig3_modes       — simulated: Lustre vs in-memory vs flush-all
    bench_local_incrementation — REAL incrementation app through SeaMount
                                  on the container's actual tiers

Simulated benches use the paper's cluster (5 nodes / 4 Lustre servers /
44 OSTs) and report model bounds next to simulated makespans. Real benches
run on the container: /dev/shm (tmpfs) -> local disk, with fsync'd writes
so page cache does not mask device speeds.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import Sea, SeaConfig, SeaMount, TierSpec
from repro.core.model import (
    ClusterSpec,
    MiB,
    Workload,
    lustre_bounds,
    sea_bounds,
)
from repro.core.simulator import Simulator

PAPER = ClusterSpec()


def _row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": f"{us:.1f}", "derived": derived}


# --------------------------------------------------------------- Table 2
def bench_table2_storage(quick: bool = True) -> list[dict]:
    """Measure tmpfs vs local-disk vs (container) read/write bandwidth,
    dd-style — the container analogue of the paper's Table 2."""
    rows = []
    nbytes = 64 * (1 << 20) if quick else 512 * (1 << 20)
    blk = np.random.default_rng(0).integers(0, 255, nbytes, dtype=np.uint8)
    targets = []
    if os.path.isdir("/dev/shm"):
        targets.append(("tmpfs", "/dev/shm/sea_bench"))
    targets.append(("disk", os.path.join(tempfile.gettempdir(), "sea_bench")))
    for name, root in targets:
        os.makedirs(root, exist_ok=True)
        path = os.path.join(root, "bench.bin")
        # write (fsync'd, like dd conv=fdatasync)
        t0 = time.perf_counter()
        with open(path, "wb") as f:
            f.write(blk.tobytes())
            f.flush()
            os.fsync(f.fileno())
        wdt = time.perf_counter() - t0
        # cached read
        t0 = time.perf_counter()
        with open(path, "rb") as f:
            f.read()
        crdt = time.perf_counter() - t0
        rows.append(
            _row(
                f"table2/{name}/write",
                wdt * 1e6,
                f"{nbytes / wdt / MiB:.0f}MiB_per_s",
            )
        )
        rows.append(
            _row(
                f"table2/{name}/cached_read",
                crdt * 1e6,
                f"{nbytes / crdt / MiB:.0f}MiB_per_s",
            )
        )
        shutil.rmtree(root, ignore_errors=True)
    return rows


# ------------------------------------------------------------ Fig 2 (sim)
def _sim_pair(cl: ClusterSpec, w: Workload) -> tuple[float, float]:
    rl = Simulator(cl, w, "lustre").run()
    rs = Simulator(cl, w, "sea").run()
    return rl.makespan, rs.makespan


def bench_fig2a_nodes(quick: bool = True) -> list[dict]:
    rows = []
    w = Workload(n=10)
    for c in (1, 2, 3, 5, 8) if not quick else (1, 5, 8):
        cl = PAPER.with_(c=c)
        tl, ts = _sim_pair(cl, w)
        lb, sb = lustre_bounds(w, cl), sea_bounds(w, cl)
        rows.append(
            _row(
                f"fig2a/nodes={c}",
                ts * 1e6,
                f"speedup={tl / ts:.2f}x;lustre={tl:.0f}s"
                f";l_bounds=[{lb[0]:.0f},{lb[1]:.0f}]"
                f";s_bounds=[{sb[0]:.0f},{sb[1]:.0f}]",
            )
        )
    return rows


def bench_fig2b_disks(quick: bool = True) -> list[dict]:
    rows = []
    w = Workload(n=5)
    for g in (1, 2, 4, 6) if not quick else (1, 6):
        cl = PAPER.with_(g=g)
        tl, ts = _sim_pair(cl, w)
        rows.append(
            _row(f"fig2b/disks={g}", ts * 1e6, f"speedup={tl / ts:.2f}x")
        )
    return rows


def bench_fig2c_iterations(quick: bool = True) -> list[dict]:
    rows = []
    for n in (1, 5, 10, 15) if not quick else (1, 10):
        w = Workload(n=n)
        tl, ts = _sim_pair(PAPER, w)
        lb, sb = lustre_bounds(w, PAPER), sea_bounds(w, PAPER)
        rows.append(
            _row(
                f"fig2c/iters={n}",
                ts * 1e6,
                f"speedup={tl / ts:.2f}x"
                f";l_bounds=[{lb[0]:.0f},{lb[1]:.0f}]"
                f";s_bounds=[{sb[0]:.0f},{sb[1]:.0f}]",
            )
        )
    return rows


def bench_fig2d_processes(quick: bool = True) -> list[dict]:
    rows = []
    w = Workload(n=5)
    for p in (1, 2, 4, 8, 16, 32) if not quick else (1, 16, 32):
        cl = PAPER.with_(p=p)
        tl, ts = _sim_pair(cl, w)
        rows.append(
            _row(f"fig2d/procs={p}", ts * 1e6, f"speedup={tl / ts:.2f}x")
        )
    return rows


def bench_fig3_modes(quick: bool = True) -> list[dict]:
    cl = PAPER.with_(p=64)
    w = Workload(n=5)
    rl = Simulator(cl, w, "lustre").run()
    rs = Simulator(cl, w, "sea").run()
    rf = Simulator(cl, w, "sea-flushall").run()
    return [
        _row("fig3/lustre", rl.makespan * 1e6, "baseline"),
        _row(
            "fig3/sea_inmemory",
            rs.makespan * 1e6,
            f"vs_lustre={rl.makespan / rs.makespan:.2f}x_faster",
        ),
        _row(
            "fig3/sea_flushall",
            rf.makespan * 1e6,
            f"vs_inmem={rf.makespan / rs.makespan:.2f}x_slower"
            f";vs_lustre={rf.makespan / rl.makespan:.2f}x_slower"
            f";paper=3.5x;1.3x",
        ),
    ]


# --------------------------------------------------- real local execution
def _incrementation_app(mount: str, n_blocks: int, block_elems: int, iters: int,
                        fsync: bool = True) -> None:
    """Paper Alg. 1, written as an UNMODIFIED numpy pipeline: it only sees
    paths under the mountpoint; Sea (or the baseline FS) does placement."""
    rng = np.random.default_rng(42)
    for b in range(n_blocks):
        chunk = rng.integers(0, 255, block_elems, dtype=np.uint8)
        prev = os.path.join(mount, f"input_{b}.npy")
        np.save(prev, chunk)
        for i in range(1, iters + 1):
            arr = np.load(prev)
            arr = arr + 1
            cur = os.path.join(mount, f"block{b}_iter{i}.npy")
            with open(cur, "wb") as f:
                np.save(f, arr)
                if fsync:
                    try:
                        f.flush()
                        os.fsync(f.fileno())
                    except (OSError, AttributeError):
                        pass
            prev = cur


def bench_local_incrementation(quick: bool = True) -> list[dict]:
    """End-to-end: the incrementation app through SeaMount on real tiers
    (tmpfs -> disk) vs. the same app writing directly to the disk tier
    (the 'PFS' stand-in). Real bytes, real devices, fsync'd."""
    n_blocks = 4 if quick else 16
    block_elems = (4 if quick else 16) * (1 << 20)  # 4/16 MiB blocks
    iters = 5
    results = []

    workdir = tempfile.mkdtemp(prefix="sea_local_")
    try:
        # --- baseline: everything on the disk tier -------------------------
        base_dir = os.path.join(workdir, "baseline")
        os.makedirs(base_dir)
        t0 = time.perf_counter()
        _incrementation_app(base_dir, n_blocks, block_elems, iters)
        t_base = time.perf_counter() - t0
        shutil.rmtree(base_dir, ignore_errors=True)

        # --- Sea in-memory: tmpfs cache with spill, finals flushed ---------
        shm = "/dev/shm" if os.path.isdir("/dev/shm") else workdir
        cfg = SeaConfig(
            mount=os.path.join(workdir, "mount"),
            tiers=[
                TierSpec(
                    name="tmpfs",
                    roots=(os.path.join(shm, "sea_local_bench"),),
                    capacity=(n_blocks * block_elems * iters) // 2,  # force spill
                ),
                TierSpec(name="disk", roots=(os.path.join(workdir, "disk"),)),
                TierSpec(
                    name="pfs",
                    roots=(os.path.join(workdir, "pfs"),),
                    persistent=True,
                ),
            ],
            max_file_size=block_elems + (1 << 16),
            n_procs=1,
            flushlist=(f"*iter{iters}.npy",),
            evictlist=(f"*iter{iters}.npy",),
        )
        with Sea(cfg) as sea:
            t0 = time.perf_counter()
            with SeaMount(sea.fs):
                _incrementation_app(cfg.mount, n_blocks, block_elems, iters)
            t_app = time.perf_counter() - t0
        t_sea = time.perf_counter() - t0  # includes final flush drain
        n_final = len(
            [p for p in os.listdir(os.path.join(workdir, "pfs"))
             if p.endswith(f"iter{iters}.npy")]
        )
        for t in sea.fs.hierarchy:
            t.wipe()
        results = [
            _row("local_incr/baseline_disk", t_base * 1e6, "all_io_on_disk"),
            _row(
                "local_incr/sea_inmemory",
                t_sea * 1e6,
                f"speedup={t_base / t_sea:.2f}x;app_only={t_app:.2f}s"
                f";finals_flushed={n_final}/{n_blocks}",
            ),
        ]
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
        shutil.rmtree("/dev/shm/sea_local_bench", ignore_errors=True)
    return results


ALL_BENCHES = [
    bench_table2_storage,
    bench_fig2a_nodes,
    bench_fig2b_disks,
    bench_fig2c_iterations,
    bench_fig2d_processes,
    bench_fig3_modes,
    bench_local_incrementation,
]
