"""Placement hot-path benchmark: O(1) ledger vs the seed's O(n) re-walk.

The acceptance target for the capacity-ledger PR: placement-decision cost
must be independent of cached-file count, with >=5x faster open()
eligibility at 10k cached files on a capped root.

Two measurements per population size:
  placement_select   — ``PlacementPolicy.select()`` alone (the eligibility
                       check every intercepted ``open(.., "w")`` pays)
  open_write_close   — end-to-end SeaFS ``open``/``write``/``close``/
                       ``remove`` of a fresh key under the mount

``PYTHONPATH=src python -m benchmarks.placement_bench [--json PATH]``
prints the same ``name,us_per_call,derived`` CSV as the other benches
(derived = speedup of ledger over walk at that population); ``--json``
additionally dumps the rows for the CI regression gate
(``benchmarks.check_regression``).
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

from repro.core import SeaConfig, SeaFS, TierSpec

_POPULATIONS = (100, 1000, 10000)


def _populate(root: str, n_files: int) -> None:
    """Drop ``n_files`` small files under ``root`` (64 dirs, like a real
    scattered cache) so the walk baseline has something to walk."""
    payload = b"x" * 64
    for i in range(n_files):
        d = os.path.join(root, f"d{i % 64:02d}")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"f{i}.bin"), "wb") as f:
            f.write(payload)


def _config(workdir: str, use_ledger: bool) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="cache",
                roots=(os.path.join(workdir, "cache"),),
                capacity=1 << 30,  # capped -> eligibility must count used bytes
            ),
            TierSpec(
                name="pfs",
                roots=(os.path.join(workdir, "pfs"),),
                persistent=True,
            ),
        ],
        max_file_size=1 << 16,
        n_procs=2,
        capacity_ledger=use_ledger,
        ledger_reconcile_interval_s=1e9,  # isolate the hot path from reconciles
    )


def _time_select(fs: SeaFS, n_calls: int) -> float:
    """Mean seconds per ``policy.select()`` (the placement decision)."""
    fs.policy.select()  # warm (ledger: triggers the one reconcile walk)
    t0 = time.perf_counter()
    for _ in range(n_calls):
        fs.policy.select()
    return (time.perf_counter() - t0) / n_calls


def _time_open(fs: SeaFS, n_calls: int) -> float:
    """Mean seconds per open/write/close/remove of a fresh key."""
    fs.policy.select()  # warm
    t0 = time.perf_counter()
    for i in range(n_calls):
        p = os.path.join(fs.mount, f"bench_{i}.bin")
        with fs.open(p, "wb") as f:
            f.write(b"y" * 128)
        fs.remove(p)
    return (time.perf_counter() - t0) / n_calls


def bench_placement_ledger_vs_walk(quick: bool = True):
    rows = []
    # the full sweep IS the quick sweep: call counts below already scale
    # inversely with population, keeping wall time bounded either way
    del quick
    for n_files in _POPULATIONS:
        workdir = tempfile.mkdtemp(prefix="sea_placement_bench_")
        try:
            cache_root = os.path.join(workdir, "cache")
            os.makedirs(cache_root, exist_ok=True)
            _populate(cache_root, n_files)

            fs_walk = SeaFS(_config(workdir, use_ledger=False))
            fs_ledger = SeaFS(_config(workdir, use_ledger=True))

            # walk cost grows with n_files: keep wall time bounded
            walk_calls = max(3, min(50, 30000 // n_files))
            ledger_calls = 2000

            s_walk = _time_select(fs_walk, walk_calls)
            s_ledger = _time_select(fs_ledger, ledger_calls)
            o_walk = _time_open(fs_walk, walk_calls)
            o_ledger = _time_open(fs_ledger, min(ledger_calls, 500))

            rows.append({
                "name": f"placement_select_walk_{n_files}f",
                "us_per_call": round(s_walk * 1e6, 2),
                "derived": "",
            })
            rows.append({
                "name": f"placement_select_ledger_{n_files}f",
                "us_per_call": round(s_ledger * 1e6, 2),
                "derived": f"speedup={s_walk / s_ledger:.1f}x",
            })
            rows.append({
                "name": f"open_write_close_walk_{n_files}f",
                "us_per_call": round(o_walk * 1e6, 2),
                "derived": "",
            })
            rows.append({
                "name": f"open_write_close_ledger_{n_files}f",
                "us_per_call": round(o_ledger * 1e6, 2),
                "derived": f"speedup={o_walk / o_ledger:.1f}x",
            })
        finally:
            shutil.rmtree(workdir, ignore_errors=True)
    return rows


ALL_PLACEMENT_BENCHES = [bench_placement_ledger_vs_walk]


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: placement_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]
    t_start = time.perf_counter()
    print("name,us_per_call,derived")
    ok = True
    rows = bench_placement_ledger_vs_walk(quick=True)
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
    # acceptance: >=5x faster open eligibility at the largest population
    big = _POPULATIONS[-1]
    walk = next(r for r in rows if r["name"] == f"open_write_close_walk_{big}f")
    led = next(r for r in rows if r["name"] == f"open_write_close_ledger_{big}f")
    speedup = walk["us_per_call"] / led["us_per_call"]
    print(f"acceptance_open_speedup_{big}f,{speedup:.1f},>=5x_required")
    ok = speedup >= 5.0
    if json_path:
        with open(json_path, "w") as f:
            json.dump(
                {
                    "rows": rows,
                    "open_speedup": round(speedup, 1),
                    "elapsed_s": round(time.perf_counter() - t_start, 2),
                },
                f,
                indent=2,
            )
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
