"""Adaptive-read-path benchmark: predictive readahead + open fast path.

Three acceptance targets for the adaptive-read-path PR (ISSUE 5):

* **Cold sequential block processing** (the Big Brain-style workload of
  the HSM follow-up paper: a pipeline walks numbered blocks it has
  never seen, computing on each). The storage speeds are *modelled*, so
  the measurement is hardware-independent and deterministic: an
  application read pays ``bytes / BW`` of its serving tier (slow PFS vs
  fast cache), and speculative staging is paced by the engine's real
  token-bucket throttle (``transfer_bandwidth_caps``) at streaming
  bandwidth. With ``readahead=True`` the predictor must overlap staging
  with compute and serve the reads hot: wall-clock >= 2x faster than
  ``readahead=False`` (median of 3 runs each).
* **Speculation discipline** — on a random-access permutation of the
  same blocks the predictor must keep wasted-prefetch bytes (staged but
  never read) under 20% of staged bytes.
* **Open fast path** — per-call bookkeeping overhead of a read-hit
  ``open``/close (Sea's Python work with the ``open(2)`` syscall
  stubbed out of both paths) must drop >= 30% with
  ``open_fast_path=True`` vs the PR-4 path (``open_fast_path=False``).

``PYTHONPATH=src python -m benchmarks.readahead_bench [--json PATH]``
prints the same ``name,us_per_call,derived`` CSV as the other benches;
``--json`` dumps rows + derived ratios for ``benchmarks.check_regression``.
"""

from __future__ import annotations

import json
import os
import random
import shutil
import sys
import tempfile
import time

from repro.core import SeaConfig, SeaFS, TierSpec

_BLOCK_BYTES = 1 << 20       # one processing block
_N_BLOCKS = 32               # a short Big Brain-style sweep
_APP_CHUNK = 512 << 10       # application read granularity (2 chunks per
                             # block: enough to model streaming, few
                             # enough that per-sleep timer overshoot
                             # cannot eat the cache-read margin)
_BW_PFS = 16e6               # modelled cold-tier read bandwidth (bytes/s)
                             # — far enough below the cache model that
                             # timer-slack jitter (~5-10ms/block on busy
                             # runners) cannot eat the >=2x gate margin
_BW_CACHE = 512e6            # modelled cache-tier read bandwidth
_BW_STAGE = 128e6            # staging stream cap (token-bucket, real)
_COMPUTE_S = 0.015           # per-block compute the staging hides under
_SEQ_RUNS = 3                # median-of
_MIN_SEQ_SPEEDUP = 2.0
_MAX_WASTED_RATIO = 0.20
_FASTPATH_CALLS = 1000
_FASTPATH_ROUNDS = 9
_MIN_FASTPATH_REDUCTION = 0.30


def _config(workdir: str, *, readahead: bool, fast: bool = True) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(os.path.join(workdir, "t0"),)),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        max_file_size=2 * _BLOCK_BYTES,
        readahead=readahead,
        open_fast_path=fast,
        transfer_bandwidth_caps={"pfs->*": _BW_STAGE},
    )


def _seed_blocks(workdir: str) -> None:
    root = os.path.join(workdir, "pfs")
    os.makedirs(root, exist_ok=True)
    blob = os.urandom(_BLOCK_BYTES)
    for i in range(_N_BLOCKS):
        with open(os.path.join(root, f"block_{i:05d}.bin"), "wb") as f:
            f.write(blob)


def _paced_read(f, tier: str) -> int:
    """Read a whole block at _APP_CHUNK granularity, sleeping out the
    modelled bandwidth of the serving tier (the real I/O inside the
    container is page-cache-fast either way; the model is what makes the
    measurement hardware-independent)."""
    bw = _BW_PFS if tier == "pfs" else _BW_CACHE
    total = 0
    while True:
        chunk = f.read(_APP_CHUNK)
        if not chunk:
            return total
        total += len(chunk)
        time.sleep(len(chunk) / bw)


def _run_sequential(workdir: str, *, readahead: bool) -> tuple[float, SeaFS]:
    fs = SeaFS(_config(workdir, readahead=readahead))
    t0 = time.perf_counter()
    for i in range(_N_BLOCKS):
        p = os.path.join(fs.mount, f"block_{i:05d}.bin")
        with fs.open(p, "rb") as f:
            _paced_read(f, f.sea_tier)
        time.sleep(_COMPUTE_S)  # per-block compute (staging overlaps here)
    dt = time.perf_counter() - t0
    fs.prefetcher.stop()
    fs.transfer.close()
    return dt, fs


def bench_sequential(workdir: str) -> tuple[list[dict], float]:
    _seed_blocks(workdir)
    cold: list[float] = []
    warm: list[float] = []
    for _ in range(_SEQ_RUNS):
        for enabled, times in ((False, cold), (True, warm)):
            # fresh cache + fresh predictor per run: every run is cold
            shutil.rmtree(os.path.join(workdir, "t0"), ignore_errors=True)
            dt, _fs = _run_sequential(workdir, readahead=enabled)
            times.append(dt)
    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    speedup = med(cold) / med(warm)
    rows = [
        {
            "name": f"seq_cold_read_{_N_BLOCKS}x{_BLOCK_BYTES >> 20}MiB",
            "us_per_call": round(med(cold) * 1e6 / _N_BLOCKS, 2),
            "derived": "readahead=off",
        },
        {
            "name": f"seq_readahead_{_N_BLOCKS}x{_BLOCK_BYTES >> 20}MiB",
            "us_per_call": round(med(warm) * 1e6 / _N_BLOCKS, 2),
            "derived": f"readahead=on speedup={speedup:.2f}x",
        },
    ]
    return rows, speedup


def bench_random_waste(workdir: str) -> tuple[list[dict], float]:
    _seed_blocks(workdir)
    shutil.rmtree(os.path.join(workdir, "t0"), ignore_errors=True)
    fs = SeaFS(_config(workdir, readahead=True))
    order = list(range(_N_BLOCKS))
    random.Random(11).shuffle(order)
    for i in order:
        p = os.path.join(fs.mount, f"block_{i:05d}.bin")
        with fs.open(p, "rb") as f:
            f.read()
        time.sleep(0.002)  # give speculation time to be wrong
    time.sleep(0.2)  # let in-flight staging settle
    fs.prefetcher.stop()  # pending predictions settle as waste
    snap = fs.telemetry.snapshot()
    fs.transfer.close()
    staged = snap["readahead_staged_bytes"]
    wasted = snap["readahead_wasted_bytes"]
    ratio = (wasted / staged) if staged else 0.0
    rows = [
        {
            "name": f"random_access_staged_{_N_BLOCKS}blk",
            "us_per_call": float(staged),
            "derived": f"wasted={wasted} ratio={ratio:.2f}",
        }
    ]
    return rows, ratio


def _time_loop(fn) -> float:
    t0 = time.perf_counter()
    for _ in range(_FASTPATH_CALLS):
        fn()
    return (time.perf_counter() - t0) * 1e6 / _FASTPATH_CALLS


class _FakeRaw:
    """Stand-in for the object ``io.open`` returns: just enough surface
    for ``_SeaFile.close`` (``tell``/``close``)."""

    __slots__ = ()

    def tell(self):
        return 0

    def close(self):
        pass


def bench_fastpath(workdir: str) -> tuple[list[dict], float]:
    """Per-call bookkeeping overhead of a read-hit open/close, fast path
    on vs off (the PR-4 baseline).

    The ``open(2)`` syscall in sandboxed kernels is bursty at the
    hundreds-of-µs scale — the same magnitude as the overhead being
    measured — so instead of subtracting a noisy raw-``io.open``
    baseline, the syscall itself is stubbed out of BOTH paths
    (``repro.core.seafs.io`` swapped for a fake whose ``open`` returns a
    no-op file). What remains is exactly Sea's per-open Python work
    (resolution, locking, counts, telemetry): deterministic and
    hardware-independent, in the same spirit as the modelled-bandwidth
    sequential workload."""
    import gc
    import types

    from repro.core import seafs as seafs_mod

    def setup(fast: bool):
        wd = os.path.join(workdir, f"fp_{int(fast)}")
        shutil.rmtree(wd, ignore_errors=True)
        fs = SeaFS(_config(wd, readahead=False, fast=fast))
        p = os.path.join(fs.mount, "hot.bin")
        with fs.open(p, "wb") as f:
            f.write(b"x" * 4096)
        for _ in range(300):  # warmup (and prime the resolver entry)
            fs.open(p, "rb").close()
        return fs, p

    fs_slow, p_slow = setup(False)
    fs_fast, p_fast = setup(True)
    fake_raw = _FakeRaw()
    fake_io = types.SimpleNamespace(open=lambda *a, **kw: fake_raw)
    slow_t: list[float] = []
    fast_t: list[float] = []
    orig_io, seafs_mod.io = seafs_mod.io, fake_io
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        # interleaved median-of-rounds: residual interpreter noise (GC
        # is off, but timers/threads remain) hits both series alike and
        # the median discards spike rounds
        for _ in range(_FASTPATH_ROUNDS):
            slow_t.append(
                _time_loop(lambda: fs_slow.open(p_slow, "rb").close())
            )
            fast_t.append(
                _time_loop(lambda: fs_fast.open(p_fast, "rb").close())
            )
    finally:
        seafs_mod.io = orig_io
        if gc_was_enabled:
            gc.enable()
    assert fs_fast.telemetry.snapshot()["fastpath_opens"] > 0
    assert fs_slow.telemetry.snapshot()["fastpath_opens"] == 0
    fs_slow.transfer.close()
    fs_fast.transfer.close()
    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    slow_o, fast_o = med(slow_t), med(fast_t)
    reduction = 1.0 - fast_o / slow_o
    rows = [
        {
            "name": "open_read_hit_pr4_overhead",
            "us_per_call": round(slow_o, 2),
            "derived": "open_fast_path=off",
        },
        {
            "name": "open_read_hit_fastpath_overhead",
            "us_per_call": round(fast_o, 2),
            "derived": f"reduction={reduction:.2f}",
        },
    ]
    return rows, reduction


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: readahead_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]

    t_start = time.perf_counter()
    workdir = tempfile.mkdtemp(prefix="sea_readahead_bench_")
    try:
        print("name,us_per_call,derived")
        seq_rows, speedup = bench_sequential(workdir)
        waste_rows, wasted_ratio = bench_random_waste(workdir)
        fp_rows, reduction = bench_fastpath(workdir)
        rows = seq_rows + waste_rows + fp_rows
        for row in rows:
            print(f"{row['name']},{row['us_per_call']},{row['derived']}")
        print(
            f"acceptance_seq_speedup,{speedup:.2f},>={_MIN_SEQ_SPEEDUP}x_required"
        )
        print(
            f"acceptance_wasted_ratio,{wasted_ratio:.2f},"
            f"<{_MAX_WASTED_RATIO}_required"
        )
        print(
            f"acceptance_fastpath_reduction,{reduction:.2f},"
            f">={_MIN_FASTPATH_REDUCTION}_required"
        )
        ok = (
            speedup >= _MIN_SEQ_SPEEDUP
            and wasted_ratio < _MAX_WASTED_RATIO
            and reduction >= _MIN_FASTPATH_REDUCTION
        )
        if json_path:
            with open(json_path, "w") as f:
                json.dump(
                    {
                        "rows": rows,
                        "cold_seq_speedup": round(speedup, 2),
                        "wasted_ratio": round(wasted_ratio, 3),
                        "fastpath_overhead_reduction": round(reduction, 3),
                        "elapsed_s": round(
                            time.perf_counter() - t_start, 2
                        ),
                    },
                    f,
                    indent=2,
                )
        raise SystemExit(0 if ok else 1)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
