"""Resolution hot-path benchmark: cached resolver vs the seed's cascade.

The acceptance target for the namespace-resolver PR: resolved-read latency
must be independent of root count on the hit path, and at 3 tiers × 4
roots the cached resolver must beat the seed's O(tiers × roots) probe
cascade by >= 10x.

Files are populated on the BASE tier (the worst case for the seed: every
cache root answers ENOENT before the base tier hits), mirroring the
read-heavy neuroimaging workloads of the HSM follow-up paper, where
metadata-path latency dominates.

Four measurements per (tiers × roots) layout:
  resolve_seed     — ``SeaFS.resolve_read`` with ``resolver_cache=False``
                     (the per-call probe cascade of the seed)
  resolve_cached   — same call with the warm location index and the
                     default verify trust window (pure dict lookup;
                     data-touching ops re-verify via their own ENOENT)
  resolve_verified — ``resolver_verify_window_s=0``: strict verify-on-hit,
                     one ``lstat`` per hit regardless of root count
  stat_cached      — end-to-end ``SeaFS.stat`` through the warm index

``PYTHONPATH=src python -m benchmarks.resolve_bench [--json PATH]``
prints the same ``name,us_per_call,derived`` CSV as the other benches;
``--json`` additionally dumps the rows for the CI regression gate
(``benchmarks.check_regression``).
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

from repro.core import SeaConfig, SeaFS, TierSpec

#: (label, roots-per-cache-tier). 3 tiers always; the base keeps one root.
_LAYOUTS = (("3x1", 1), ("3x2", 2), ("3x4", 4))
_N_FILES = 256


def _config(
    workdir: str, roots_per_tier: int, cached: bool, verify_window_s: float = 0.05
) -> SeaConfig:
    def roots(tag: str) -> tuple[str, ...]:
        return tuple(
            os.path.join(workdir, f"{tag}{i}") for i in range(roots_per_tier)
        )

    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=roots("t")),
            TierSpec(name="disk", roots=roots("d")),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        max_file_size=1 << 16,
        n_procs=2,
        resolver_cache=cached,
        resolver_verify_window_s=verify_window_s,
    )


def _populate_base(workdir: str, n_files: int) -> list[str]:
    """Drop ``n_files`` small files directly on the base tier (cold input
    data, per the paper: inputs start on the PFS)."""
    base = os.path.join(workdir, "pfs")
    keys = []
    for i in range(n_files):
        key = f"inputs/d{i % 16:02d}/f{i}.bin"
        real = os.path.join(base, key)
        os.makedirs(os.path.dirname(real), exist_ok=True)
        with open(real, "wb") as f:
            f.write(b"x" * 64)
        keys.append(key)
    return keys


def _time_resolve(fs: SeaFS, keys: list[str], n_rounds: int) -> float:
    """Mean seconds per ``resolve_read`` over the key population."""
    for key in keys:
        assert fs.resolve_read(key) is not None  # warm (and sanity)
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        for key in keys:
            fs.resolve_read(key)
    return (time.perf_counter() - t0) / (n_rounds * len(keys))


def _time_stat(fs: SeaFS, keys: list[str], n_rounds: int) -> float:
    paths = [os.path.join(fs.mount, k) for k in keys]
    fs.stat(paths[0])  # warm
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        for p in paths:
            fs.stat(p)
    return (time.perf_counter() - t0) / (n_rounds * len(paths))


def bench_resolver_vs_seed():
    rows = []
    for label, roots_per_tier in _LAYOUTS:
        workdir = tempfile.mkdtemp(prefix="sea_resolve_bench_")
        try:
            keys = _populate_base(workdir, _N_FILES)
            fs_seed = SeaFS(_config(workdir, roots_per_tier, cached=False))
            fs_cached = SeaFS(_config(workdir, roots_per_tier, cached=True))
            fs_strict = SeaFS(
                _config(workdir, roots_per_tier, cached=True, verify_window_s=0.0)
            )

            s_seed = _time_resolve(fs_seed, keys, n_rounds=3)
            s_cached = _time_resolve(fs_cached, keys, n_rounds=20)
            s_strict = _time_resolve(fs_strict, keys, n_rounds=10)
            s_stat = _time_stat(fs_cached, keys, n_rounds=10)

            rows.append({
                "name": f"resolve_seed_{label}",
                "us_per_call": round(s_seed * 1e6, 2),
                "derived": "",
            })
            rows.append({
                "name": f"resolve_cached_{label}",
                "us_per_call": round(s_cached * 1e6, 2),
                "derived": f"speedup={s_seed / s_cached:.1f}x",
            })
            rows.append({
                "name": f"resolve_verified_{label}",
                "us_per_call": round(s_strict * 1e6, 2),
                "derived": f"speedup={s_seed / s_strict:.1f}x",
            })
            rows.append({
                "name": f"stat_cached_{label}",
                "us_per_call": round(s_stat * 1e6, 2),
                "derived": "",
            })
        finally:
            shutil.rmtree(workdir, ignore_errors=True)
    return rows


ALL_RESOLVE_BENCHES = [bench_resolver_vs_seed]


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: resolve_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]
    t_start = time.perf_counter()
    print("name,us_per_call,derived")
    rows = bench_resolver_vs_seed()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")

    def _us(name: str) -> float:
        return next(r for r in rows if r["name"] == name)["us_per_call"]

    # acceptance 1: >=10x over the seed cascade at the widest layout
    big = _LAYOUTS[-1][0]
    speedup = _us(f"resolve_seed_{big}") / _us(f"resolve_cached_{big}")
    print(f"acceptance_resolve_speedup_{big},{speedup:.1f},>=10x_required")
    # acceptance 2: hit path independent of root count (flat across layouts)
    small = _LAYOUTS[0][0]
    flatness = _us(f"resolve_cached_{big}") / _us(f"resolve_cached_{small}")
    print(f"acceptance_hit_flatness_{big}_vs_{small},{flatness:.2f},<=3x_required")
    ok = speedup >= 10.0 and flatness <= 3.0
    if json_path:
        with open(json_path, "w") as f:
            json.dump(
                {
                    "rows": rows,
                    "resolve_speedup": round(speedup, 1),
                    "hit_flatness": round(flatness, 2),
                    "elapsed_s": round(time.perf_counter() - t_start, 2),
                },
                f,
                indent=2,
            )
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
