"""Roofline benches: read the dry-run artifacts and emit per-cell roofline
rows (+ markdown table generation for EXPERIMENTS.md)."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_records(mesh: str | None = None, kind: str = "baseline") -> list[dict]:
    """kind: baseline | analysis | variant (by artifact filename prefix)."""
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        base = os.path.basename(f)
        is_analysis = base.startswith("analysis__")
        is_variant = "variant-" in base
        if kind == "baseline" and (is_analysis or is_variant):
            continue
        if kind == "analysis" and not is_analysis:
            continue
        if kind == "variant" and not is_variant:
            continue
        with open(f) as fh:
            r = json.load(fh)
        if mesh and r.get("mesh") != mesh:
            continue
        r["_file"] = base
        recs.append(r)
    return recs


def bench_roofline_table(quick: bool = True) -> list[dict]:
    """One row per dry-run cell: the three roofline terms + dominant."""
    rows = []
    for r in load_records("single", "baseline"):
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            rows.append({"name": name, "us_per_call": "0",
                         "derived": f"{r['status']}:{r.get('reason', '')[:60]}"})
            continue
        t = r["roofline"]
        rows.append({
            "name": name,
            "us_per_call": f"{t['step_lower_bound_s'] * 1e6:.0f}",
            "derived": (
                f"dom={t['dominant']};comp={t['compute_s']:.3g}s"
                f";mem={t['memory_s']:.3g}s;coll={t['collective_s']:.3g}s"
                f";useful_flops={r.get('useful_flops_ratio') or 0:.3f}"
            ),
        })
    for r in load_records("single", "variant"):
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        rows.append({
            "name": f"roofline-variant/{r.get('variant', '?')}/{r['arch']}/{r['shape']}",
            "us_per_call": f"{t['step_lower_bound_s'] * 1e6:.0f}",
            "derived": (
                f"dom={t['dominant']};comp={t['compute_s']:.3g}s"
                f";mem={t['memory_s']:.3g}s;coll={t['collective_s']:.3g}s"
            ),
        })
    for r in load_records("single", "analysis"):
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        rows.append({
            "name": f"roofline-analysis/{r['arch']}/{r['shape']}",
            "us_per_call": f"{t['step_lower_bound_s'] * 1e6:.0f}",
            "derived": (
                f"dom={t['dominant']};comp={t['compute_s']:.3g}s"
                f";mem={t['memory_s']:.3g}s;coll={t['collective_s']:.3g}s"
                f";useful_flops={r.get('useful_flops_ratio') or 0:.3f}"
            ),
        })
    return rows


def bench_dryrun_status(quick: bool = True) -> list[dict]:
    """Deliverable (e): every (arch x shape x mesh) compiles."""
    rows = []
    for mesh in ("single", "multi"):
        recs = load_records(mesh, "baseline")
        ok = sum(r["status"] == "ok" for r in recs)
        skip = sum(r["status"] == "skipped" for r in recs)
        err = sum(r["status"] == "error" for r in recs)
        rows.append({
            "name": f"dryrun/{mesh}",
            "us_per_call": "0",
            "derived": f"ok={ok};skipped={skip};failed={err}",
        })
    return rows


# ------------------------------------------------------------- markdown
MD_HEADER = (
    "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
    "bytes/chip (GB) | MODEL/HLO flops | bottleneck note |\n"
    "|---|---|---|---|---|---|---|---|---|"
)

NOTES = {
    "compute": "more MXU-friendly tiling / larger per-chip batch",
    "memory": "cut HBM traffic: remat policy, fused ops, bf16 intermediates",
    "collective": "resharding: fewer all-gathers (param layout), comm overlap",
}


def markdown_table(mesh: str = "single") -> str:
    lines = [MD_HEADER]
    for r in load_records(mesh, "baseline"):
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | "
                f"{r['reason'][:60]} |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR |||||||")
            continue
        t = r["roofline"]
        mem = r.get("memory", {}) or {}
        args_gb = (mem.get("argument_size_in_bytes") or 0) / 1e9
        tmp_gb = (mem.get("temp_size_in_bytes") or 0) / 1e9
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"**{t['dominant']}** | {args_gb:.1f}+{tmp_gb:.1f} | "
            f"{ratio:.3f} | {NOTES[t['dominant']]} |"
        )
    return "\n".join(lines)


ALL_ROOFLINE_BENCHES = [bench_dryrun_status, bench_roofline_table]

if __name__ == "__main__":
    import sys

    print(markdown_table(sys.argv[1] if len(sys.argv) > 1 else "single"))
