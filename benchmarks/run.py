"""Benchmark harness entry point.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only PREFIX]``

Prints ``name,us_per_call,derived`` CSV — one section per paper
table/figure plus framework-side kernel and roofline benchmarks.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full sweeps (slow)")
    ap.add_argument("--only", default="", help="only benches whose name starts with this")
    args = ap.parse_args()

    from benchmarks.paper_benches import ALL_BENCHES

    benches = list(ALL_BENCHES)
    try:
        from benchmarks.placement_bench import ALL_PLACEMENT_BENCHES

        benches += ALL_PLACEMENT_BENCHES
    except ImportError:
        pass
    try:
        from benchmarks.kernel_benches import ALL_KERNEL_BENCHES

        benches += ALL_KERNEL_BENCHES
    except ImportError:
        pass
    try:
        from benchmarks.roofline_bench import ALL_ROOFLINE_BENCHES

        benches += ALL_ROOFLINE_BENCHES
    except ImportError:
        pass

    print("name,us_per_call,derived")
    failed = 0
    for bench in benches:
        if args.only and not bench.__name__.startswith(
            ("bench_" + args.only, args.only)
        ):
            continue
        try:
            for row in bench(quick=not args.full):
                print(f"{row['name']},{row['us_per_call']},{row['derived']}")
            sys.stdout.flush()
        except Exception:
            failed += 1
            print(f"{bench.__name__},ERROR,see_stderr")
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
