"""Overhead gate for the seacheck runtime lock-order detector.

Runs a lock-heavy tier-1 subset twice — uninstrumented and under
``SEACHECK=1`` — as real pytest subprocesses (the instrumentation must
be installed before ``repro`` imports, so in-process toggling would not
measure the real leg) and reports the wall-clock ratio. The CI
``SEACHECK=1`` matrix leg is only viable if instrumentation stays cheap:
``check_regression`` gates ``overhead_x`` at < 2.0.

``python -m benchmarks.seacheck_bench [--json PATH]``
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: lock-heavy, wall-clock-bounded subset: the shared journal (fcntl +
#: thread-lock pairing), the transfer engine (worker pool + per-key
#: locks), and the extent plane (per-map locks + validity journal)
SUBSET = (
    "tests/test_shared_ledger.py",
    "tests/test_transfer.py",
    "tests/test_extents.py",
)

MAX_OVERHEAD_X = 2.0


def _run_subset(instrumented: bool) -> float:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("SEACHECK", None)
    if instrumented:
        env["SEACHECK"] = "1"
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider", *SUBSET],
        cwd=_REPO,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    elapsed = time.perf_counter() - t0
    if proc.returncode != 0:
        label = "SEACHECK=1" if instrumented else "uninstrumented"
        print(proc.stdout + proc.stderr, file=sys.stderr)
        raise SystemExit(f"seacheck_bench: {label} subset run failed")
    return elapsed


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: seacheck_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]
    t_start = time.perf_counter()
    # warm interpreter/page caches so the first leg isn't penalised
    _run_subset(instrumented=False)
    plain_s = _run_subset(instrumented=False)
    instrumented_s = _run_subset(instrumented=True)
    overhead = instrumented_s / plain_s
    print("name,seconds,derived")
    print(f"tier1_subset_plain,{plain_s:.2f},baseline")
    print(f"tier1_subset_seacheck,{instrumented_s:.2f},SEACHECK=1")
    print(f"acceptance_overhead,{overhead:.2f}x,<{MAX_OVERHEAD_X}x_required")
    ok = overhead < MAX_OVERHEAD_X
    if json_path:
        with open(json_path, "w") as f:
            json.dump(
                {
                    "plain_s": round(plain_s, 2),
                    "instrumented_s": round(instrumented_s, 2),
                    "overhead_x": round(overhead, 2),
                    "elapsed_s": round(time.perf_counter() - t_start, 2),
                },
                f,
                indent=2,
            )
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
