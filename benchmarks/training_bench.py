"""Training-I/O benchmark: async sharded checkpointing + device feed.

Three acceptance targets for the training-I/O PR (ISSUE 8), all on
*modelled* speeds so the measurement is hardware-independent and
deterministic:

* **Async checkpoint overlap** — a step loop (modelled compute:
  ``_STEP_S`` per step) checkpoints a multi-leaf ~24 MiB state every
  ``_CKPT_EVERY`` steps through a paced ``open_fn`` (every checkpoint
  byte pays ``bytes / _BW_CKPT``, the modelled burst-buffer write
  bandwidth). Blocking saves with ``checkpoint_workers=1`` — the seed
  path — must cost >= ``_MIN_BLOCKING_OVERHEAD`` x the no-checkpoint
  wall clock, while ``save(..., async_=True)`` with a worker fan-out
  must stay under ``_MAX_ASYNC_OVERHEAD`` x: the same bytes disappear
  behind compute.
* **Device feed** — a real Sea-staged ``DataPipeline`` feeding a step
  loop where each batch pays a modelled host->device put (``_PUT_S``)
  plus compute (``_FEED_STEP_S``). ``device_iter`` double-buffers the
  put of batch N+1 against compute on batch N and must beat the
  unbuffered put-then-compute loop by >= ``_MIN_FEED_SPEEDUP`` x.
* **Sharded write-once** — on a 2-device mesh (host platform devices)
  a state with a sharded and a replicated leaf saves each shard exactly
  once: manifest shard files are unique and total payload bytes stay
  within npy-header slack of the logical state bytes
  (``sharded_write_ratio`` ~ 1.0), and the checkpoint restores
  bit-exact.

``PYTHONPATH=src python -m benchmarks.training_bench [--json PATH]``
prints ``name,seconds,derived`` rows; ``--json`` dumps the derived
ratios for ``benchmarks.check_regression``.
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint.manager import CheckpointManager  # noqa: E402
from repro.core import Sea, SeaConfig, TierSpec  # noqa: E402
from repro.data.pipeline import DataPipeline, write_dataset  # noqa: E402

_STEP_S = 0.025              # modelled fwd/bwd compute per step
_N_STEPS = 16                # steps per arm
_CKPT_EVERY = 4              # checkpoint cadence (saves at 4, 8, 12)
_N_LEAVES = 12               # state leaves (float32, 2 MiB each -> 24 MiB)
_LEAF_ELEMS = 512 * 1024
_BW_CKPT = 125e6             # modelled burst-buffer write bandwidth (B/s)
_ASYNC_WORKERS = 4
_MIN_BLOCKING_OVERHEAD = 2.0
_MAX_ASYNC_OVERHEAD = 1.15

_FEED_STEP_S = 0.02          # modelled compute per batch
_PUT_S = 0.02                # modelled host->device transfer per batch
_MIN_FEED_SPEEDUP = 1.5

_MAX_SHARD_SLACK = 0.01      # payload/logical ratio slack (npy headers)


def _make_sea(workdir: str, tag: str, *, workers: int) -> Sea:
    cfg = SeaConfig(
        mount=os.path.join(workdir, tag, "mount"),
        tiers=[
            TierSpec(name="bb", roots=(os.path.join(workdir, tag, "bb"),)),
            TierSpec(
                name="pfs",
                roots=(os.path.join(workdir, tag, "pfs"),),
                persistent=True,
            ),
        ],
        max_file_size=1 << 23,
        n_procs=1,
        checkpoint_workers=workers,
    )
    return Sea(cfg)


class _PacedFile:
    """Write-paced file proxy: every written byte pays 1/_BW_CKPT s —
    the modelled burst-buffer bandwidth — on the *writing* thread, so
    blocking saves stall the step loop and async saves stall only the
    background writers."""

    def __init__(self, f):
        self._f = f

    def write(self, b):
        if not isinstance(b, str):
            time.sleep(len(b) / _BW_CKPT)
        return self._f.write(b)

    def __getattr__(self, name):
        return getattr(self._f, name)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return self._f.__exit__(*exc)


def _paced_open(fs):
    def open_fn(path, mode="r"):
        f = fs.open(path, mode)
        return _PacedFile(f) if "w" in mode else f

    return open_fn


def _make_state():
    rng = np.random.default_rng(0)
    params = {
        f"w{i:02d}": jnp.asarray(
            rng.standard_normal(_LEAF_ELEMS, dtype=np.float32)
        )
        for i in range(_N_LEAVES)
    }
    return {"params": params, "step": jnp.zeros((), jnp.int32)}


def _run_steps(state, mgr: CheckpointManager | None, async_: bool) -> float:
    t0 = time.perf_counter()
    for step in range(1, _N_STEPS + 1):
        time.sleep(_STEP_S)  # modelled compute
        if mgr is not None and step % _CKPT_EVERY == 0 and step < _N_STEPS:
            mgr.save(step, state, async_=async_)
    if mgr is not None:
        mgr.wait()
    return time.perf_counter() - t0


def bench_checkpoint_overlap(workdir: str):
    state = _make_state()
    t_nockpt = _run_steps(state, None, False)

    sea_b = _make_sea(workdir, "ckpt_blocking", workers=1)
    try:
        mgr = CheckpointManager(sea_b, open_fn=_paced_open(sea_b.fs))
        t_block = _run_steps(state, mgr, False)
    finally:
        sea_b.shutdown()

    sea_a = _make_sea(workdir, "ckpt_async", workers=_ASYNC_WORKERS)
    try:
        mgr = CheckpointManager(sea_a, open_fn=_paced_open(sea_a.fs))
        t_async = _run_steps(state, mgr, True)
        overlap_hits = sea_a.fs.telemetry.snapshot()["ckpt_overlap_hits"]
    finally:
        sea_a.shutdown()

    blocking_x = t_block / t_nockpt
    async_x = t_async / t_nockpt
    rows = [
        {"name": "steps_no_ckpt", "seconds": round(t_nockpt, 3),
         "derived": f"{_N_STEPS}_steps"},
        {"name": "steps_blocking_ckpt", "seconds": round(t_block, 3),
         "derived": f"overhead={blocking_x:.2f}x"},
        {"name": "steps_async_ckpt", "seconds": round(t_async, 3),
         "derived": f"overhead={async_x:.2f}x_overlap_hits={overlap_hits}"},
    ]
    return rows, blocking_x, async_x


def bench_device_feed(workdir: str):
    sea = _make_sea(workdir, "feed", workers=2)
    try:
        write_dataset(
            sea, "bench", n_shards=2, tokens_per_shard=8192, vocab_size=211
        )

        def paced_put(batch):
            time.sleep(_PUT_S)  # modelled host->device transfer
            return batch

        with DataPipeline(
            sea, "bench", batch_size=4, seq_len=128, evict_consumed=False
        ) as pipe:
            t0 = time.perf_counter()
            n_unbuf = 0
            for batch in pipe:
                paced_put(batch)
                time.sleep(_FEED_STEP_S)
                n_unbuf += 1
            t_unbuf = time.perf_counter() - t0

        with DataPipeline(
            sea, "bench", batch_size=4, seq_len=128, evict_consumed=False
        ) as pipe:
            t0 = time.perf_counter()
            n_buf = 0
            for _batch in pipe.device_iter(depth=2, put_fn=paced_put):
                time.sleep(_FEED_STEP_S)
                n_buf += 1
            t_buf = time.perf_counter() - t0
    finally:
        sea.shutdown()

    assert n_buf == n_unbuf > 0, (n_buf, n_unbuf)
    speedup = t_unbuf / t_buf
    rows = [
        {"name": "feed_unbuffered", "seconds": round(t_unbuf, 3),
         "derived": f"{n_unbuf}_batches"},
        {"name": "feed_double_buffered", "seconds": round(t_buf, 3),
         "derived": f"speedup={speedup:.2f}x"},
    ]
    return rows, speedup


def bench_sharded_write_once(workdir: str):
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    devices = jax.devices()[:2]
    mesh = Mesh(np.array(devices), ("d",))
    rng = np.random.default_rng(1)
    w = rng.standard_normal((len(devices) * 128, 4096), dtype=np.float32)
    b = rng.standard_normal(4096, dtype=np.float32)
    state = {
        "w": jax.device_put(w, NamedSharding(mesh, PartitionSpec("d"))),
        "b": jax.device_put(b, NamedSharding(mesh, PartitionSpec())),
    }
    logical = w.nbytes + b.nbytes

    sea = _make_sea(workdir, "sharded", workers=_ASYNC_WORKERS)
    try:
        t0 = time.perf_counter()
        mgr = CheckpointManager(sea)
        mgr.save(1, state)
        t_save = time.perf_counter() - t0
        with sea.fs.open(
            os.path.join(mgr.root, "step_00000001", "manifest.json")
        ) as f:
            manifest = json.load(f)
        files = [
            ent["file"]
            for meta in manifest["leaves"].values()
            for ent in meta["shards"]
        ]
        payload = sum(
            ent["bytes"]
            for meta in manifest["leaves"].values()
            for ent in meta["shards"]
        )
        restored = mgr.restore(
            1, {"w": np.zeros_like(w), "b": np.zeros_like(b)}
        )
    finally:
        sea.shutdown()

    unique = len(files) == len(set(files))
    ratio = payload / logical
    roundtrip_ok = bool(
        np.array_equal(np.asarray(restored["w"]), w)
        and np.array_equal(np.asarray(restored["b"]), b)
    )
    rows = [
        {"name": "sharded_save", "seconds": round(t_save, 3),
         "derived": (
             f"devices={len(devices)}_files={len(files)}"
             f"_ratio={ratio:.4f}"
         )},
    ]
    return rows, unique, ratio, roundtrip_ok


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: training_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]

    t_start = time.perf_counter()
    workdir = tempfile.mkdtemp(prefix="sea_training_bench_")
    try:
        print("name,seconds,derived")
        ckpt_rows, blocking_x, async_x = bench_checkpoint_overlap(workdir)
        feed_rows, feed_speedup = bench_device_feed(workdir)
        shard_rows, unique, ratio, roundtrip_ok = bench_sharded_write_once(
            workdir
        )
        rows = ckpt_rows + feed_rows + shard_rows
        for row in rows:
            print(f"{row['name']},{row['seconds']},{row['derived']}")
        print(
            f"acceptance_blocking_overhead,{blocking_x:.2f},"
            f">={_MIN_BLOCKING_OVERHEAD}x_required"
        )
        print(
            f"acceptance_async_overhead,{async_x:.2f},"
            f"<={_MAX_ASYNC_OVERHEAD}x_required"
        )
        print(
            f"acceptance_feed_speedup,{feed_speedup:.2f},"
            f">={_MIN_FEED_SPEEDUP}x_required"
        )
        print(
            f"acceptance_sharded_write_once,"
            f"{1.0 if unique and roundtrip_ok else 0.0},"
            f"ratio={ratio:.4f}"
        )
        ok = (
            blocking_x >= _MIN_BLOCKING_OVERHEAD
            and async_x <= _MAX_ASYNC_OVERHEAD
            and feed_speedup >= _MIN_FEED_SPEEDUP
            and unique
            and roundtrip_ok
            and 1.0 <= ratio <= 1.0 + _MAX_SHARD_SLACK
        )
        if json_path:
            with open(json_path, "w") as f:
                json.dump(
                    {
                        "rows": rows,
                        "blocking_overhead_x": round(blocking_x, 2),
                        "async_overhead_x": round(async_x, 2),
                        "feed_speedup": round(feed_speedup, 2),
                        "sharded_unique_files": unique,
                        "sharded_write_ratio": round(ratio, 4),
                        "sharded_roundtrip_ok": roundtrip_ok,
                        "elapsed_s": round(time.perf_counter() - t_start, 2),
                    },
                    f,
                    indent=2,
                )
        raise SystemExit(0 if ok else 1)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
