"""Data-plane benchmark: the TransferEngine vs the seed's shutil copies.

Two acceptance targets for the transfer-engine PR:

* **Large-file throughput** — the engine's chunked ``copy_file_range``
  loop must move a large file at least as fast as a bare
  ``shutil.copyfile`` (the seed's whole-file copy). Both bottom out at
  the same in-kernel copy syscalls, so the pass condition is parity:
  median per-round ratio >= 0.85 after de-biasing (alternating
  measurement order, fresh destination files for both sides) — a
  genuine chunk-loop regression (e.g. a too-small chunk size, or the
  buffered fallback engaging when zero-copy is available) measures
  0.6-0.75; a ratio above 1 is noise in the engine's favour, not a
  real win.
* **Concurrent overlap** — staging N independent files through the
  engine's bounded worker pool must beat the seed's serial copy loop by
  > 1.5x when per-chunk device latency dominates (the chunk hook injects
  a fixed per-chunk stall, modelling a high-latency device/network the
  way the openPMD/ADIOS2 streaming pipelines overlap I/O).

``PYTHONPATH=src python -m benchmarks.transfer_bench [--json PATH]``
prints the same ``name,us_per_call,derived`` CSV as the other benches;
``--json`` dumps rows + derived ratios for ``benchmarks.check_regression``.
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

from repro.core import SeaConfig, TierSpec, TransferEngine

_LARGE_BYTES = 64 << 20      # one large artifact (a checkpoint shard)
_LARGE_ROUNDS = 16           # best-of, alternating measurement order
_MIN_LARGE_RATIO = 0.85      # parity gate (see module docstring): a real
                             # chunk-loop regression measures ~0.6-0.75;
                             # scheduler drift on busy runners is ~±0.1
_OVERLAP_FILES = 8
_OVERLAP_BYTES = 4 << 20
_OVERLAP_CHUNK = 1 << 20
_OVERLAP_STALL_S = 0.005     # injected per-chunk device latency — large
                             # enough that the stall (not the memcpy)
                             # dominates, so the pool's overlap is what
                             # the measurement sees even on 2-core runners


def _config(workdir: str, workers: int, chunk: int | None = None) -> SeaConfig:
    kw = {"transfer_chunk_bytes": chunk} if chunk else {}
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(name="fast", roots=(os.path.join(workdir, "fast"),)),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        transfer_workers=workers,
        **kw,
    )


def _make_file(path: str, nbytes: int) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(os.urandom(1 << 20) * (nbytes >> 20) or os.urandom(nbytes))
    return path


def bench_large_file(workdir: str) -> tuple[list[dict], float]:
    src = _make_file(os.path.join(workdir, "src", "big.bin"), _LARGE_BYTES)
    engine = TransferEngine(_config(workdir, workers=1))
    dst_dir = os.path.join(workdir, "dst")
    os.makedirs(dst_dir, exist_ok=True)
    seq = [0]

    def timed(fn) -> float:
        # a FRESH destination every round for BOTH sides: rewriting an
        # existing file reuses already-allocated pages (tmpfs/page
        # cache), which flattered whichever side kept its dst path
        seq[0] += 1
        dst = os.path.join(dst_dir, f"out_{seq[0]}.bin")
        t0 = time.perf_counter()
        fn(dst)
        dt = time.perf_counter() - t0
        if seq[0] <= 2:  # verify both copiers' output once (warmup round)
            with open(dst, "rb") as a, open(src, "rb") as b:
                assert a.read(1 << 16) == b.read(1 << 16)  # sanity
        os.unlink(dst)
        return dt

    copy_shutil = lambda dst: shutil.copyfile(src, dst)  # noqa: E731
    copy_engine = lambda dst: engine.copy(src, dst)  # noqa: E731
    timed(copy_shutil), timed(copy_engine)  # warmup (page in the source)
    ratios: list[float] = []
    shutil_times: list[float] = []
    engine_times: list[float] = []
    for i in range(_LARGE_ROUNDS):
        # alternate who goes first inside a round (the first copy of a
        # pair consistently measures faster — frequency/cache effects)
        # and take the MEDIAN of per-round ratios: robust to the load
        # spikes of shared CI runners, which best-of is not
        if i % 2 == 0:
            ts, te = timed(copy_shutil), timed(copy_engine)
        else:
            te, ts = timed(copy_engine), timed(copy_shutil)
        shutil_times.append(ts)
        engine_times.append(te)
        ratios.append(ts / te)
    s_shutil, s_engine = min(shutil_times), min(engine_times)
    ratio = sorted(ratios)[len(ratios) // 2]

    mbps = lambda s: _LARGE_BYTES / s / 1e6  # noqa: E731
    rows = [
        {
            "name": f"copy_shutil_{_LARGE_BYTES >> 20}MiB",
            "us_per_call": round(s_shutil * 1e6, 2),
            "derived": f"{mbps(s_shutil):.0f}MB/s",
        },
        {
            "name": f"copy_engine_{_LARGE_BYTES >> 20}MiB",
            "us_per_call": round(s_engine * 1e6, 2),
            "derived": f"{mbps(s_engine):.0f}MB/s ratio={ratio:.2f}x",
        },
    ]
    return rows, ratio


def bench_overlap(workdir: str) -> tuple[list[dict], float]:
    """Serial vs pooled staging of independent files with per-chunk
    latency injected through the engine's chunk hook."""
    srcs = [
        _make_file(os.path.join(workdir, "pfs", f"in_{i}.bin"), _OVERLAP_BYTES)
        for i in range(_OVERLAP_FILES)
    ]

    def run(workers: int) -> float:
        engine = TransferEngine(
            _config(workdir, workers=workers, chunk=_OVERLAP_CHUNK)
        )
        engine.chunk_hook = lambda *_a: time.sleep(_OVERLAP_STALL_S)
        dsts = [
            os.path.join(workdir, f"stage{workers}", f"out_{i}.bin")
            for i in range(_OVERLAP_FILES)
        ]
        for d in dsts:
            os.makedirs(os.path.dirname(d), exist_ok=True)
        t0 = time.perf_counter()
        if workers == 1:
            for s, d in zip(srcs, dsts):
                engine.copy(s, d)
        else:
            futs = [engine.submit_copy(s, d) for s, d in zip(srcs, dsts)]
            for f in futs:
                f.result()
        dt = time.perf_counter() - t0
        engine.close()
        return dt

    s_serial = run(1)
    s_pool = run(4)
    speedup = s_serial / s_pool
    rows = [
        {
            "name": f"prefetch_serial_{_OVERLAP_FILES}x{_OVERLAP_BYTES >> 20}MiB",
            "us_per_call": round(s_serial * 1e6, 2),
            "derived": "",
        },
        {
            "name": f"prefetch_pool4_{_OVERLAP_FILES}x{_OVERLAP_BYTES >> 20}MiB",
            "us_per_call": round(s_pool * 1e6, 2),
            "derived": f"overlap={speedup:.2f}x",
        },
    ]
    return rows, speedup


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        if argv.index("--json") + 1 >= len(argv):
            print("usage: transfer_bench [--json PATH]")
            raise SystemExit(2)
        json_path = argv[argv.index("--json") + 1]

    t_start = time.perf_counter()
    workdir = tempfile.mkdtemp(prefix="sea_transfer_bench_")
    try:
        print("name,us_per_call,derived")
        large_rows, ratio = bench_large_file(workdir)
        overlap_rows, speedup = bench_overlap(workdir)
        rows = large_rows + overlap_rows
        for row in rows:
            print(f"{row['name']},{row['us_per_call']},{row['derived']}")
        print(f"acceptance_large_ratio,{ratio:.2f},>={_MIN_LARGE_RATIO}x_required")
        print(f"acceptance_overlap_speedup,{speedup:.2f},>1.5x_required")
        ok = ratio >= _MIN_LARGE_RATIO and speedup > 1.5
        if json_path:
            with open(json_path, "w") as f:
                json.dump(
                    {
                        "rows": rows,
                        "large_ratio": round(ratio, 2),
                        "overlap_speedup": round(speedup, 2),
                        "elapsed_s": round(
                            time.perf_counter() - t_start, 2
                        ),
                    },
                    f,
                    indent=2,
                )
        raise SystemExit(0 if ok else 1)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
