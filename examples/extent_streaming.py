"""Streaming a file TWICE the cache tier's capacity through the extent
plane.

Whole-file placement cannot serve this workload hot at all: the file
never fits, so every read falls through to the slow base tier. With
``extent_map=True`` the cache holds a *sliding window* of 4 MiB blocks —
each block is faulted once on first touch, served hot for the rest of
its lifetime, and punched back to a hole when the LRU needs room — so
the scan streams through a tier half its size without ever
over-committing the capacity ledger.

The demo seeds a 32 MiB input on the (modelled) PFS, mounts a 16 MiB
cache in front of it, then:

  1. block-scans the whole file sequentially,
  2. random-accesses a handful of offsets (only the touched blocks
     fault — no whole-file stage),

and prints the extent telemetry counters plus the ledger-vs-walk
accounting after each phase.

    PYTHONPATH=src python examples/extent_streaming.py
"""

import os
import random
import shutil
import tempfile

from repro.core import SeaConfig, SeaFS, TierSpec

FILE_BYTES = 32 << 20    # the cold input: 2x the cache tier
EXTENT_BYTES = 4 << 20   # 8 blocks per file
CACHE_CAP = 16 << 20     # the tier the file does NOT fit in
CHUNK = 1 << 20          # application read granularity


def make_config(workdir: str) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="fast",
                roots=(os.path.join(workdir, "fast"),),
                capacity=CACHE_CAP,
            ),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        max_file_size=FILE_BYTES,
        extent_map=True,          # key -> extent map on the cache tiers
        extent_bytes=EXTENT_BYTES,
        lru_evict=True,           # punch cold extents when the tier is full
    )


def report(fs: SeaFS, phase: str) -> None:
    snap = fs.telemetry.snapshot()
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    used, walk = tier.used_bytes(root), tier.scan_used_bytes(root)
    print(f"\n-- {phase} --")
    for k in (
        "extent_hits",
        "extent_misses",
        "extents_staged",
        "extents_punched",
        "extent_promotions",
    ):
        print(f"  {k:20s} {snap[k]}")
    print(
        f"  cache used: ledger={used} walk={walk} cap={CACHE_CAP} "
        f"({'OK' if used == walk <= CACHE_CAP else 'DRIFT'})"
    )


def main() -> None:
    workdir = tempfile.mkdtemp(prefix="sea_extent_demo_")
    try:
        # a cold input that already lives on the base tier (a PFS dataset)
        pfs = os.path.join(workdir, "pfs")
        os.makedirs(pfs)
        data = os.urandom(FILE_BYTES)
        with open(os.path.join(pfs, "dataset.bin"), "wb") as f:
            f.write(data)

        fs = SeaFS(make_config(workdir))
        p = os.path.join(fs.mount, "dataset.bin")
        print(
            f"file={FILE_BYTES >> 20}MiB  cache={CACHE_CAP >> 20}MiB  "
            f"extent={EXTENT_BYTES >> 20}MiB "
            f"({FILE_BYTES // EXTENT_BYTES} blocks)"
        )

        # 1. sequential block scan: every block faults once, then serves
        #    hot; the LRU punches the oldest blocks to stay under cap
        seen = 0
        with fs.open(p, "rb") as f:
            while chunk := f.read(CHUNK):
                assert chunk == data[seen : seen + len(chunk)]
                seen += len(chunk)
        assert seen == FILE_BYTES
        report(fs, f"sequential scan ({seen >> 20} MiB verified)")

        # 2. random access: only the touched blocks fault — a punched
        #    region simply re-faults its one extent, never the whole file
        rng = random.Random(7)
        for _ in range(6):
            off = rng.randrange(FILE_BYTES - CHUNK)
            with fs.open(p, "rb") as f:
                f.seek(off)
                assert f.read(CHUNK) == data[off : off + CHUNK]
        report(fs, "random access (6 x 1 MiB)")

        fs.prefetcher.stop()
        fs.transfer.close()
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
