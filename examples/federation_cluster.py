"""Two Sea "nodes" federating their caches over one shared base tier.

Demonstrates `SeaConfig(federation=True)`: each node (a real forked
process here, standing in for a cluster node) has its *own* cache root
but shares the base tier. Node A writes a working set and publishes the
cache locations in the shared registry
(`<base>/.sea_ledger/federation/`); node B's reads then resolve to A's
cache and pull peer-to-peer — throttled under the `"peer->*"` bandwidth
cap — instead of hitting the base filesystem. The registry is advisory:
kill node A and B's reads silently fall back to the base tier.

    PYTHONPATH=src python examples/federation_cluster.py
"""

import multiprocessing as mp
import os
import shutil
import tempfile

from repro.core import SeaConfig, SeaFS, TierSpec

N_FILES = 8
F = 1 << 18  # 256 KiB working-set files

_ctx = mp.get_context("fork")


def make_config(workdir: str, node: str) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            # per-node cache: every node gets its own root...
            TierSpec(
                name="cache",
                roots=(os.path.join(workdir, f"cache_{node}"),),
            ),
            # ...but the persistent base tier is shared cluster-wide
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        max_file_size=F,
        shared_ledger=True,          # the registry extends its machinery
        federation=True,
        federation_node=node,
        transfer_bandwidth_caps={"peer->*": 512e6},
    )


def node_a(workdir: str, staged_ev, done_ev) -> None:
    fs = SeaFS(make_config(workdir, "node-a"))
    for i in range(N_FILES):
        p = os.path.join(fs.mount, f"shard_{i:03d}.npy")
        with fs.open(p, "wb") as f:
            f.write(os.urandom(F))  # committed to cache_A + published
    print(f"node-a (pid {os.getpid()}): staged {N_FILES} shards, "
          f"holders={sorted(fs.federation.holders('shard_000.npy'))}")
    staged_ev.set()
    done_ev.wait(timeout=60)  # stay alive: liveness = heartbeat + pid
    fs.transfer.close()


def node_b(workdir: str) -> None:
    fs = SeaFS(make_config(workdir, "node-b"))
    for i in range(N_FILES):
        p = os.path.join(fs.mount, f"shard_{i:03d}.npy")
        with fs.open(p, "rb") as f:
            assert len(f.read()) == F
    snap = fs.telemetry.snapshot()
    print(f"node-b (pid {os.getpid()}): peer_hits={snap['peer_hits']} "
          f"peer_pull_bytes={snap['peer_pull_bytes']} "
          f"peer_fallbacks={snap['peer_fallbacks']}")
    assert snap["peer_hits"] == N_FILES
    fs.federation.retire()  # clean exit: unpublish + leave the cluster
    fs.transfer.close()


def main() -> None:
    workdir = tempfile.mkdtemp(prefix="sea_federation_")
    try:
        staged_ev, done_ev = _ctx.Event(), _ctx.Event()
        a = _ctx.Process(target=node_a, args=(workdir, staged_ev, done_ev))
        a.start()
        if not staged_ev.wait(timeout=60):
            raise RuntimeError("node-a failed to stage")
        node_b(workdir)  # every read arrives via a peer pull from node A
        done_ev.set()
        a.join(timeout=60)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
