"""Two worker processes sharing one capped Sea hierarchy.

Demonstrates `SeaConfig(shared_ledger=True)`: both workers mount the same
tiers, the cross-process ledger keeps the capped tmpfs root from being
jointly over-committed, and the flusher leader election leaves exactly one
flush-and-evict daemon (the second worker spools its close events to it).

    PYTHONPATH=src python examples/multiproc_workers.py
"""

import multiprocessing as mp
import os
import shutil
import tempfile

from repro.core import Sea, SeaConfig, TierSpec
from repro.core.ledger import LEDGER_DIRNAME
from repro.core.telemetry import load_aggregate

F = 1 << 16  # 64 KiB worst-case file size


def make_config(workdir: str) -> SeaConfig:
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="tmpfs",
                roots=(os.path.join(workdir, "fast"),),
                capacity=8 * F,  # tiny on purpose: forces spill under load
            ),
            TierSpec(
                name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True
            ),
        ],
        max_file_size=F,
        n_procs=2,
        shared_ledger=True,       # cross-process ledger + flusher election
        leader_heartbeat_s=0.25,
        flushlist=("results/*",),  # materialize final outputs to the base
        evictlist=("results/*",),
    )


def worker(workdir: str, idx: int) -> None:
    sea = Sea(make_config(workdir)).start()
    role = "leader" if sea.flusher.is_leader else "follower"
    print(f"worker {idx} (pid {os.getpid()}): flusher {role}")
    for j in range(8):
        path = os.path.join(sea.fs.mount, f"results/w{idx}_{j}.out")
        sea.fs.write_bytes(path, os.urandom(F // 2))
    sea.shutdown()  # drain: follower hands leftovers to the leader's spool


def main() -> None:
    workdir = tempfile.mkdtemp(prefix="sea_multiproc_demo_")
    try:
        ctx = mp.get_context("fork")
        procs = [ctx.Process(target=worker, args=(workdir, i)) for i in range(2)]
        for p in procs:
            p.start()
        for p in procs:
            p.join()
        results = sorted(os.listdir(os.path.join(workdir, "pfs", "results")))
        print(f"materialized on the base tier: {len(results)} files")
        stats = load_aggregate(
            os.path.join(workdir, "pfs", LEDGER_DIRNAME, "telemetry")
        )
        print(
            f"aggregate over pids {stats['pids']}: "
            f"{stats['flushed_files']} flushed, "
            f"{stats['tiers'].get('tmpfs', {}).get('bytes_written', 0):.0f} "
            "bytes through tmpfs"
        )
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
