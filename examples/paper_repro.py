"""Reproduce the paper's headline results (Figs. 2-3) via the calibrated
cluster simulator + analytic model (Eqs. 1-11), printed as a table.

    PYTHONPATH=src python examples/paper_repro.py
"""
from repro.core.model import ClusterSpec, Workload, sea_bounds
from repro.core.simulator import Simulator

PAPER = ClusterSpec()

def run(cl, w, system="sea"):
    return Simulator(cl, w, system).run().makespan

print(f"{'experiment':34s} {'lustre':>8s} {'sea':>8s} {'speedup':>8s}  paper")
rows = [
    ("base (5 nodes, 6 procs, 10 iters)", PAPER, Workload(n=10), "~2.4x"),
    ("1 node", PAPER.with_(c=1), Workload(n=10), "~1.0x"),
    ("1 iteration", PAPER, Workload(n=1), "<=1.0x"),
    ("32 procs, 5 iters", PAPER.with_(p=32), Workload(n=5), "~3.0x"),
    ("1 disk, 5 iters", PAPER.with_(g=1), Workload(n=5), "<1.0x"),
]
for name, cl, w, paper in rows:
    tl, ts = run(cl, w, "lustre"), run(cl, w, "sea")
    print(f"{name:34s} {tl:7.0f}s {ts:7.0f}s {tl/ts:7.2f}x  {paper}")

cl, w = PAPER.with_(p=64), Workload(n=5)
tl = run(cl, w, "lustre"); ts = run(cl, w, "sea"); tf = run(cl, w, "sea-flushall")
print(f"\nFig 3 (64 procs): flush-all/in-memory = {tf/ts:.2f}x (paper 3.5x), "
      f"flush-all/lustre = {tf/tl:.2f}x (paper 1.3x)")
lo, hi = sea_bounds(w, cl)
print(f"model bounds for Sea: [{lo:.0f}s, {hi:.0f}s], simulated {ts:.0f}s")
