"""Quickstart: the Sea data-placement library in 60 seconds.

1. Declare a tiered hierarchy (tmpfs -> disk -> 'PFS').
2. Run an UNMODIFIED numpy pipeline under SeaMount interception.
3. Watch files land on the fast tier, finals flush to the PFS.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.core import Sea, SeaConfig, SeaMount, TierSpec

workdir = tempfile.mkdtemp(prefix="sea_quickstart_")
cfg = SeaConfig(
    mount=os.path.join(workdir, "mount"),
    tiers=[
        TierSpec(name="tmpfs", roots=("/dev/shm/sea_quickstart",)),
        TierSpec(name="disk", roots=(os.path.join(workdir, "disk"),)),
        TierSpec(name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True),
    ],
    max_file_size=1 << 22,
    n_procs=1,
    flushlist=("results/*",),            # finals -> long-term storage
    evictlist=("results/*", "*.tmp"),    # ... and drop from cache after
)

with Sea(cfg) as sea:
    mount = sea.fs.mount
    with SeaMount(sea.fs):               # <- LD_PRELOAD analogue
        # unmodified application code: plain numpy + open()
        data = np.arange(1 << 18, dtype=np.int32)
        np.save(os.path.join(mount, "input.npy"), data)            # cache tier
        for i in range(3):
            data = np.load(os.path.join(mount, "input.npy" if i == 0
                                        else f"iter_{i - 1}.npy")) + 1
            np.save(os.path.join(mount, f"iter_{i}.npy"), data)    # intermediates
        np.save(os.path.join(mount, "results/final.npy"), data)    # final output
    print("input lives on   :", sea.fs.where(os.path.join(mount, "input.npy")))
    print("intermediate on  :", sea.fs.where(os.path.join(mount, "iter_1.npy")))

# after shutdown (final flush): results are on the persistent tier
final = os.path.join(workdir, "pfs", "results", "final.npy")
print("final on PFS      :", os.path.exists(final))
print("final[:3]         :", np.load(final)[:3], "(= input + 3)")
print("telemetry         :", {k: v for k, v in sea.fs.telemetry.snapshot().items()
                              if k in ("flushed_files", "evicted_files", "redirect_hits")})
