"""Batched serving: prefill + KV/state-cache decode on a reduced config of
any assigned architecture (try rwkv6-7b for state-space decode, or
jamba-v0.1-52b for the hybrid cache).

    PYTHONPATH=src python examples/serve_batched.py [arch]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "jamba-v0.1-52b"
    main(["--arch", arch, "--batch", "4", "--prompt-len", "32",
          "--new-tokens", "16"])
