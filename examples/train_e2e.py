"""End-to-end training: Sea-staged data, pjit train loop, burst-buffer
checkpoints, crash-safe resume. Trains a ~20M-param LM for 200 steps
(pass --steps/--params-m to scale up to the ~100M configuration).

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--params-m 20]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "small", "--params-m", "20", "--steps", "200",
        "--batch", "4", "--seq", "256", "--ckpt-every", "50",
        "--workdir", "/tmp/sea_train_e2e",
    ]
    main(argv)
