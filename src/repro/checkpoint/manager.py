"""Burst-buffer checkpointing through Sea — the paper's pattern applied to
training state.

Saves land on the *fastest tier with space* (host tmpfs — the burst
buffer) and the Sea flush daemon materializes the checkpoint to the
persistent tier asynchronously (MOVE mode: flush + evict, keeping the
burst buffer free for the next save). This is exactly the checkpoint
workflow that motivated HPC burst buffers (paper §2.1) and Sea's
copy/move semantics (§3.3).

Async saves (``save(..., async_=True)``) cost the step loop only the
device->host snapshot: a ``SaveHandle`` future returns immediately while
a coordinator thread fans the per-leaf .npy streams through the shared
TransferEngine worker pool (at most ``checkpoint_workers`` in flight),
then commits the manifest and finally the ``_COMPLETE`` marker. Saves
are serialized: a new ``save`` first waits for (and surfaces the failure
of) the previous in-flight one. On multi-host meshes each process writes
only its addressable ``replica_id == 0`` shards; manifest, marker and GC
belong to process 0.

Crash safety: the ``_COMPLETE`` marker is committed strictly after every
leaf file and the manifest; restore only considers steps whose marker
AND manifest files verify (crc32). A crash anywhere before the marker
leaves no restorable partial — the un-markered directory is reaped by
the next save's GC. ``restore_latest`` reads through the hierarchy, so a
checkpoint still sitting in the burst buffer restores at tmpfs speed —
node-local restart after preemption costs seconds, not a PFS read.

Elastic restore: pass ``shardings`` built from a *different* mesh and the
leaves are device_put against it (tests/test_checkpoint.py exercises a
reshard).
"""

from __future__ import annotations

import json
import logging
import os
import re
import threading
import time
from dataclasses import dataclass, field
from typing import Callable

from repro.checkpoint import serialization as ser
from repro.core import Sea

_STEP_RE = re.compile(r"^step_(\d+)$")
_MARKER = "_COMPLETE"

log = logging.getLogger("repro.checkpoint")


class SaveHandle:
    """Future for an in-flight checkpoint save. ``result()`` blocks until
    the background writer committed the ``_COMPLETE`` marker (returning
    the step directory) or re-raises its failure."""

    def __init__(self, step: int, directory: str):
        self.step = step
        self.directory = directory
        self._done = threading.Event()
        self._exc: BaseException | None = None
        self._waiters = 0
        self._consumed = False  # outcome observed via result()
        self._lock = threading.Lock()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> str:
        with self._lock:
            self._waiters += 1
        try:
            if not self._done.wait(timeout):
                raise TimeoutError(
                    f"checkpoint save of step {self.step} still in flight"
                )
        finally:
            with self._lock:
                self._waiters -= 1
        with self._lock:
            self._consumed = True
        if self._exc is not None:
            raise self._exc
        return self.directory

    def _finish(self, exc: BaseException | None) -> bool:
        """Mark complete; True when nobody sat blocked in ``result()``
        (the write was fully hidden behind compute)."""
        self._exc = exc
        with self._lock:
            overlapped = self._waiters == 0
            if not overlapped:
                # a blocked result() caller is about to observe (and for a
                # failure, re-raise) this outcome: mark it consumed BEFORE
                # releasing the waiter, so _unsettled() can never pop the
                # handle in the window before the waiter returns and
                # re-surface the same failure to a later save()/wait()
                self._consumed = True
        self._done.set()
        return overlapped


@dataclass
class CheckpointManager:
    sea: Sea
    subdir: str = "checkpoints"
    keep_n: int = 3
    #: test/bench hook: substitute for ``sea.fs.open`` on every
    #: checkpoint byte (fault injection, modelled tier pacing)
    open_fn: Callable | None = None
    _lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False
    )
    _inflight: SaveHandle | None = field(default=None, repr=False)
    #: last background save that failed with nobody blocked in result():
    #: the next save()/wait() surfaces it instead of letting it vanish
    _failed: SaveHandle | None = field(default=None, repr=False)

    @property
    def root(self) -> str:
        return os.path.join(self.sea.fs.mount, self.subdir)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _open(self, path: str, mode: str = "r"):
        fn = self.open_fn or self.sea.fs.open
        return fn(path, mode)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, async_: bool = False,
             blocking_flush: bool = False):
        """Write the state to the burst buffer; flush happens async.

        Blocking (default): returns the step directory once the marker is
        committed (leaf writes still fan across the engine pool).
        ``async_=True``: returns a :class:`SaveHandle` as soon as the
        device->host snapshot is taken; the write proceeds behind
        compute. ``blocking_flush=True`` additionally drains the flusher
        (implies a blocking save)."""
        t0 = time.monotonic()
        prev = self._unsettled()
        if prev is not None:
            prev.result()  # serialize saves; surface a failed background write
        d = self._step_dir(step)
        self._clear_partial(d)
        manifest, jobs = ser.snapshot_tree(state)  # device -> host copy
        handle = SaveHandle(step, d)
        if async_ and not blocking_flush:
            with self._lock:
                self._inflight = handle
            threading.Thread(
                target=self._write, args=(handle, d, manifest, jobs, True),
                name=f"sea-ckpt-save-{step}", daemon=True,
            ).start()
            self.sea.fs.telemetry.record_ckpt_save(time.monotonic() - t0)
            return handle
        self._write(handle, d, manifest, jobs, False)
        self.sea.fs.telemetry.record_ckpt_save(time.monotonic() - t0)
        handle.result()  # re-raise a write failure
        if blocking_flush:
            self.sea.flusher.drain()
        return d

    def wait(self) -> None:
        """Block until any in-flight async save committed (re-raising its
        failure). Call before shutdown so ``drain()`` sees every leaf."""
        h = self._unsettled()
        if h is not None:
            h.result()

    def _unsettled(self) -> SaveHandle | None:
        """The handle the caller must settle before proceeding: the save
        still in flight, or — when the background writer already finished
        AND failed AND nobody observed it — the failed handle. Without the
        second case a fast-failing async save whose thread cleared
        ``_inflight`` first would silently swallow its error."""
        with self._lock:
            prev = self._inflight
            if prev is not None:
                return prev
            prev, self._failed = self._failed, None
        if prev is not None and prev._consumed:
            prev = None  # someone already saw (and re-raised) the failure
        return prev

    def _clear_partial(self, d: str) -> None:
        """Re-saving a step must not mix old and new leaves under a stale
        marker: drop the marker first (restore ignores the dir from here
        on), then any leftover files."""
        fs = self.sea.fs
        try:
            names = fs.listdir(d)
        except FileNotFoundError:
            return
        if _MARKER in names:
            fs.remove(os.path.join(d, _MARKER))
        for name in names:
            if name != _MARKER:
                try:
                    fs.remove(os.path.join(d, name))
                except FileNotFoundError:
                    pass

    def _write(self, handle: SaveHandle, d: str, manifest: dict, jobs,
               count_overlap: bool) -> None:
        """Coordinator for one save: leaf streams fan through the engine
        pool (bounded by ``checkpoint_workers``), then manifest, then the
        marker — strictly last, so no crash window exposes a restorable
        partial."""
        fs = self.sea.fs
        exc: BaseException | None = None
        try:
            engine = getattr(fs, "transfer", None)
            workers = max(1, getattr(fs.config, "checkpoint_workers", 2))
            if engine is not None and workers > 1 and len(jobs) > 1:
                sem = threading.BoundedSemaphore(workers)
                futs = []
                for fname, arr, entry in jobs:
                    sem.acquire()
                    futs.append(
                        engine.submit(self._write_leaf, d, fname, arr,
                                      entry, sem)
                    )
                for f in futs:
                    f.result()
            else:
                for fname, arr, entry in jobs:
                    self._write_leaf(d, fname, arr, entry, None)
            if ser.process_index() == 0:
                ser.write_manifest(manifest, d, open_fn=self._open)
                with self._open(os.path.join(d, _MARKER), "w") as f:
                    f.write(json.dumps({"step": handle.step}))
                self._gc()
        except BaseException as e:  # surfaced via handle.result()
            exc = e
        overlapped = handle._finish(exc)
        with self._lock:
            if self._inflight is handle:
                self._inflight = None
            if exc is not None and not handle._consumed:
                # failed with nobody blocked in result(): park it so the
                # next save()/wait() surfaces the error. A waiter that WAS
                # blocked has _consumed set by _finish, so the failure is
                # never delivered twice.
                self._failed = handle
        if exc is None and count_overlap and overlapped:
            fs.telemetry.record_ckpt_overlap_hit()

    def _write_leaf(self, d: str, fname: str, arr, entry: dict,
                    sem: threading.Semaphore | None) -> None:
        try:
            crc, n = ser.write_leaf(
                os.path.join(d, fname), arr, open_fn=self._open
            )
            entry["crc32"], entry["bytes"] = crc, n
            self.sea.fs.telemetry.record_ckpt_save(0.0, nbytes=n)
        finally:
            if sem is not None:
                sem.release()

    # ------------------------------------------------------------------ list
    def available_steps(self) -> list[int]:
        fs = self.sea.fs
        try:
            names = fs.listdir(self.root)
        except FileNotFoundError:
            return []
        steps = []
        for n in names:
            m = _STEP_RE.match(n)
            if not m:
                continue
            if fs.exists(os.path.join(self.root, n, _MARKER)):
                steps.append(int(m.group(1)))
        return sorted(steps)

    # ------------------------------------------------------------------ load
    def restore(self, step: int, template, shardings=None):
        d = self._step_dir(step)
        fs = self.sea.fs
        return ser.load_tree(
            template, d, open_fn=self._open, shardings=shardings,
            pool=getattr(fs, "transfer", None),
        )

    def restore_latest(self, template, shardings=None):
        """Returns (step, state) or (None, None) if nothing checkpointed.
        Corrupt/partial steps are discarded loudly: counted in telemetry
        (``ckpt_restore_fallbacks``) and logged, so a flaky tier shows up
        as itself rather than as silent slowness."""
        for step in reversed(self.available_steps()):
            try:
                return step, self.restore(step, template, shardings)
            except (IOError, ValueError, FileNotFoundError, KeyError) as e:
                self.sea.fs.telemetry.record_ckpt_restore_fallback()
                log.warning(
                    "discarding checkpoint step %d (%s: %s); "
                    "falling back to an older step",
                    step, type(e).__name__, e,
                )
                continue
        return None, None

    # ------------------------------------------------------------------ gc
    def _gc(self) -> None:
        """Prune beyond ``keep_n`` AND reap crashed partials. The seed
        leaked both ways: un-markered step dirs are invisible to
        ``available_steps`` so they were never cleaned, and pruned steps
        left their empty ``step_XXXXXXXX`` directory behind."""
        if ser.process_index() != 0:
            return
        fs = self.sea.fs
        try:
            names = fs.listdir(self.root)
        except FileNotFoundError:
            return
        complete: list[int] = []
        partial: list[int] = []
        for n in names:
            m = _STEP_RE.match(n)
            if not m:
                continue
            s = int(m.group(1))
            if fs.exists(os.path.join(self.root, n, _MARKER)):
                complete.append(s)
            else:
                partial.append(s)
        complete.sort()
        doomed = partial + complete[: max(len(complete) - self.keep_n, 0)]
        for s in doomed:
            d = self._step_dir(s)
            try:
                for name in fs.listdir(d):
                    try:
                        fs.remove(os.path.join(d, name))
                    except FileNotFoundError:
                        pass
            except FileNotFoundError:
                pass
            try:
                fs.rmdir(d)
            except OSError:
                pass  # a straggler write raced in; next GC retries


def checkpoint_sea_config(workdir: str, **kw):
    """A SeaConfig preset for checkpointing: checkpoint files are MOVEd
    (flush + evict) to the persistent tier; heartbeats stay cache-only."""
    import dataclasses

    from repro.core import default_local_config

    cfg = default_local_config(workdir, **kw)
    return dataclasses.replace(
        cfg,
        flushlist=("checkpoints/*/*",),
        evictlist=("checkpoints/*/*", "heartbeats/*"),
    )
