"""Burst-buffer checkpointing through Sea — the paper's pattern applied to
training state.

Saves land on the *fastest tier with space* (host tmpfs — the burst
buffer), so the training loop blocks only for a memory-speed write; the
Sea flush daemon materializes the checkpoint to the persistent tier
asynchronously (MOVE mode: flush + evict, keeping the burst buffer free
for the next save). This is exactly the checkpoint workflow that
motivated HPC burst buffers (paper §2.1) and Sea's copy/move semantics
(§3.3).

Crash safety: a ``_COMPLETE`` marker is written after every leaf file and
the manifest; restore only considers steps whose marker AND manifest
files verify (crc32). ``restore_latest`` reads through the hierarchy, so
a checkpoint still sitting in the burst buffer restores at tmpfs speed —
node-local restart after preemption costs seconds, not a PFS read.

Elastic restore: pass ``shardings`` built from a *different* mesh and the
leaves are device_put against it (tests/test_checkpoint.py exercises a
reshard).
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass

from repro.checkpoint import serialization as ser
from repro.core import Sea

_STEP_RE = re.compile(r"^step_(\d+)$")
_MARKER = "_COMPLETE"


@dataclass
class CheckpointManager:
    sea: Sea
    subdir: str = "checkpoints"
    keep_n: int = 3

    @property
    def root(self) -> str:
        return os.path.join(self.sea.fs.mount, self.subdir)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, blocking_flush: bool = False) -> str:
        """Write the state to the burst buffer; flush happens async."""
        d = self._step_dir(step)
        fs = self.sea.fs
        ser.save_tree(state, d, open_fn=fs.open, makedirs_fn=None)
        with fs.open(os.path.join(d, _MARKER), "w") as f:
            f.write(json.dumps({"step": step}))
        self._gc()
        if blocking_flush:
            self.sea.flusher.drain()
        return d

    # ------------------------------------------------------------------ list
    def available_steps(self) -> list[int]:
        fs = self.sea.fs
        try:
            names = fs.listdir(self.root)
        except FileNotFoundError:
            return []
        steps = []
        for n in names:
            m = _STEP_RE.match(n)
            if not m:
                continue
            if fs.exists(os.path.join(self.root, n, _MARKER)):
                steps.append(int(m.group(1)))
        return sorted(steps)

    # ------------------------------------------------------------------ load
    def restore(self, step: int, template, shardings=None):
        d = self._step_dir(step)
        fs = self.sea.fs
        return ser.load_tree(template, d, open_fn=fs.open, shardings=shardings)

    def restore_latest(self, template, shardings=None):
        """Returns (step, state) or (None, None) if nothing checkpointed."""
        for step in reversed(self.available_steps()):
            try:
                return step, self.restore(step, template, shardings)
            except (IOError, ValueError, FileNotFoundError, KeyError):
                continue  # partial/corrupt checkpoint: fall back to older
        return None, None

    # ------------------------------------------------------------------ gc
    def _gc(self) -> None:
        steps = self.available_steps()
        fs = self.sea.fs
        for s in steps[: max(len(steps) - self.keep_n, 0)]:
            d = self._step_dir(s)
            try:
                for name in fs.listdir(d):
                    fs.remove(os.path.join(d, name))
            except FileNotFoundError:
                pass


def checkpoint_sea_config(workdir: str, **kw):
    """A SeaConfig preset for checkpointing: checkpoint files are MOVEd
    (flush + evict) to the persistent tier; heartbeats stay cache-only."""
    import dataclasses

    from repro.core import default_local_config

    cfg = default_local_config(workdir, **kw)
    return dataclasses.replace(
        cfg,
        flushlist=("checkpoints/*/*",),
        evictlist=("checkpoints/*/*", "heartbeats/*"),
    )
