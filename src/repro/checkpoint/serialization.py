"""Pytree (de)serialization to per-leaf .npy files + a JSON manifest.

bfloat16 leaves are stored as uint16 bit patterns (numpy-portable) with
the logical dtype recorded in the manifest. Every leaf carries a crc32 so
restore can verify integrity after a crash or partial flush.
"""

from __future__ import annotations

import io
import json
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(x)
    logical = str(arr.dtype)
    if logical == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, logical


def _from_numpy(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical == "bfloat16":
        import ml_dtypes

        return arr.view(ml_dtypes.bfloat16)
    return arr


def save_tree(tree, dirpath: str, open_fn: Callable = open,
              makedirs_fn: Callable | None = None) -> dict:
    """Write every leaf to ``dirpath/<idx>.npy``; returns the manifest."""
    if makedirs_fn is not None:
        makedirs_fn(dirpath, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: dict[str, Any] = {"leaves": {}}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = _path_str(path)
        arr, logical = _to_numpy(leaf)
        fname = f"{i:05d}.npy"
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        data = buf.getvalue()
        with open_fn(f"{dirpath}/{fname}", "wb") as f:
            f.write(data)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": logical,
            "crc32": zlib.crc32(data) & 0xFFFFFFFF,
            "bytes": len(data),
        }
    with open_fn(f"{dirpath}/manifest.json", "w") as f:
        json.dump(manifest, f)
    return manifest


def load_manifest(dirpath: str, open_fn: Callable = open) -> dict:
    with open_fn(f"{dirpath}/manifest.json", "r") as f:
        return json.load(f)


def load_tree(template, dirpath: str, open_fn: Callable = open,
              shardings=None, verify: bool = True):
    """Load into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    jax.sharding.Sharding for elastic restore onto a different mesh."""
    manifest = load_manifest(dirpath, open_fn)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    out = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = _path_str(path)
        meta = manifest["leaves"][key]
        with open_fn(f"{dirpath}/{meta['file']}", "rb") as f:
            data = f.read()
        if verify and (zlib.crc32(data) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {key} in {dirpath}")
        arr = np.load(io.BytesIO(data), allow_pickle=False)
        arr = _from_numpy(arr, meta["dtype"])
        expected = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expected:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {expected}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
