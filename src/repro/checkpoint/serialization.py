"""Pytree (de)serialization to per-leaf .npy files + a JSON manifest.

bfloat16 leaves are stored as uint16 bit patterns (numpy-portable) with
the logical dtype recorded in the manifest. Every shard file carries a
crc32 — folded incrementally while the bytes stream out, not computed
over a staged ``BytesIO`` copy — so restore can verify integrity after a
crash or partial flush without save ever holding a serialized duplicate
of a leaf in memory.

Sharded leaves: a jax.Array's host snapshot covers only the shards this
process addresses with ``replica_id == 0``, so on a multi-host mesh each
shard is written exactly once cluster-wide (no N×-duplicated replicated
leaves). A leaf then appears in the manifest as a list of shard files
with their global index ranges; restore reassembles them. Single-shard
leaves keep the seed's flat ``file``/``crc32`` manifest keys, so old
checkpoints load unchanged.
"""

from __future__ import annotations

import io
import json
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def process_index() -> int:
    """This host's rank (0 on single-process runs): the rank that owns
    manifest + marker writes."""
    try:
        return jax.process_index()
    except Exception:
        return 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(x)
    logical = str(arr.dtype)
    if logical == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, logical


def _from_numpy(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical == "bfloat16":
        import ml_dtypes

        return arr.view(ml_dtypes.bfloat16)
    return arr


class _CRC32Writer:
    """File-object shim: streams writes through to ``f`` while folding
    each chunk into a running crc32. ``np.save`` onto a non-file object
    writes the payload in bounded buffered chunks, so neither the
    serialized leaf nor its checksum input is ever fully materialized."""

    def __init__(self, f):
        self._f = f
        self.crc = 0
        self.nbytes = 0

    def write(self, b) -> int:
        self._f.write(b)
        self.crc = zlib.crc32(b, self.crc)
        self.nbytes += len(b)
        return len(b)


def _shard_index(shard, shape) -> list[list[int]] | None:
    """JSON-able ``[[start, stop], ...]`` per dim, or None when the shard
    covers the whole (or 0-d) array."""
    if not shape:
        return None
    out = []
    full = True
    for sl, dim in zip(shard.index, shape):
        start, stop, _ = sl.indices(dim)
        out.append([start, stop])
        if start != 0 or stop != dim:
            full = False
    return None if full else out


def _snapshot_leaf(leaf) -> tuple[tuple, str, list]:
    """Device->host snapshot of the parts of ``leaf`` this process must
    write. Returns (global_shape, logical_dtype, [(index, host_arr)]):
    one entry per addressable shard with replica_id 0 (each shard of a
    sharded/replicated array is written by exactly one process), or the
    whole array for plain host values."""
    shards = getattr(leaf, "addressable_shards", None)
    if shards:
        shape = tuple(leaf.shape)
        logical = str(leaf.dtype)
        parts = []
        for s in shards:
            if s.replica_id != 0:
                continue
            arr, logical = _to_numpy(s.data)
            parts.append((_shard_index(s, shape), arr))
        return shape, logical, parts
    arr, logical = _to_numpy(leaf)
    return tuple(arr.shape), logical, [(None, arr)]


def snapshot_tree(tree) -> tuple[dict, list]:
    """Snapshot every leaf to host memory (the only device-blocking part
    of a save). Returns ``(manifest, jobs)`` where each job is
    ``(fname, host_array, shard_entry)`` still to be written —
    ``write_leaf`` fills the entry's ``crc32``/``bytes`` in place, so the
    manifest is complete once every job ran."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: dict[str, Any] = {"leaves": {}}
    jobs = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = _path_str(path)
        shape, logical, parts = _snapshot_leaf(leaf)
        meta: dict[str, Any] = {
            "shape": list(shape),
            "dtype": logical,
            "shards": [],
        }
        single = len(parts) == 1 and parts[0][0] is None
        for j, (idx, arr) in enumerate(parts):
            fname = f"{i:05d}.npy" if single else f"{i:05d}.s{j:02d}.npy"
            entry = {"file": fname, "index": idx, "crc32": None, "bytes": None}
            meta["shards"].append(entry)
            jobs.append((fname, arr, entry))
        manifest["leaves"][key] = meta
    return manifest, jobs


def write_leaf(path: str, arr: np.ndarray,
               open_fn: Callable = open) -> tuple[int, int]:
    """Stream one host array to ``path`` as .npy; returns (crc32, bytes)."""
    with open_fn(path, "wb") as f:
        w = _CRC32Writer(f)
        np.save(w, arr, allow_pickle=False)
    return w.crc & 0xFFFFFFFF, w.nbytes


def write_manifest(manifest: dict, dirpath: str,
                   open_fn: Callable = open) -> None:
    """Commit the manifest (leaf writes must have completed). Leaves with
    one whole-array shard mirror the seed's flat ``file``/``crc32``/
    ``bytes`` keys for backward compatibility."""
    for meta in manifest["leaves"].values():
        sh = meta.get("shards") or []
        if len(sh) == 1 and sh[0]["index"] is None:
            meta["file"] = sh[0]["file"]
            meta["crc32"] = sh[0]["crc32"]
            meta["bytes"] = sh[0]["bytes"]
    with open_fn(f"{dirpath}/manifest.json", "w") as f:
        json.dump(manifest, f)


def save_tree(tree, dirpath: str, open_fn: Callable = open,
              makedirs_fn: Callable | None = None) -> dict:
    """Write every leaf to ``dirpath/<idx>.npy``; returns the manifest.
    (Serial convenience path — CheckpointManager fans the same jobs
    through the transfer-engine pool instead.)"""
    if makedirs_fn is not None:
        makedirs_fn(dirpath, exist_ok=True)
    manifest, jobs = snapshot_tree(tree)
    for fname, arr, entry in jobs:
        crc, n = write_leaf(f"{dirpath}/{fname}", arr, open_fn)
        entry["crc32"], entry["bytes"] = crc, n
    write_manifest(manifest, dirpath, open_fn)
    return manifest


def load_manifest(dirpath: str, open_fn: Callable = open) -> dict:
    with open_fn(f"{dirpath}/manifest.json", "r") as f:
        return json.load(f)


def read_leaf(dirpath: str, key: str, meta: dict, open_fn: Callable = open,
              verify: bool = True) -> np.ndarray:
    """Read + verify + reassemble one leaf's host array from its shard
    files (flat seed-format manifests read as one whole-array shard)."""
    shards = meta.get("shards") or [
        {"file": meta["file"], "index": None, "crc32": meta["crc32"]}
    ]
    parts = []
    for ent in shards:
        with open_fn(f"{dirpath}/{ent['file']}", "rb") as f:
            data = f.read()
        if verify and (zlib.crc32(data) & 0xFFFFFFFF) != ent["crc32"]:
            raise IOError(f"checksum mismatch for {key} in {dirpath}")
        parts.append(
            (ent.get("index"), np.load(io.BytesIO(data), allow_pickle=False))
        )
    if len(parts) == 1 and parts[0][0] is None:
        arr = parts[0][1]
    else:
        arr = np.empty(tuple(meta["shape"]), dtype=parts[0][1].dtype)
        for idx, p in parts:
            sl = (
                tuple(slice(a, b) for a, b in idx)
                if idx is not None
                else tuple(slice(None) for _ in arr.shape)
            )
            arr[sl] = p
    return _from_numpy(arr, meta["dtype"])


def load_tree(template, dirpath: str, open_fn: Callable = open,
              shardings=None, verify: bool = True, pool=None):
    """Load into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    jax.sharding.Sharding for elastic restore onto a different mesh.
    ``pool``: optional TransferEngine — leaf reads fan out across its
    workers and each finished leaf's ``device_put`` overlaps the reads
    still in flight."""
    manifest = load_manifest(dirpath, open_fn)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    items = []
    for path, leaf in leaves_with_paths:
        key = _path_str(path)
        items.append((key, manifest["leaves"][key], leaf))

    def _read(item):
        key, meta, _ = item
        return read_leaf(dirpath, key, meta, open_fn, verify)

    if pool is not None and len(items) > 1:
        futs = [pool.submit(_read, item) for item in items]
        arrs = (f.result() for f in futs)
    else:
        arrs = (_read(item) for item in items)
    out = []
    for i, arr in enumerate(arrs):
        key, meta, leaf = items[i]
        expected = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expected:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {expected}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
