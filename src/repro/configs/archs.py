"""The 10 assigned architectures (exact public-literature configs).

Sources per the assignment brief:
    rwkv6-7b                  [arXiv:2404.05892]
    llama4-maverick-400b-a17b [hf:meta-llama/Llama-4 family]
    qwen2-moe-a2.7b           [hf:Qwen/Qwen1.5-MoE-A2.7B]
    phi-3-vision-4.2b         [hf:microsoft/Phi-3-vision-128k-instruct]
    gemma3-4b                 [hf:google/gemma-3 family]
    mistral-large-123b        [hf:mistralai/Mistral-Large-Instruct-2407]
    granite-3-2b              [hf:ibm-granite/granite-3.0-2b-base]
    qwen3-4b                  [hf:Qwen/Qwen3 family]
    whisper-base              [arXiv:2212.04356]
    jamba-v0.1-52b            [arXiv:2403.19887]

``reduced(cfg)`` shrinks any config to smoke-test size while preserving
its family structure (pattern, MoE, SSM, enc-dec wiring).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import (
    AttentionConfig,
    EncDecConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    register,
)


@register("rwkv6-7b")
def rwkv6_7b() -> ModelConfig:
    """RWKV-6 'Finch' 7B: attention-free, data-dependent decay."""
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=65536,
        pattern=("rwkv:rwkv",),
        rwkv=RWKVConfig(head_size=64, decay_lora=64, gate_lora=64,
                        token_shift_lora=32, chunk=64),
        attention=None,
        supports_long_context=True,   # O(1) state in sequence length
    )


@register("llama4-maverick-400b-a17b")
def llama4_maverick() -> ModelConfig:
    """Llama-4 Maverick-class: 48L, alternating dense/MoE (128e top-1 +
    1 shared expert) -> ~400B total / ~17B active."""
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        d_ff=8192,
        vocab_size=202048,
        pattern=("attn:mlp", "attn:moe"),
        attention=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                                  rope_theta=500000.0),
        moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                      d_ff_shared=8192, capacity_factor=1.25),
        opt_state_dtype="bfloat16",   # >=100B params: bf16 m/v
    )


@register("qwen2-moe-a2.7b")
def qwen2_moe() -> ModelConfig:
    """Qwen1.5-MoE-A2.7B: 60 routed experts top-4 (padded 60->64 for EP)
    + 4 shared experts (4x1408 = 5632 merged)."""
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        d_ff=5632,
        vocab_size=151936,
        pattern=("attn:moe",),
        attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                                  rope_theta=1000000.0),
        moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                      num_shared_experts=4, d_ff_shared=5632,
                      capacity_factor=1.25, padded_experts=4),
    )


@register("phi-3-vision-4.2b")
def phi3_vision() -> ModelConfig:
    """Phi-3-vision: phi3-mini backbone; CLIP frontend STUBBED —
    input_specs provide 256 precomputed patch embeddings."""
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        d_ff=8192,
        vocab_size=32064,
        pattern=("attn:mlp",),
        attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=96,
                                  rope_theta=10000.0),
        frontend="vision_stub",
        frontend_tokens=256,
    )


@register("gemma3-4b")
def gemma3_4b() -> ModelConfig:
    """Gemma-3 4B: 5 local (1024-window) : 1 global interleave, qk-norm,
    dual RoPE bases, tied embeddings, 262k vocab."""
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        d_ff=10240,
        vocab_size=262144,
        pattern=("local:mlp",) * 5 + ("attn:mlp",),
        attention=AttentionConfig(num_heads=8, num_kv_heads=4, head_dim=256,
                                  rope_theta=1000000.0, rope_theta_local=10000.0,
                                  qk_norm=True, sliding_window=1024),
        act="gelu",
        tie_embeddings=True,
        supports_long_context=True,   # ring-buffer caches on 5/6 of layers
    )


@register("mistral-large-123b")
def mistral_large() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        d_ff=28672,
        vocab_size=32768,
        pattern=("attn:mlp",),
        attention=AttentionConfig(num_heads=96, num_kv_heads=8, head_dim=128,
                                  rope_theta=1000000.0),
        opt_state_dtype="bfloat16",   # 123B params: bf16 m/v
    )


@register("granite-3-2b")
def granite3_2b() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        d_ff=8192,
        vocab_size=49155,
        pattern=("attn:mlp",),
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=64,
                                  rope_theta=10000.0),
    )


@register("qwen3-4b")
def qwen3_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        d_ff=9728,
        vocab_size=151936,
        pattern=("attn:mlp",),
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                                  rope_theta=1000000.0, qk_norm=True),
        tie_embeddings=True,
    )


@register("whisper-base")
def whisper_base() -> ModelConfig:
    """Whisper-base: 6L encoder + 6L decoder (self+cross), conv frontend
    STUBBED (frame embeddings provided). Decode shapes beyond the 448
    trained positions are nominal (see DESIGN.md)."""
    return ModelConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,                    # decoder layers
        d_model=512,
        d_ff=2048,
        vocab_size=51865,
        pattern=("attnx:mlp",),
        attention=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=64),
        encdec=EncDecConfig(n_encoder_layers=6, decoder_seq_divisor=4,
                            cross_len_decode=1500),
        frontend="audio_stub",
    )


@register("jamba-v0.1-52b")
def jamba_52b() -> ModelConfig:
    """Jamba v0.1: period-8 block — 7 Mamba + 1 attention (offset 4),
    MoE (16e top-2) on every odd sublayer."""
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=65536,
        pattern=(
            "mamba:mlp", "mamba:moe", "mamba:mlp", "mamba:moe",
            "attn:mlp", "mamba:moe", "mamba:mlp", "mamba:moe",
        ),
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                                  rope_theta=10000.0),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256, chunk=256),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                      capacity_factor=1.25),
        supports_long_context=True,   # only 4 of 32 layers hold KV
    )


# ------------------------------------------------------------------ reduced
def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink to smoke-test size, preserving the family structure."""
    kw: dict = dict(
        n_layers=len(cfg.pattern) + min(len(cfg.remainder), 1),
        d_model=64,
        d_ff=128,
        vocab_size=512,
    )
    if cfg.attention is not None:
        kw["attention"] = dataclasses.replace(
            cfg.attention,
            num_heads=4,
            num_kv_heads=min(cfg.attention.num_kv_heads, 2),
            head_dim=16,
            q_chunk=16,
            kv_chunk=16,
            sliding_window=8 if cfg.attention.sliding_window else None,
        )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.d_ff_shared else 0,
            padded_experts=0,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, dt_rank=8, chunk=16)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_size=16, decay_lora=8, gate_lora=8,
            token_shift_lora=8, chunk=16,
        )
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(
            cfg.encdec, n_encoder_layers=2, cross_len_decode=24
        )
    if cfg.frontend_tokens:
        kw["frontend_tokens"] = 8
    return cfg.replace(**kw)
