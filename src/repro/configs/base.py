"""Model/architecture configuration schema + registry.

Every assigned architecture is a ``ModelConfig`` built from the exact
public-literature hyperparameters (see ``src/repro/configs/<id>.py``).
A config describes the layer stack as a repeating *pattern* of sublayer
kinds (period P); ``n_layers = n_periods * P + len(remainder)``. Pattern
entries are "<mixer>:<ffn>" strings:

    mixer ∈ {attn, local, mamba, rwkv}     ffn ∈ {mlp, moe, rwkv}

e.g. gemma3 = ("local:mlp",)*5 + ("attn:mlp",)  — 5 sliding-window layers
per global layer; jamba period-8 interleaves 7 mamba + 1 attention with
MoE on every other sublayer.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rope_theta_local: float = 10000.0   # gemma3 uses a lower base locally
    qk_norm: bool = False
    sliding_window: int | None = None   # for "local" pattern entries
    q_chunk: int = 512                  # flash-style chunking (XLA path)
    kv_chunk: int = 1024
    causal: bool = True
    logit_softcap: float | None = None
    kv_replicate_hint: bool = True      # False: let SPMD keep K/V sharded


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0                # total shared-expert hidden width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    padded_experts: int = 0             # pad expert dim for EP divisibility


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                    # 0 -> ceil(d_model / 16)
    chunk: int = 256                    # time-chunking for the scan
    scan_dtype: str = "float32"         # bf16 halves the chunk temporaries


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64
    gate_lora: int = 64
    token_shift_lora: int = 32
    chunk: int = 64                     # WKV chunk length


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 6
    encoder_seq_ratio: int = 1          # encoder frames per "seq_len" unit
    decoder_seq_divisor: int = 4        # decoder tokens = seq_len / divisor
    cross_len_decode: int = 1500        # encoder length during decode (whisper 30s)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    pattern: tuple[str, ...] = ("attn:mlp",)
    attention: AttentionConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    encdec: EncDecConfig | None = None
    frontend: str = "none"               # none | vision_stub | audio_stub
    frontend_tokens: int = 0             # stub embeddings prepended to text
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"                    # mlp activation (GLU gate)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                  # none | dots | full
    # optimizer-state dtype: fp32 default; bf16 for >=100B-param models
    opt_state_dtype: str = "float32"
    # which shape cells this arch supports (skip policy, see DESIGN.md)
    supports_long_context: bool = False
    # ANALYSIS ONLY: unroll the period scan so XLA cost_analysis counts
    # every layer (scan bodies are otherwise counted once — see
    # EXPERIMENTS.md §Roofline methodology)
    unroll_stack: bool = False

    # ---------------------------------------------------------------- sizes
    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows, padded to a multiple of 256 so the vocab
        dim shards evenly over the 16-way model axis (padded logits are
        masked to -inf)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> tuple[str, ...]:
        """Trailing sublayers that do not fill a whole period."""
        r = self.n_layers % len(self.pattern)
        return self.pattern[:r]

    def head_dims(self) -> tuple[int, int, int]:
        a = self.attention
        assert a is not None
        return a.num_heads, a.num_kv_heads, a.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline math)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D  # embeddings
        if not self.tie_embeddings:
            total += V * D
        counts = {k: 0 for k in ("attn", "local", "attnx", "mamba", "rwkv")}
        ffns = {k: 0 for k in ("mlp", "moe", "rwkv")}
        full = list(self.pattern) * self.n_periods + list(self.remainder)
        for entry in full:
            mixer, ffn = entry.split(":")
            counts[mixer] += 1
            ffns[ffn] += 1
        if self.attention is not None:
            H, Hk, Dh = self.head_dims()
            attn_p = D * H * Dh + 2 * D * Hk * Dh + H * Dh * D
            total += (counts["attn"] + counts["local"]) * attn_p
            total += counts["attnx"] * 2 * attn_p  # self + cross
        if self.encdec is not None and self.attention is not None:
            H, Hk, Dh = self.head_dims()
            attn_p = D * H * Dh + 2 * D * Hk * Dh + H * Dh * D
            total += self.encdec.n_encoder_layers * (attn_p + 3 * D * F)
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * D
            dtr = s.dt_rank or math.ceil(D / 16)
            mamba_p = (
                D * 2 * d_in + s.d_conv * d_in
                + d_in * (dtr + 2 * s.d_state) + dtr * d_in
                + d_in * s.d_state + d_in + d_in * D
            )
            total += counts["mamba"] * mamba_p
        if self.rwkv is not None:
            total += counts["rwkv"] * (4 * D * D + D * D)  # r,k,v,g,o proj
            total += counts["rwkv"] * (
                self.rwkv.decay_lora * 2 * D + self.rwkv.token_shift_lora * 12 * D
            )
        ffns_mlp = ffns["mlp"]
        total += ffns_mlp * 3 * D * F  # SwiGLU
        if ffns["rwkv"]:
            total += ffns["rwkv"] * (2 * D * F + D * D)  # rwkv channel mix
        if self.moe is not None and ffns["moe"]:
            m = self.moe
            per_layer = m.num_experts * 3 * D * m.d_ff_expert + D * m.num_experts
            if m.d_ff_shared:
                per_layer += 3 * D * m.d_ff_shared + D
            total += ffns["moe"] * per_layer
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters for MoE models — MODEL_FLOPS uses
        6 * N_active * D_tokens."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive_experts = m.num_experts - m.top_k
        full = list(self.pattern) * self.n_periods + list(self.remainder)
        n_moe = sum(1 for e in full if e.endswith(":moe"))
        return self.param_count() - n_moe * inactive_experts * 3 * self.d_model * m.d_ff_expert

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------------------ registry
_REGISTRY: dict[str, "callable"] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ModelConfig:
    # import the per-arch modules lazily so the registry is populated
    from repro import configs as _pkg  # noqa: F401
    import repro.configs.archs  # noqa: F401

    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]()


def list_archs() -> list[str]:
    import repro.configs.archs  # noqa: F401

    return sorted(_REGISTRY)


# ---------------------------------------------------------------- shapes
@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Skip policy (documented in DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
