"""repro.core — the Sea data-placement library (the paper's contribution).

Public surface:
    SeaConfig / TierSpec      configuration (paper §3.1.1)
    SeaFS                     stateless path translation + file ops (§3.1.2)
    SeaMount                  Python-level interception context (LD_PRELOAD analogue)
    Flusher / Sea             flush-and-evict daemon, prefetcher (§3.3)
    Resolver                  O(1) key→location resolution, verify-on-hit
    CapacityLedger            O(1) capacity accounting (beyond-paper hot path)
    SharedCapacityLedger      cross-process ledger (n_procs instances per node)
    Mode / CompiledRules      copy / remove / move / keep (Table 1)
    TransferEngine            data plane: chunked, atomic tier-to-tier copies
    ExtentStore / ExtentMap   block-granular partial replicas (extent plane)
    FederationRegistry        cluster cache federation (peer-aware placement)
    perf model                ``repro.core.model`` (Eqs. 1–11)
    simulator                 ``repro.core.simulator`` (paper-scale experiments)
"""

from .config import SeaConfig, default_local_config
from .extents import PART_SUFFIX, ExtentMap, ExtentStore
from .federation import FederationRegistry
from .flusher import Flusher, Sea
from .intercept import SeaMount
from .ledger import CapacityLedger, Reservation
from .lists import CompiledRules, Mode, matches, resolve_mode
from .placement import PlacementPolicy
from .prefetcher import Prefetcher
from .resolver import Resolver
from .seafs import SeaFS
from .shared_ledger import SharedCapacityLedger, SharedReservation
from .telemetry import Telemetry
from .tiers import Hierarchy, Tier, TierSpec
from .transfer import (
    TransferAdmissionError,
    TransferCancelled,
    TransferEngine,
    TransferError,
    TransferResult,
)

__all__ = [
    "SeaConfig",
    "default_local_config",
    "ExtentMap",
    "ExtentStore",
    "PART_SUFFIX",
    "FederationRegistry",
    "Flusher",
    "Sea",
    "SeaMount",
    "CapacityLedger",
    "Reservation",
    "SharedCapacityLedger",
    "SharedReservation",
    "Mode",
    "CompiledRules",
    "matches",
    "resolve_mode",
    "PlacementPolicy",
    "Prefetcher",
    "Resolver",
    "SeaFS",
    "Telemetry",
    "Hierarchy",
    "Tier",
    "TierSpec",
    "TransferEngine",
    "TransferError",
    "TransferAdmissionError",
    "TransferCancelled",
    "TransferResult",
]
