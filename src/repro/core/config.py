"""Sea configuration.

Mirrors the paper's configuration surface (§3.1.1/§5.1): a mountpoint, an
ordered storage hierarchy, the maximum file size the workflow produces, the
number of concurrent processes, and the three list files
(.sea_flushlist / .sea_evictlist / .sea_prefetchlist).

"At minimum, Sea requires the specification of a configuration file for it
to work." — we accept a Python dataclass, a TOML/INI-style file, or
environment variables, keeping the minimal-configuration requirement.
"""

from __future__ import annotations

import configparser
import os
from dataclasses import dataclass, field, replace

from .tiers import Hierarchy, TierSpec

#: default basenames, identical to the paper
FLUSHLIST_NAME = ".sea_flushlist"
EVICTLIST_NAME = ".sea_evictlist"
PREFETCHLIST_NAME = ".sea_prefetchlist"


@dataclass
class SeaConfig:
    mount: str                          # virtual mountpoint the app writes under
    tiers: list[TierSpec]               # fastest first; last = persistent base
    max_file_size: int = 1 << 20        # F: max bytes one workflow file may have
    n_procs: int = 1                    # p: concurrent writer processes
    flushlist: tuple[str, ...] = ()     # glob patterns, relative to mount
    evictlist: tuple[str, ...] = ()
    prefetchlist: tuple[str, ...] = ()
    #: flusher behaviour
    flush_interval_s: float = 0.05      # poll period of the flush-and-evict daemon
    max_inflight_flush_bytes: int = 1 << 30  # beyond-paper: bounded async flushing
    flush_workers: int = 2              # worker pool size: flushes of independent
                                        # keys proceed concurrently
    #: capacity-accounting ledger (O(1) placement hot path)
    capacity_ledger: bool = True        # False = seed's stateless per-call rescan
    ledger_reconcile_interval_s: float = 5.0  # staleness bound for absorbing
                                              # external writers via re-walk
    #: namespace resolver (O(1) resolution hot path, verify-on-hit)
    resolver_cache: bool = True         # False = seed's O(tiers*roots) probe
                                        # cascade on every resolution
    resolver_negative_ttl_s: float = 0.05  # how long a confirmed miss is
                                           # trusted (read-miss storms)
    resolver_verify_window_s: float = 0.05  # how long a verified hit skips
                                            # the lstat (0 = verify every
                                            # hit; data reads always heal
                                            # on ENOENT either way)
    #: data plane (chunked streaming transfer engine — every byte moved
    #: between tiers goes through repro.core.transfer.TransferEngine)
    transfer_engine: bool = True        # False = seed's whole-file shutil copy
                                        # (atomic commit + accounting kept)
    transfer_workers: int = 4           # bounded parallel transfer pool size
    transfer_chunk_bytes: int = 32 << 20  # chunk size of the streaming copy
                                        # loop (zero-copy syscalls: large
                                        # chunks cost no userspace memory,
                                        # small ones measurably lose to the
                                        # per-call setup overhead)
    transfer_bandwidth_caps: dict[str, float] = field(default_factory=dict)
                                        # bytes/sec per tier pair: "src->dst",
                                        # "src->*", "*->dst", or "*" wildcard
    transfer_retries: int = 2           # retry-with-backoff on transient I/O
    transfer_backoff_s: float = 0.02    # first backoff; doubles per attempt
    transfer_deadline_s: float = 0.0    # >0: abort a copy whose chunk loop
                                        # makes no progress for this long —
                                        # the reservation is released and the
                                        # root's breaker trips (0 = disabled)
    #: failure domains (per-root health tracking + circuit breakers)
    health_window_s: float = 30.0       # sliding window the per-root error
                                        # rate is computed over
    health_error_threshold: float = 0.5  # error rate (within the window) that
                                         # opens a cache root's breaker
    health_min_events: int = 4          # minimum events in the window before
                                        # the error rate can trip the breaker
    health_open_s: float = 2.0          # how long an open breaker waits
                                        # before admitting a half-open probe
    #: fault injection (chaos testing; empty = plane inactive)
    faults: str = ""                    # injection spec, e.g.
                                        # "transfer.chunk:errno=EIO,p=0.5,n=3"
                                        # (see repro.core.faults for grammar)
    fault_seed: int = 0                 # seed of the injection schedule RNG
                                        # (print it: reruns are reproducible)
    #: multi-process coordination (n_procs Sea instances on one node)
    shared_ledger: bool = False         # file-backed cross-process ledger under
                                        # each root + single-flusher election
    leader_heartbeat_s: float = 0.5     # flush-leader heartbeat period; follower
                                        # takeover within 2 missed heartbeats
    #: cluster-scale cache federation (peer-aware miss resolution:
    #: local hit -> peer hit -> base fallback; registry on the base tier)
    federation: bool = False            # publish cache replicas to the shared
                                        # key-location registry and pull
                                        # peer->cache on a local miss
                                        # (requires shared_ledger=True)
    federation_node: str = ""           # this node's registry identity
                                        # ("" = "<host>-<pid>")
    federation_heartbeat_s: float = 1.0  # membership heartbeat period
    federation_node_ttl_s: float = 10.0  # cross-host liveness window: a node
                                         # whose heartbeat is older is dead
                                         # and its entries expire on reconcile
                                         # (same-host death is caught
                                         # immediately by the PID probe)
    #: adaptive read path (predictive readahead + open fast path)
    readahead: bool = False             # access-pattern-driven speculative
                                        # staging base->cache (beyond-paper)
    readahead_depth: int = 4            # max files staged ahead per detected
                                        # sequence (adaptive, 1..depth)
    readahead_min_confidence: float = 0.5  # empirical confidence a predicted
                                           # key needs before staging
    open_fast_path: bool = True         # read-hit opens skip key locks and
                                        # take batched per-thread telemetry
                                        # (False = PR-4 open path, benchmark
                                        # baseline)
    #: extent-granular data plane (block-level placement on cache tiers)
    extent_map: bool = False            # True = key -> extent map on cache
                                        # tiers: sparse partial replicas,
                                        # streaming reads through partially
                                        # staged files, per-extent eviction
                                        # (False = whole-file plane, the
                                        # PR-5 behaviour)
    extent_bytes: int = 32 << 20        # fixed extent (block) size of the
                                        # extent map; staging, admission,
                                        # readahead and eviction all operate
                                        # at this granularity
    #: training I/O (async checkpoint writer + device-feed pipeline)
    checkpoint_async: bool = True       # training drivers overlap checkpoint
                                        # writes with compute (save() itself
                                        # defaults to blocking; this knob is
                                        # what launch/train passes through)
    checkpoint_workers: int = 2         # per-save cap on concurrent leaf
                                        # writes fanned through the shared
                                        # TransferEngine worker pool
    device_prefetch: int = 2            # device batches held in flight by
                                        # DataPipeline.device_iter (host ->
                                        # device double buffering; 1 = no
                                        # overlap beyond the current batch)
    #: beyond-paper options (all default OFF for paper faithfulness)
    stripe_chunk_bytes: int = 0         # >0 enables striping across same-level roots
    lru_evict: bool = False             # auto-evict LRU when a tier is full

    def __post_init__(self) -> None:
        self.mount = os.path.abspath(self.mount)
        self.flushlist = tuple(self.flushlist)
        self.evictlist = tuple(self.evictlist)
        self.prefetchlist = tuple(self.prefetchlist)
        if self.max_file_size <= 0:
            raise ValueError("max_file_size must be positive")
        if self.n_procs <= 0:
            raise ValueError("n_procs must be positive")
        if self.flush_workers <= 0:
            raise ValueError("flush_workers must be positive")
        if self.ledger_reconcile_interval_s < 0:
            raise ValueError("ledger_reconcile_interval_s must be >= 0")
        if self.resolver_negative_ttl_s < 0:
            raise ValueError("resolver_negative_ttl_s must be >= 0")
        if self.resolver_verify_window_s < 0:
            raise ValueError("resolver_verify_window_s must be >= 0")
        if self.leader_heartbeat_s <= 0:
            raise ValueError("leader_heartbeat_s must be positive")
        if self.transfer_workers <= 0:
            raise ValueError("transfer_workers must be positive")
        if self.transfer_chunk_bytes <= 0:
            raise ValueError("transfer_chunk_bytes must be positive")
        if self.transfer_retries < 0:
            raise ValueError("transfer_retries must be >= 0")
        if self.transfer_backoff_s < 0:
            raise ValueError("transfer_backoff_s must be >= 0")
        if self.transfer_deadline_s < 0:
            raise ValueError("transfer_deadline_s must be >= 0")
        if self.health_window_s <= 0:
            raise ValueError("health_window_s must be positive")
        if not 0.0 < self.health_error_threshold <= 1.0:
            raise ValueError("health_error_threshold must be in (0, 1]")
        if self.health_min_events <= 0:
            raise ValueError("health_min_events must be positive")
        if self.health_open_s <= 0:
            raise ValueError("health_open_s must be positive")
        self.transfer_bandwidth_caps = dict(self.transfer_bandwidth_caps)
        for pair, rate in self.transfer_bandwidth_caps.items():
            if float(rate) <= 0:
                raise ValueError(
                    f"transfer_bandwidth_caps[{pair!r}] must be positive"
                )
        if self.readahead_depth <= 0:
            raise ValueError("readahead_depth must be positive")
        if not 0.0 <= self.readahead_min_confidence <= 1.0:
            raise ValueError("readahead_min_confidence must be in [0, 1]")
        if self.extent_bytes <= 0:
            raise ValueError("extent_bytes must be positive")
        if self.extent_map and not self.transfer_engine:
            raise ValueError("extent_map requires transfer_engine=True")
        if self.shared_ledger and not self.capacity_ledger:
            raise ValueError("shared_ledger requires capacity_ledger=True")
        if self.federation and not self.shared_ledger:
            raise ValueError("federation requires shared_ledger=True")
        if self.federation_heartbeat_s <= 0:
            raise ValueError("federation_heartbeat_s must be positive")
        if self.federation_node_ttl_s <= self.federation_heartbeat_s:
            raise ValueError(
                "federation_node_ttl_s must exceed federation_heartbeat_s"
            )
        if self.checkpoint_workers <= 0:
            raise ValueError("checkpoint_workers must be positive")
        if self.device_prefetch <= 0:
            raise ValueError("device_prefetch must be positive")

    # -- presets (paper §3.1.1: "two main modes based on flushing spec") ----
    def in_memory(self, final_globs: tuple[str, ...]) -> "SeaConfig":
        """In-memory computing: only final outputs are flushed (and evicted
        once flushed); intermediates never touch the base tier."""
        return replace(self, flushlist=tuple(final_globs), evictlist=tuple(final_globs))

    def copy_all(self) -> "SeaConfig":
        """Copy-all: everything is materialized to long-term storage."""
        return replace(self, flushlist=("*",), evictlist=())

    def build_hierarchy(self) -> Hierarchy:
        return Hierarchy.from_specs(
            list(self.tiers),
            use_ledger=self.capacity_ledger,
            shared=self.shared_ledger,
            reconcile_interval_s=self.ledger_reconcile_interval_s,
        )

    # -- parsing -------------------------------------------------------------
    @classmethod
    def from_file(cls, path: str) -> "SeaConfig":
        """Parse an INI-style Sea configuration file::

            [sea]
            mount = /sea
            max_file_size = 647088128
            n_procs = 6

            [tier.tmpfs]
            roots = /dev/shm/sea
            write_bw = 2684354560
            read_bw = 7000000000

            [tier.pfs]
            roots = /lustre/scratch
            persistent = true
        """
        cp = configparser.ConfigParser()
        with open(path) as f:
            cp.read_file(f)
        # [transfer.caps] keys are tier-pair names ("NVMe->pfs") that must
        # match TierSpec names exactly — re-read just that section with a
        # case-preserving transform so every other section keeps the
        # historical case-insensitive option lookup
        caps: dict[str, float] = {}
        if cp.has_section("transfer.caps"):
            cpc = configparser.ConfigParser()
            cpc.optionxform = str
            with open(path) as f:
                cpc.read_file(f)
            caps = {
                k: cpc["transfer.caps"].getfloat(k)
                for k in cpc.options("transfer.caps")
                if k not in cpc.defaults()  # [DEFAULT] keys are not caps
            }
        sea = cp["sea"]
        tiers: list[TierSpec] = []
        for section in cp.sections():
            if not section.startswith("tier."):
                continue
            t = cp[section]
            tiers.append(
                TierSpec(
                    name=section[len("tier.") :],
                    roots=tuple(x.strip() for x in t["roots"].split(",")),
                    read_bw=t.getfloat("read_bw", 0.0),
                    write_bw=t.getfloat("write_bw", 0.0),
                    capacity=t.getint("capacity", fallback=None),
                    persistent=t.getboolean("persistent", fallback=False),
                )
            )
        base = os.path.dirname(os.path.abspath(path))

        def _read_list(name: str) -> tuple[str, ...]:
            p = os.path.join(base, name)
            if not os.path.exists(p):
                return ()
            with open(p) as f:
                return tuple(
                    ln.strip() for ln in f if ln.strip() and not ln.startswith("#")
                )

        return cls(
            mount=sea["mount"],
            tiers=tiers,
            max_file_size=sea.getint("max_file_size", 1 << 20),
            n_procs=sea.getint("n_procs", 1),
            flush_workers=sea.getint("flush_workers", 2),
            capacity_ledger=sea.getboolean("capacity_ledger", True),
            ledger_reconcile_interval_s=sea.getfloat(
                "ledger_reconcile_interval_s", 5.0
            ),
            resolver_cache=sea.getboolean("resolver_cache", True),
            resolver_negative_ttl_s=sea.getfloat("resolver_negative_ttl_s", 0.05),
            resolver_verify_window_s=sea.getfloat(
                "resolver_verify_window_s", 0.05
            ),
            shared_ledger=sea.getboolean("shared_ledger", False),
            leader_heartbeat_s=sea.getfloat("leader_heartbeat_s", 0.5),
            federation=sea.getboolean("federation", False),
            federation_node=sea.get("federation_node", ""),
            federation_heartbeat_s=sea.getfloat("federation_heartbeat_s", 1.0),
            federation_node_ttl_s=sea.getfloat("federation_node_ttl_s", 10.0),
            transfer_engine=sea.getboolean("transfer_engine", True),
            transfer_workers=sea.getint("transfer_workers", 4),
            transfer_chunk_bytes=sea.getint("transfer_chunk_bytes", 32 << 20),
            transfer_retries=sea.getint("transfer_retries", 2),
            transfer_backoff_s=sea.getfloat("transfer_backoff_s", 0.02),
            transfer_deadline_s=sea.getfloat("transfer_deadline_s", 0.0),
            health_window_s=sea.getfloat("health_window_s", 30.0),
            health_error_threshold=sea.getfloat("health_error_threshold", 0.5),
            health_min_events=sea.getint("health_min_events", 4),
            health_open_s=sea.getfloat("health_open_s", 2.0),
            faults=sea.get("faults", ""),
            fault_seed=sea.getint("fault_seed", 0),
            transfer_bandwidth_caps=caps,
            readahead=sea.getboolean("readahead", False),
            readahead_depth=sea.getint("readahead_depth", 4),
            readahead_min_confidence=sea.getfloat(
                "readahead_min_confidence", 0.5
            ),
            open_fast_path=sea.getboolean("open_fast_path", True),
            extent_map=sea.getboolean("extent_map", False),
            extent_bytes=sea.getint("extent_bytes", 32 << 20),
            checkpoint_async=sea.getboolean("checkpoint_async", True),
            checkpoint_workers=sea.getint("checkpoint_workers", 2),
            device_prefetch=sea.getint("device_prefetch", 2),
            flushlist=_read_list(FLUSHLIST_NAME),
            evictlist=_read_list(EVICTLIST_NAME),
            prefetchlist=_read_list(PREFETCHLIST_NAME),
        )

    @classmethod
    def from_env(cls, env: dict[str, str] | None = None) -> "SeaConfig":
        """SEA_CONFIG=<path> or SEA_MOUNT/SEA_TIERS=<root:root:...>."""
        env = dict(os.environ if env is None else env)
        if "SEA_CONFIG" in env:
            return cls.from_file(env["SEA_CONFIG"])
        roots = [r for r in env.get("SEA_TIERS", "").split(":") if r]
        if len(roots) < 2:
            raise ValueError("SEA_TIERS must list >=2 roots (fastest first)")
        tiers = [TierSpec(name=f"t{i}", roots=(r,)) for i, r in enumerate(roots)]
        tiers[-1] = replace(tiers[-1], persistent=True)
        return cls(
            mount=env.get("SEA_MOUNT", "/sea"),
            tiers=tiers,
            max_file_size=int(env.get("SEA_MAX_FILE_SIZE", 1 << 20)),
            n_procs=int(env.get("SEA_NPROCS", "1")),
            shared_ledger=env.get("SEA_SHARED_LEDGER", "0") not in ("0", "", "false"),
            federation=env.get("SEA_FEDERATION", "0") not in ("0", "", "false"),
            federation_node=env.get("SEA_FEDERATION_NODE", ""),
            resolver_cache=env.get("SEA_RESOLVER_CACHE", "1")
            not in ("0", "", "false"),
            readahead=env.get("SEA_READAHEAD", "0") not in ("0", "", "false"),
            extent_map=env.get("SEA_EXTENT_MAP", "0") not in ("0", "", "false"),
            extent_bytes=int(env.get("SEA_EXTENT_BYTES", 32 << 20)),
            transfer_deadline_s=float(env.get("SEA_TRANSFER_DEADLINE_S", "0")),
            faults=env.get("SEA_FAULTS", ""),
            fault_seed=int(env.get("SEA_FAULT_SEED", "0")),
        )


def default_local_config(
    workdir: str,
    *,
    max_file_size: int = 1 << 20,
    n_procs: int = 1,
    tmpfs_capacity: int | None = None,
    disk_capacity: int | None = None,
    n_disks: int = 1,
) -> SeaConfig:
    """A convenient single-node hierarchy rooted under ``workdir``:
    tmpfs (/dev/shm) -> local disk -> 'pfs' directory (base tier).

    Used by tests, examples, and the framework's checkpoint/data layers.
    """
    shm = "/dev/shm" if os.path.isdir("/dev/shm") else workdir
    # namespace the tmpfs root by the FULL workdir path (hashed) — basename
    # collisions across runs must never share a burst buffer
    import hashlib

    tag = hashlib.sha1(os.path.abspath(workdir).encode()).hexdigest()[:12]
    return SeaConfig(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="tmpfs",
                roots=(os.path.join(shm, f"sea_{tag}"),),
                capacity=tmpfs_capacity,
                read_bw=7.0e9,
                write_bw=2.7e9,
            ),
            TierSpec(
                name="disk",
                roots=tuple(
                    os.path.join(workdir, f"disk{i}") for i in range(n_disks)
                ),
                capacity=disk_capacity,
                read_bw=5.26e8,
                write_bw=4.47e8,
            ),
            TierSpec(
                name="pfs",
                roots=(os.path.join(workdir, "pfs"),),
                read_bw=1.45e9,
                write_bw=1.27e8,
                persistent=True,
            ),
        ],
        max_file_size=max_file_size,
        n_procs=n_procs,
    )
