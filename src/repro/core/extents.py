"""Extent maps — block-granular placement state for partial cache replicas.

PRs 1–5 encode one central invariant: *a key lives wholly on one tier*.
That invariant makes a 100 GB volume inadmissible to a small NVMe tier
and forces a reader to wait for the entire base→cache stage. This module
breaks the invariant at the data-plane level, following the sub-file heat
management of the authors' user-space HSM follow-up (arXiv:2404.11556)
and the streaming granularity of the openPMD/ADIOS2 work
(arXiv:2107.06108): a key may additionally have a **partial replica** on
a cache tier — a sparse file holding any subset of fixed-size extents
(``SeaConfig.extent_bytes``) — tracked by an :class:`ExtentMap` and made
crash-durable by a per-key validity journal under the root's ledger dir.

Layout on a cache root::

    <root>/<key>.sea_part                      sparse data file, st_size =
                                               logical size, holes where
                                               extents are not yet staged
    <root>/.sea_ledger/extents/<quoted>.json   validity journal: which
                                               extents hold committed bytes

The ``.sea_part`` suffix keeps partial replicas invisible to every
whole-file code path (``Hierarchy.locate`` probes ``<root>/<key>``), so
no reader can ever mistake a hole for data. The journal is written with
the ledger's tmp+``os.replace`` discipline and **only after** the
extent's bytes are durably in the part file — a crash at any point
leaves the extent unmarked, never torn-but-valid. When the last extent
lands, the part file is promoted (``os.replace``) to the plain replica
path and the journal removed: a fully-staged key degenerates to exactly
the whole-file plane's state.

Capacity accounting uses *disk usage*, not logical size: a sparse part
file occupies only its staged blocks, and ``min(st_size, st_blocks*512)``
(see :func:`repro.core.ledger.file_disk_usage`) is what both the ledger
notifications and the reconcile walk record — so a file bigger than the
tier is admitted extent by extent without ever double-counting holes.
"""

from __future__ import annotations

import ctypes
import json
import os
import threading
import time
import urllib.parse

from .ledger import LEDGER_DIRNAME, file_disk_usage

#: suffix of sparse partial replicas on cache tiers — invisible to
#: whole-file resolution (locate probes the exact key), skipped by the
#: flusher scan, listdir unions, and the LRU whole-file walk
PART_SUFFIX = ".sea_part"

#: journal directory under each root's ledger dir
EXTENT_DIRNAME = "extents"

#: separator inside extent prediction tokens. NUL cannot appear in a
#: path, so a token never collides with a real key; the trailing "x"
#: keeps the numeric tail out of the prefetcher's stride regex for the
#: surrounding key while the zero-padded index itself still matches it.
EXTENT_TOKEN_SEP = "\x00x"


def extent_token(key: str, idx: int) -> str:
    """Prediction-stream token for extent ``idx`` of ``key`` — lets the
    prefetcher's existing numeric stride detector run at block
    granularity *within* one file."""
    return f"{key}{EXTENT_TOKEN_SEP}{idx:08d}"


def split_extent_token(token: str) -> tuple[str, int] | None:
    """Inverse of :func:`extent_token`; None for plain whole-file keys."""
    key, sep, tail = token.rpartition(EXTENT_TOKEN_SEP)
    if not sep:
        return None
    try:
        return key, int(tail)
    except ValueError:
        return None


_FALLOC_FL_KEEP_SIZE = 0x01
_FALLOC_FL_PUNCH_HOLE = 0x02

try:  # Linux-only; CPython exposes no fallocate(2) flags, so go via libc
    _libc = ctypes.CDLL(None, use_errno=True)
    _fallocate = _libc.fallocate
    _fallocate.argtypes = [
        ctypes.c_int,
        ctypes.c_int,
        ctypes.c_longlong,
        ctypes.c_longlong,
    ]
    _fallocate.restype = ctypes.c_int
except (OSError, AttributeError):  # pragma: no cover - non-Linux fallback
    _fallocate = None


def punch_hole(fd: int, offset: int, length: int) -> bool:
    """Deallocate ``[offset, offset+length)`` of an open file, keeping its
    logical size (``FALLOC_FL_PUNCH_HOLE | FALLOC_FL_KEEP_SIZE``). Returns
    False where unsupported (non-Linux, or a filesystem without hole
    support) — the caller falls back to dropping the whole replica."""
    if _fallocate is None:
        return False
    return _fallocate(
        fd, _FALLOC_FL_PUNCH_HOLE | _FALLOC_FL_KEEP_SIZE, offset, length
    ) == 0


def part_path(root: str, key: str) -> str:
    return os.path.join(root, key + PART_SUFFIX)


def journal_path(root: str, key: str) -> str:
    return os.path.join(
        root,
        LEDGER_DIRNAME,
        EXTENT_DIRNAME,
        urllib.parse.quote(key, safe="") + ".json",
    )


class ExtentMap:
    """Live state of one key's partial replica on one cache root.

    The ``valid`` set is only ever mutated under the owning
    :class:`ExtentStore`'s per-map lock; readers may probe it lock-free
    (set membership is GIL-atomic) — a stale answer costs one journal
    round-trip or one redundant stage, never a wrong byte."""

    __slots__ = (
        "key",
        "tier",
        "root",
        "size",
        "extent_bytes",
        "valid",
        "atime",
        "verified_at",
        "dead",
        "lock",
    )

    def __init__(self, key: str, tier, root: str, size: int, extent_bytes: int):
        self.key = key
        self.tier = tier
        self.root = root
        self.size = int(size)
        self.extent_bytes = int(extent_bytes)
        self.valid: set[int] = set()
        self.atime: dict[int, float] = {}  # per-extent last read (monotonic)
        self.verified_at = 0.0  # last lstat verify of the part file
        self.dead = False       # discarded/promoted: no further staging
        self.lock = threading.Lock()

    @property
    def part_real(self) -> str:
        return part_path(self.root, self.key)

    @property
    def part_rel(self) -> str:
        """Ledger-relative name of the part file (what a reconcile walk
        of the root records it under)."""
        return self.key + PART_SUFFIX

    @property
    def n_extents(self) -> int:
        return max(1, -(-self.size // self.extent_bytes))

    def index_of(self, offset: int) -> int:
        return min(max(offset, 0) // self.extent_bytes, self.n_extents - 1)

    def extent_range(self, idx: int) -> tuple[int, int]:
        """(start, length) of extent ``idx``; the last extent is short."""
        start = idx * self.extent_bytes
        return start, min(self.extent_bytes, self.size - start)

    def is_valid(self, idx: int) -> bool:
        return idx in self.valid

    @property
    def complete(self) -> bool:
        return len(self.valid) >= self.n_extents

    def valid_bytes(self) -> int:
        return sum(self.extent_range(i)[1] for i in self.valid)

    def touch(self, idx: int) -> None:
        self.atime[idx] = time.monotonic()

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"ExtentMap({self.key!r}, {len(self.valid)}/{self.n_extents} "
            f"extents, root={self.root!r})"
        )


class ExtentStore:
    """Registry of partial replicas (at most one per key) plus their
    crash-durable validity journals. The store owns journal I/O and the
    part files' lifecycle; admission, byte movement, and ledger deltas
    stay with the caller (:class:`~repro.core.seafs.SeaFS`), which holds
    the key lock around every mutation."""

    def __init__(self, extent_bytes: int, telemetry=None):
        self.extent_bytes = int(extent_bytes)
        self.telemetry = telemetry
        self._maps: dict[str, ExtentMap] = {}
        self._lock = threading.Lock()

    # -- lookup ---------------------------------------------------------------
    def get(self, key: str) -> ExtentMap | None:
        """The live map for ``key``, or None when no partial replica is
        known in-process (use :meth:`load` to also probe journals left by
        a previous process)."""
        em = self._maps.get(key)  # GIL-atomic read on the hot path
        if em is not None and em.dead:
            return None
        return em

    def maps(self) -> list[ExtentMap]:
        """Snapshot of every live map (eviction scans iterate this while
        stagers mutate the registry)."""
        with self._lock:
            return list(self._maps.values())

    def load(self, key: str, cache_tiers) -> ExtentMap | None:
        """``get``, falling back to the on-disk journals of every cache
        root — how a fresh process (or one that crashed mid-stage)
        re-adopts a partial replica. A journal whose part file is missing,
        resized, or written with a different extent size is stale and is
        dropped."""
        em = self.get(key)
        if em is not None:
            return em
        for tier in cache_tiers:
            for root in tier.roots:
                em = self._load_one(key, tier, root)
                if em is not None:
                    with self._lock:
                        return self._maps.setdefault(key, em)
        return None

    def _load_one(self, key: str, tier, root: str) -> ExtentMap | None:
        jp = journal_path(root, key)
        try:
            with open(jp) as f:
                rec = json.load(f)
        except (OSError, ValueError):
            return None
        part = part_path(root, key)
        try:
            st = os.stat(part)
        except OSError:
            self._drop_files(root, key)
            return None
        size = int(rec.get("size", -1))
        ebytes = int(rec.get("extent_bytes", 0))
        if st.st_size != size or ebytes != self.extent_bytes:
            self._drop_files(root, key)
            return None
        em = ExtentMap(key, tier, root, size, self.extent_bytes)
        n = em.n_extents
        # freshly constructed map, not yet published to _maps — no other
        # thread can hold a reference, so no lock is needed here
        valid = {int(i) for i in rec.get("valid", ()) if 0 <= int(i) < n}
        em.valid = valid  # seacheck: ignore[lock-discipline]
        em.verified_at = time.monotonic()
        return em

    # -- lifecycle ------------------------------------------------------------
    def create(self, key: str, tier, root: str, size: int) -> ExtentMap:
        """Materialize an empty partial replica: a sparse part file of the
        full logical size (zero blocks allocated) plus an empty journal.
        Caller holds the key lock and accounts the (≈0) disk usage."""
        em = ExtentMap(key, tier, root, size, self.extent_bytes)
        real = em.part_real
        os.makedirs(os.path.dirname(real), exist_ok=True)
        with open(real, "wb") as f:
            f.truncate(size)
        self._write_journal(em)
        em.verified_at = time.monotonic()
        with self._lock:
            self._maps[key] = em
        return em

    def mark_valid(self, em: ExtentMap, idx: int) -> None:
        """Extent ``idx``'s bytes are durably in the part file: record it
        — memory first, then the journal (write-after-bytes ordering is
        what makes a SIGKILL at any point leave the extent unmarked,
        never torn-but-valid)."""
        with em.lock:
            em.valid.add(idx)
            self._write_journal(em)
        em.touch(idx)

    def punch(self, em: ExtentMap, idx: int) -> int:
        """Evict one staged extent: journal first (an extent must never be
        marked valid while its bytes are being deallocated), then punch
        the hole. Returns the bytes freed, or 0 when ``idx`` held nothing
        or the filesystem cannot punch (caller discards the replica)."""
        with em.lock:
            if idx not in em.valid:
                return 0
            em.valid.discard(idx)
            self._write_journal(em)
        em.atime.pop(idx, None)
        start, length = em.extent_range(idx)
        try:
            fd = os.open(em.part_real, os.O_RDWR)
        except OSError:
            return 0
        try:
            if not punch_hole(fd, start, length):
                return 0
        finally:
            os.close(fd)
        return length

    def discard(self, key: str) -> ExtentMap | None:
        """Drop the partial replica entirely (key overwritten, removed,
        truncated, or the replica evicted): part file + journal + map.
        Returns the dropped map so the caller can settle the ledger."""
        with self._lock:
            em = self._maps.pop(key, None)
        if em is not None:
            em.dead = True
            self._drop_files(em.root, key)
        return em

    def promote(self, em: ExtentMap) -> str:
        """Every extent is valid: rename the part file over the plain
        replica path (atomic — readers see either the partial plane or a
        complete whole-file replica) and retire the journal/map. Returns
        the final real path; the caller re-points resolver + ledger."""
        final = os.path.join(em.root, em.key)
        os.replace(em.part_real, final)
        em.dead = True
        with self._lock:
            if self._maps.get(em.key) is em:
                del self._maps[em.key]
        try:
            os.unlink(journal_path(em.root, em.key))
        except OSError:
            pass
        if self.telemetry is not None:
            self.telemetry.record_extent_promoted()
        return final

    def clear(self) -> None:
        """Forget every in-memory map (``wipe``; on-disk state went with
        the roots)."""
        with self._lock:
            for em in self._maps.values():
                em.dead = True
            self._maps.clear()

    # -- journal I/O ----------------------------------------------------------
    def _write_journal(self, em: ExtentMap) -> None:
        jp = journal_path(em.root, em.key)
        os.makedirs(os.path.dirname(jp), exist_ok=True)
        rec = {
            "size": em.size,
            "extent_bytes": em.extent_bytes,
            "valid": sorted(em.valid),
        }
        tmp = f"{jp}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, jp)  # atomic: a crash never leaves a torn journal

    def _drop_files(self, root: str, key: str) -> None:
        for p in (part_path(root, key), journal_path(root, key)):
            try:
                os.unlink(p)
            except OSError:
                pass

    # -- accounting helper ----------------------------------------------------
    @staticmethod
    def disk_usage(em: ExtentMap) -> int:
        """Current on-disk usage of the part file — what the ledger must
        carry for it (holes cost nothing; matches the reconcile walk's
        sparse-aware :func:`~repro.core.ledger.file_disk_usage`)."""
        try:
            return file_disk_usage(em.part_real)
        except OSError:
            return 0
