"""Unified errno classification and the fault-injection plane.

Two concerns live here because they are two sides of the same contract:

* ``classify`` — the single transient-vs-permanent-vs-capacity errno table
  shared by the transfer engine's retry loop, the flusher's backoff logic,
  and the health tracker's breaker trips.  Before this module each caller
  kept its own partial copy of the table and they disagreed (ENOSPC burned
  transfer retries while the flusher backed off forever).

* ``FaultPlane`` — named injection sites threaded through seafs / transfer /
  extents / federation / shared_ledger.  A site is a cheap module-level
  ``fire("transfer.chunk", path=...)`` call that is a no-op unless a plane
  is active.  Rules are parsed from a compact spec string (config ``faults``
  or env ``SEA_FAULTS``) and driven by a seeded RNG so a chaos run is
  reproducible from its printed seed.

Spec grammar (rules separated by ``;``, fields by ``,``)::

    <site-glob>:<action>[,key=value ...]

    actions:  errno=<NAME|int>   raise OSError(errno) at the site
              delay=<seconds>    sleep (cancel-aware) at the site
              torn               truncate the in-flight file to half and
                                 raise EIO (simulates a torn write)
              crash              os._exit(86) — crash the process at the
                                 site (use from subprocess tests only)
    keys:     p=<0..1>           per-hit probability (seeded RNG)
              n=<int>            fire at most n times, then disarm
              after=<int>        skip the first `after` matching hits
              path=<glob>        only fire when the site's path matches

Example: ``transfer.chunk:errno=EIO,p=0.5,n=3;seafs.open:delay=0.2,path=*/disk0/*``
"""

from __future__ import annotations

import errno as _errno
import fnmatch
import os
import random
import threading
import time
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Shared errno classification (single source of truth — transfer.py and
# flusher.py alias these rather than keeping private copies).
# ---------------------------------------------------------------------------

# Copy-mechanism errors: the fast path (copy_file_range / sendfile) is not
# supported for this file pair — demote to the next implementation, do not
# count against retries or health.
FALLBACK_ERRNOS = frozenset(
    {
        _errno.EXDEV,
        _errno.EINVAL,
        _errno.ENOSYS,
        _errno.EOPNOTSUPP,
        getattr(_errno, "ENOTSUP", _errno.EOPNOTSUPP),
        _errno.EBADF,
    }
)

# Fail fast: retrying cannot help (wrong path shape, permissions, name too
# long).  The flusher parks these on a long backoff instead of hammering.
PERMANENT_ERRNOS = frozenset(
    {
        _errno.EISDIR,
        _errno.ENOTDIR,
        _errno.EACCES,
        _errno.EPERM,
        _errno.ENAMETOOLONG,
    }
)

# Capacity exhaustion: retrying burns time without freeing bytes.  These trip
# the root's circuit breaker so placement routes around the full root.
CAPACITY_ERRNOS = frozenset({_errno.ENOSPC, getattr(_errno, "EDQUOT", _errno.ENOSPC)})

#: classification labels returned by :func:`classify`
TRANSIENT = "transient"
PERMANENT = "permanent"
CAPACITY = "capacity"


def classify(exc: BaseException) -> str:
    """Classify an I/O exception for retry/breaker decisions.

    Returns ``"capacity"`` (ENOSPC/EDQUOT — trip the breaker, don't retry),
    ``"permanent"`` (retry cannot help), or ``"transient"`` (worth a retry).
    Non-OSError exceptions are transient: they are usually injected faults or
    wrapper errors whose cause is unknown.
    """
    e = getattr(exc, "errno", None)
    if e is None:
        return TRANSIENT
    if e in CAPACITY_ERRNOS:
        return CAPACITY
    if e in PERMANENT_ERRNOS:
        return PERMANENT
    return TRANSIENT


# ---------------------------------------------------------------------------
# Fault-injection plane
# ---------------------------------------------------------------------------


class FaultCrash(SystemExit):
    """Raised in lieu of os._exit when a crash action runs with exit disabled."""


@dataclass
class FaultRule:
    site: str  # fnmatch glob over site names
    action: str = ""  # "errno" | "delay" | "torn" | "crash"
    errno: int = _errno.EIO
    delay_s: float = 0.0
    prob: float = 1.0
    limit: int = -1  # max fires; -1 = unlimited
    after: int = 0  # skip the first `after` matching hits
    path_glob: str = ""  # only fire when ctx path matches (empty = any)
    # runtime state
    hits: int = 0
    fires: int = 0
    rng: random.Random = field(default_factory=random.Random, repr=False)


class FaultPlane:
    """Deterministic, seeded fault schedule over named injection sites.

    Thread-safe: rule state advances under an internal lock so concurrent
    workers hitting the same site see a consistent schedule.
    """

    def __init__(self, rules: list[FaultRule] | None = None, *, seed: int = 0) -> None:
        self.seed = int(seed)
        self.rules: list[FaultRule] = list(rules or [])
        self._lock = threading.Lock()
        for i, r in enumerate(self.rules):
            r.rng = random.Random((self.seed << 8) ^ i)

    # -- spec parsing -------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: str, *, seed: int = 0) -> "FaultPlane":
        rules: list[FaultRule] = []
        for raw in (spec or "").split(";"):
            raw = raw.strip()
            if not raw:
                continue
            site, _, rest = raw.partition(":")
            if not rest:
                raise ValueError(f"fault rule {raw!r}: missing action")
            rule = FaultRule(site=site.strip())
            for part in rest.split(","):
                part = part.strip()
                if not part:
                    continue
                k, sep, v = part.partition("=")
                k = k.strip()
                v = v.strip()
                if k == "errno":
                    rule.action = "errno"
                    rule.errno = getattr(_errno, v) if not v.isdigit() else int(v)
                elif k == "delay":
                    rule.action = "delay"
                    rule.delay_s = float(v)
                elif k == "torn" and not sep:
                    rule.action = "torn"
                elif k == "crash" and not sep:
                    rule.action = "crash"
                elif k == "p":
                    rule.prob = float(v)
                elif k == "n":
                    rule.limit = int(v)
                elif k == "after":
                    rule.after = int(v)
                elif k == "path":
                    rule.path_glob = v
                else:
                    raise ValueError(f"fault rule {raw!r}: unknown field {part!r}")
            if not rule.action:
                raise ValueError(f"fault rule {raw!r}: no action given")
            rules.append(rule)
        return cls(rules, seed=seed)

    # -- firing -------------------------------------------------------------

    def fire(self, site: str, *, path: str | None = None, cancel=None) -> None:
        """Evaluate all rules against a site hit; may raise or delay."""
        for rule in self.rules:
            if not fnmatch.fnmatchcase(site, rule.site):
                continue
            if rule.path_glob and not (path and fnmatch.fnmatch(path, rule.path_glob)):
                continue
            with self._lock:
                rule.hits += 1
                if rule.hits <= rule.after:
                    continue
                if rule.limit >= 0 and rule.fires >= rule.limit:
                    continue
                if rule.prob < 1.0 and rule.rng.random() >= rule.prob:
                    continue
                rule.fires += 1
            self._act(rule, site, path, cancel)

    def _act(self, rule: FaultRule, site: str, path: str | None, cancel) -> None:
        if rule.action == "errno":
            raise OSError(rule.errno, f"{os.strerror(rule.errno)} [injected@{site}]", path)
        if rule.action == "delay":
            # Cancel-aware hang: a deadline watchdog setting the cancel event
            # unblocks the sleep, modelling a mount that un-wedges on abort.
            if cancel is not None:
                cancel.wait(rule.delay_s)
            else:
                time.sleep(rule.delay_s)
            return
        if rule.action == "torn":
            if path:
                try:
                    size = os.path.getsize(path)
                    # deliberately NOT atomic: the whole point is to tear
                    # the in-flight file the way a dying device would
                    with open(path, "r+b") as f:  # seacheck: ignore[atomic-commit]
                        f.truncate(size // 2)
                except OSError:
                    pass
            raise OSError(_errno.EIO, f"torn write [injected@{site}]", path)
        if rule.action == "crash":
            os._exit(86)
        raise AssertionError(f"unknown fault action {rule.action!r}")


# ---------------------------------------------------------------------------
# Process-global activation.  Sites call the module-level ``fire`` which is a
# single attribute check when no plane is active — cheap enough to leave in
# production code paths.
# ---------------------------------------------------------------------------

_ACTIVE: FaultPlane | None = None


def activate(plane: FaultPlane | None) -> None:
    global _ACTIVE
    _ACTIVE = plane


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active_plane() -> FaultPlane | None:
    return _ACTIVE


def fire(site: str, *, path: str | None = None, cancel=None) -> None:
    plane = _ACTIVE
    if plane is not None:
        plane.fire(site, path=path, cancel=cancel)


#: Injection sites currently threaded through the data plane.  Keep this in
#: sync with the table in docs/ARCHITECTURE.md ("Failure domains").
SITES = (
    "seafs.open",  # before opening a cache-tier real for read
    "seafs.write",  # before each application write on a cache-tier handle
    "transfer.chunk",  # after each chunk of a whole-file copy (path=tmp)
    "transfer.range_chunk",  # after each chunk of an extent copy_range
    "transfer.commit",  # just before the atomic os.replace commit
    "extents.stage",  # before staging an extent into a part file
    "federation.pull",  # before a peer pull copy begins
    "flusher.flush",  # before the flusher copies a key to base
    "shared_ledger.append",  # before a journal record is appended
)
