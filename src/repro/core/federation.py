"""Cluster-scale cache federation: node membership + key-location registry.

Every Sea node's cache is an island in the paper's design: a read miss
streams cold from the base (Lustre) tier even when a sibling node staged
the same key seconds ago. This module federates the caches — a small
registry on the *shared base tier* records which node holds which key, so
a local miss can resolve cluster-wide (local hit → peer hit → base
fallback) and pull peer→cache instead of base→cache when the peer link is
the cheaper path.

The registry extends :mod:`repro.core.shared_ledger`'s journal machinery
host→cluster — the same patterns solve the same problems one level up:

* **Append-compact location journal** (``locations``): header
  ``SEAFED1 <generation> <reconcile_ts>`` followed by
  ``W <size> <quoted-node> <quoted-root> <quoted-key>`` (node holds a
  cache replica of key under root) and ``D <quoted-node> <quoted-key>``
  records. Mutations append one record under an exclusive ``fcntl``
  lock; readers replay only the unseen suffix (byte-offset tracked), so
  steady-state cost is O(1) per operation. Past a few multiples of the
  live-entry count the journal is compacted in place (generation bump —
  peers detect it and reload). A torn trailing record is repaired by
  truncating to the last complete line, exactly like the capacity
  journal.
* **Per-node heartbeat files** (``nodes/<node>.json``, written
  tmp + ``os.replace``): the cluster analogue of the reservation
  markers' dead-owner detection. On the same host a dead node is caught
  immediately by the signal-0 PID probe; across hosts (where PIDs mean
  nothing) staleness of the heartbeat timestamp is the liveness signal.
* **Reconcile expiry**: entries of dead/departed nodes are expired on
  :meth:`reconcile` (triggered lazily once the shared ``reconcile_ts``
  ages past the node TTL), so a crashed node's registry residue
  disappears within one TTL instead of forever poisoning lookups.

The registry is **advisory**: correctness always comes from the base
fallback. A stale entry (peer evicted or died mid-pull) costs one failed
copy attempt, after which the caller expunges the entry and falls back —
it can never produce a wrong read, a partial file, or a leaked
reservation (the transfer engine's atomic-commit contract covers the
pull path).

Store layout (on the shared base tier)::

    <base_root>/.sea_ledger/federation/locations       location journal
    <base_root>/.sea_ledger/federation/nodes/<n>.json  per-node heartbeat
"""

from __future__ import annotations

import fcntl
import json
import os
import socket
import threading
import time
from contextlib import contextmanager
from urllib.parse import quote, unquote

from .ledger import LEDGER_DIRNAME
from .shared_ledger import pid_alive

_MAGIC = "SEAFED1"
_FED_DIRNAME = "federation"
_NODES_DIRNAME = "nodes"
_JOURNAL_NAME = "locations"
_HB_SUFFIX = ".json"

_HOST = (socket.gethostname() or "localhost").replace(".", "-") or "localhost"


def default_node_name() -> str:
    """Stable-for-the-process default node identity. Host + PID: every Sea
    instance owns its own cache roots, so on a multi-process node each
    instance is its own federation "node" (their replicas are distinct
    resources a peer can pull)."""
    return f"{_HOST}-{os.getpid()}"


class _FedAccount:
    """Per-journal, per-process replica of the registry state.

    Like :class:`~repro.core.shared_ledger._SharedAccount`: POSIX fcntl
    locks are owned per (process, inode), so accounts live in a
    process-global registry keyed by the journal's realpath — every
    FederationRegistry in the process shares one fd and one thread lock
    per journal.
    """

    __slots__ = (
        "lock",
        "fd",
        "loaded",
        "entries",
        "generation",
        "offset",
        "lines",
        "reconcile_ts",
        "synced_at",
    )

    def __init__(self):
        self.lock = threading.Lock()
        self.fd: int | None = None
        self.loaded = False
        #: key -> {node: (cache_root, size)}
        self.entries: dict[str, dict[str, tuple[str, int]]] = {}
        self.generation = 0
        self.offset = 0          # bytes of journal replayed so far
        self.lines = 0           # records since last compaction
        self.reconcile_ts = 0.0  # shared wall-clock; 0 = never reconciled
        self.synced_at = 0.0     # monotonic time of the last journal sync


_FED_ACCOUNTS: dict[str, _FedAccount] = {}
_FED_ACCOUNTS_LOCK = threading.Lock()


def _global_account(journal_path: str) -> _FedAccount:
    key = os.path.realpath(journal_path)
    acct = _FED_ACCOUNTS.get(key)
    if acct is None:
        with _FED_ACCOUNTS_LOCK:
            acct = _FED_ACCOUNTS.setdefault(key, _FedAccount())
    return acct


class FederationRegistry:
    """Node membership + key→node location registry for one cluster
    (= one shared base root). All public mutation/lookup methods are
    best-effort and never raise on registry I/O errors — the registry is
    an accelerator; the base tier remains the source of truth."""

    def __init__(
        self,
        base_root: str,
        node: str | None = None,
        *,
        heartbeat_s: float = 1.0,
        node_ttl_s: float = 10.0,
        telemetry=None,
        compact_min_records: int = 512,
        nodes_cache_s: float = 0.25,
    ):
        self.base_root = base_root
        self.node = node or default_node_name()
        self.heartbeat_s = float(heartbeat_s)
        self.node_ttl_s = float(node_ttl_s)
        self.telemetry = telemetry
        self.compact_min_records = compact_min_records
        self._dir = os.path.join(base_root, LEDGER_DIRNAME, _FED_DIRNAME)
        self._nodes_dir = os.path.join(self._dir, _NODES_DIRNAME)
        self._journal_path = os.path.join(self._dir, _JOURNAL_NAME)
        self._last_hb = 0.0          # monotonic time of our last heartbeat
        self._nodes_cache: tuple[float, dict] = (0.0, {})
        self._nodes_cache_s = float(nodes_cache_s)
        self._cache_lock = threading.Lock()
        # join the cluster: the heartbeat must exist before the first
        # publish, or a reconcile could expire our fresh entries as
        # belonging to an unknown node
        self.heartbeat()

    # -- heartbeats (membership) --------------------------------------------
    def _hb_path(self, node: str) -> str:
        return os.path.join(self._nodes_dir, quote(node, safe="") + _HB_SUFFIX)

    def heartbeat(self) -> None:
        """Refresh this node's membership record (tmp + ``os.replace``,
        the flusher-heartbeat pattern — readers never see a torn file)."""
        os.makedirs(self._nodes_dir, exist_ok=True)
        path = self._hb_path(self.node)
        tmp = f"{path}.{os.getpid()}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(
                    {
                        "node": self.node,
                        "host": _HOST,
                        "pid": os.getpid(),
                        "ts": time.time(),
                    },
                    f,
                )
            os.replace(tmp, path)
        except OSError:
            return
        self._last_hb = time.monotonic()

    def maybe_heartbeat(self) -> None:
        """Heartbeat when the last one is older than ``heartbeat_s``.
        Called from the paths that touch the registry anyway (publish,
        lookup) and from the flusher's coordination loop — no dedicated
        thread needed."""
        if time.monotonic() - self._last_hb >= self.heartbeat_s:
            self.heartbeat()

    def _read_nodes(self) -> dict[str, dict]:
        """All heartbeat records, cached briefly (a cold-miss storm must
        not re-read O(nodes) files per lookup)."""
        with self._cache_lock:
            ts, cached = self._nodes_cache
            if time.monotonic() - ts < self._nodes_cache_s:
                return cached
        infos: dict[str, dict] = {}
        try:
            names = os.listdir(self._nodes_dir)
        except OSError:
            names = []
        for fn in names:
            if not fn.endswith(_HB_SUFFIX):
                continue
            try:
                with open(os.path.join(self._nodes_dir, fn)) as f:
                    info = json.load(f)
            except (OSError, ValueError):
                continue
            if isinstance(info, dict) and "node" in info:
                infos[str(info["node"])] = info
        with self._cache_lock:
            self._nodes_cache = (time.monotonic(), infos)
        return infos

    def _node_alive(self, info: dict, now: float) -> bool:
        """Cross-host liveness: heartbeat freshness within the TTL.
        Same-host: the signal-0 PID probe is authoritative (dead-owner
        detection, as for reservation markers) — it both catches a crash
        before the TTL elapses and keeps a live-but-quiet node alive."""
        try:
            pid = int(info.get("pid", 0))
            ts = float(info.get("ts", 0.0))
        except (TypeError, ValueError):
            return False
        if info.get("host") == _HOST:
            return pid_alive(pid)
        return (now - ts) <= self.node_ttl_s

    def live_nodes(self) -> dict[str, dict]:
        """Currently-live members (by heartbeat/PID evidence)."""
        now = time.time()
        return {
            n: info
            for n, info in self._read_nodes().items()
            if self._node_alive(info, now)
        }

    # -- journal plumbing (the shared_ledger pattern, one journal) ----------
    def _account(self) -> _FedAccount:
        return _global_account(self._journal_path)

    @contextmanager
    def _locked(self):
        """Thread lock + exclusive fcntl lock on the location journal,
        with the inode recheck that survives a wipe-replaced journal."""
        acct = self._account()
        with acct.lock:
            while True:
                if acct.fd is None:
                    os.makedirs(self._dir, exist_ok=True)
                    acct.fd = os.open(
                        self._journal_path, os.O_RDWR | os.O_CREAT, 0o644
                    )
                    acct.loaded = False
                fcntl.lockf(acct.fd, fcntl.LOCK_EX)
                try:
                    ino = os.stat(self._journal_path).st_ino
                except FileNotFoundError:
                    ino = -1
                if ino == os.fstat(acct.fd).st_ino:
                    break
                fcntl.lockf(acct.fd, fcntl.LOCK_UN)
                os.close(acct.fd)
                acct.fd = None
            try:
                yield acct
            finally:
                fcntl.lockf(acct.fd, fcntl.LOCK_UN)

    # seacheck: holds-lock
    def _sync(self, acct: _FedAccount) -> None:
        size = os.fstat(acct.fd).st_size
        if size == 0:
            header = f"{_MAGIC} 1 0\n".encode()
            os.pwrite(acct.fd, header, 0)
            acct.loaded = True
            acct.entries = {}
            acct.generation = 1
            acct.offset = len(header)
            acct.lines = 0
            acct.reconcile_ts = 0.0
            acct.synced_at = time.monotonic()
            return
        if acct.loaded:
            head = os.pread(acct.fd, 128, 0).split(b"\n", 1)[0]
            if self._parse_header(head)[0] == acct.generation:
                self._replay_from(acct, acct.offset, size)
                acct.synced_at = time.monotonic()
                return
        self._reload(acct, size)
        acct.synced_at = time.monotonic()

    @staticmethod
    def _parse_header(line: bytes) -> tuple[int, float]:
        parts = line.decode("utf-8", "replace").split()
        try:
            if parts[0] != _MAGIC:
                return -1, 0.0
            return int(parts[1]), float(parts[2])
        except (IndexError, ValueError):
            return -1, 0.0

    # seacheck: holds-lock
    def _reload(self, acct: _FedAccount, size: int) -> None:
        data = os.pread(acct.fd, size, 0)
        nl = data.find(b"\n")
        gen, ts = self._parse_header(data[:nl] if nl >= 0 else data)
        if gen < 0:
            # corrupt header: reset — the registry is advisory, losing it
            # degrades to cold base reads, never to wrong data
            os.ftruncate(acct.fd, 0)
            self._sync(acct)
            return
        acct.generation = gen
        acct.reconcile_ts = ts
        acct.entries = {}
        acct.lines = 0
        acct.offset = nl + 1
        acct.loaded = True
        self._replay_from(acct, acct.offset, size)

    # seacheck: holds-lock
    def _replay_from(self, acct: _FedAccount, start: int, size: int) -> None:
        if size <= start:
            return
        data = os.pread(acct.fd, size - start, start)
        if not data.endswith(b"\n"):
            # torn trailing record (writer died mid-append): truncate to
            # the last complete line under the lock
            cut = data.rfind(b"\n") + 1
            os.ftruncate(acct.fd, start + cut)
            data = data[:cut]
        for line in data.decode("utf-8", "replace").splitlines():
            self._apply(acct, line)
            acct.lines += 1
        acct.offset = start + len(data)

    # seacheck: holds-lock
    @staticmethod
    def _apply(acct: _FedAccount, line: str) -> None:
        if line.startswith("W "):
            try:
                _, sz, qnode, qroot, qkey = line.split(" ", 4)
                nbytes = int(sz)
            except ValueError:
                return
            key = unquote(qkey)
            acct.entries.setdefault(key, {})[unquote(qnode)] = (
                unquote(qroot),
                nbytes,
            )
        elif line.startswith("D "):
            try:
                _, qnode, qkey = line.split(" ", 2)
            except ValueError:
                return
            holders = acct.entries.get(unquote(qkey))
            if holders is not None:
                holders.pop(unquote(qnode), None)
                if not holders:
                    del acct.entries[unquote(qkey)]

    # seacheck: holds-lock
    def _append(self, acct: _FedAccount, line: str) -> None:
        data = line.encode()
        os.pwrite(acct.fd, data, acct.offset)
        acct.offset += len(data)
        acct.lines += 1
        total = sum(len(h) for h in acct.entries.values())
        if acct.lines > max(self.compact_min_records, 4 * total):
            self._rewrite(acct)

    # seacheck: holds-lock
    def _rewrite(
        self, acct: _FedAccount, reconcile_ts: float | None = None
    ) -> None:
        acct.generation += 1
        if reconcile_ts is not None:
            acct.reconcile_ts = reconcile_ts
        buf = [f"{_MAGIC} {acct.generation} {acct.reconcile_ts}\n"]
        for key, holders in acct.entries.items():
            for node, (root, sz) in holders.items():
                buf.append(
                    f"W {sz} {quote(node, safe='')} {quote(root, safe='')} "
                    f"{quote(key, safe='')}\n"
                )
        data = "".join(buf).encode()
        os.ftruncate(acct.fd, 0)
        os.pwrite(acct.fd, data, 0)
        acct.offset = len(data)
        acct.lines = 0

    # -- publish / unpublish -------------------------------------------------
    def publish(self, key: str, cache_root: str, nbytes: int) -> bool:
        """Record that THIS node holds a cache replica of ``key`` under
        ``cache_root`` (called on write commit / staging / peer pull)."""
        self.maybe_heartbeat()
        try:
            with self._locked() as acct:
                self._sync(acct)
                acct.entries.setdefault(key, {})[self.node] = (
                    cache_root,
                    int(nbytes),
                )
                self._append(
                    acct,
                    f"W {int(nbytes)} {quote(self.node, safe='')} "
                    f"{quote(cache_root, safe='')} {quote(key, safe='')}\n",
                )
            return True
        except OSError:
            return False

    def unpublish(self, key: str) -> bool:
        """Drop THIS node's entry for ``key`` (called on evict / remove /
        overwrite-elsewhere). No-op when the node never published it."""
        return self.expunge(key, self.node)

    def expunge(self, key: str, node: str) -> bool:
        """Drop ``node``'s entry for ``key``. Any member may expunge a
        provably-stale entry (pull hit ENOENT: the replica is gone even
        though the owner never logged the eviction — e.g. it crashed)."""
        try:
            with self._locked() as acct:
                self._sync(acct)
                holders = acct.entries.get(key)
                if holders is None or node not in holders:
                    return False
                holders.pop(node, None)
                if not holders:
                    del acct.entries[key]
                self._append(
                    acct,
                    f"D {quote(node, safe='')} {quote(key, safe='')}\n",
                )
            return True
        except OSError:
            return False

    def unpublish_all(self) -> int:
        """Drop every entry THIS node published (wipe/retire)."""
        dropped = 0
        try:
            with self._locked() as acct:
                self._sync(acct)
                mine = [
                    k
                    for k, holders in acct.entries.items()
                    if self.node in holders
                ]
                for key in mine:
                    holders = acct.entries[key]
                    holders.pop(self.node, None)
                    if not holders:
                        del acct.entries[key]
                    self._append(
                        acct,
                        f"D {quote(self.node, safe='')} "
                        f"{quote(key, safe='')}\n",
                    )
                    dropped += 1
        except OSError:
            pass
        return dropped

    # -- lookup (the peer resolution tier) -----------------------------------
    def lookup(self, key: str) -> list[tuple[str, str, int]]:
        """Live peers holding a cache replica of ``key``, as
        ``(node, real_path, size)`` — self excluded, dead/stale nodes
        skipped. Empty on any registry I/O error (callers fall back to
        the base tier)."""
        self.maybe_heartbeat()
        self._maybe_reconcile()
        try:
            with self._locked() as acct:
                self._sync(acct)
                holders = dict(acct.entries.get(key, ()))
        except OSError:
            return []
        if not holders:
            return []
        now = time.time()
        infos = self._read_nodes()
        out = []
        for node in sorted(holders):
            if node == self.node:
                continue
            info = infos.get(node)
            if info is None or not self._node_alive(info, now):
                continue
            root, nbytes = holders[node]
            out.append((node, os.path.join(root, key), nbytes))
        return out

    def holders(self, key: str) -> dict[str, tuple[str, int]]:
        """Raw registry state for one key (tests/introspection): every
        recorded holder, liveness NOT filtered."""
        try:
            with self._locked() as acct:
                self._sync(acct)
                return dict(acct.entries.get(key, ()))
        except OSError:
            return {}

    # -- reconcile (dead-node expiry) ----------------------------------------
    def _maybe_reconcile(self) -> None:
        acct = self._account()
        if not acct.loaded:
            try:
                with self._locked() as a:
                    self._sync(a)
            except OSError:
                return
        # reconcile_ts is shared through the journal header: one expiry
        # pass by any member satisfies the bound for all of them
        if (
            acct.reconcile_ts
            and (time.time() - acct.reconcile_ts) < self.node_ttl_s
        ):
            return
        self.reconcile()

    def reconcile(self) -> int:
        """Expire the registry entries (and heartbeat files) of dead
        nodes: stale heartbeat past the TTL, dead same-host PID, or no
        heartbeat at all (a retired member). Returns entries expired."""
        now = time.time()
        # bypass the nodes cache: expiry decisions need fresh evidence
        with self._cache_lock:
            self._nodes_cache = (0.0, {})
        infos = self._read_nodes()
        dead = {
            n for n, info in infos.items() if not self._node_alive(info, now)
        }
        expired = 0
        try:
            with self._locked() as acct:
                self._sync(acct)
                known = {
                    node
                    for holders in acct.entries.values()
                    for node in holders
                }
                dead |= {n for n in known if n not in infos}
                dead.discard(self.node)
                if dead:
                    for key in list(acct.entries):
                        holders = acct.entries[key]
                        for n in list(holders):
                            if n in dead:
                                del holders[n]
                                expired += 1
                        if not holders:
                            del acct.entries[key]
                self._rewrite(acct, reconcile_ts=now)
        except OSError:
            return expired
        for n in dead:
            try:
                os.unlink(self._hb_path(n))
            except OSError:
                pass
        return expired

    def retire(self) -> None:
        """Leave the cluster cleanly: drop every published entry and the
        heartbeat, so peers stop considering this node immediately
        instead of after a failed pull + TTL expiry."""
        self.unpublish_all()
        try:
            os.unlink(self._hb_path(self.node))
        except OSError:
            pass

    def snapshot(self) -> dict:
        """Registry introspection: entry count per node + live members."""
        per_node: dict[str, int] = {}
        try:
            with self._locked() as acct:
                self._sync(acct)
                for holders in acct.entries.values():
                    for node in holders:
                        per_node[node] = per_node.get(node, 0) + 1
        except OSError:
            pass
        return {
            "node": self.node,
            "entries_by_node": per_node,
            "live_nodes": sorted(self.live_nodes()),
        }
