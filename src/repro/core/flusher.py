"""Background flush-and-evict daemon + prefetcher (paper §3.3, §5.1).

"If only a single instance of Sea is called on a compute node, there will
only be a single flush and evict process." — the paper pairs one worker
with each Sea instance; we generalise to a small worker pool
(``SeaConfig.flush_workers``) so flushes of *independent keys* proceed
concurrently while per-key ``key_lock`` serialisation keeps any single
file's flush/evict atomic.

The daemon reacts to file-close events and also runs stateless scans of
the cache tiers on demand (so files written before the daemon started, or
by other processes sharing the tiers, are still picked up). Flushes are
atomic: copy to ``<dst>.sea_tmp`` on the base tier, then ``os.replace``;
eviction of a MOVEd file happens only after the rename commits, so readers
resolving the hierarchy always find a complete copy (fixes the paper's
§5.5 in-flight-access limitation). Every flush/evict transactionally
updates the capacity ledger, keeping placement's O(1) free-space counters
truthful without a rescan.

**Single-flusher coordination** (``SeaConfig.shared_ledger``): the paper
notes that "if Sea is launched many times on a given node, there will be
many flush and evict processes" — racing duplicate flushers over the same
hierarchy. In shared mode exactly one elected leader per hierarchy runs
the daemon: leadership is an ``fcntl`` lock on
``<base_root>/.sea_ledger/flusher.lock`` plus a heartbeat file rewritten
every ``leader_heartbeat_s``. Followers enqueue their close events into a
spool directory the leader drains; on leader death (the kernel releases
the lock) a follower whose staleness check fires takes over within two
heartbeats, rescans the cache tiers, and drains the spool.
"""

from __future__ import annotations

import fcntl
import json
import os
import queue
import sys
import threading
import time
import traceback
from urllib.parse import quote, unquote

from . import faults
from .extents import PART_SUFFIX
from .faults import TRANSIENT, classify
from .ledger import LEDGER_DIRNAME, TMP_SUFFIX
from .lists import Mode
from .seafs import SeaFS

_TMP_SUFFIX = TMP_SUFFIX  # one canonical staging suffix (ledger.py)

#: leadership lock paths held by THIS process. fcntl locks are owned per
#: (process, inode): a second Flusher in the same process would "win" the
#: lock trivially and closing its fd would drop the first one's — so
#: in-process contenders are arbitrated here instead of through fcntl.
_HELD_LEADER_LOCKS: set[str] = set()
_HELD_LEADER_LOCKS_GUARD = threading.Lock()


class Flusher:
    def __init__(self, fs: SeaFS):
        self.fs = fs
        self.config = fs.config
        self.n_workers = max(1, int(getattr(fs.config, "flush_workers", 1)))
        self._q: "queue.Queue[str | None]" = queue.Queue()
        self._pending: set[str] = set()   # keys queued but not yet picked up
        self._active: dict[str, bool] = {}  # being processed -> resubmit flag
        self._deferred: set[str] = set()  # skipped busy; await any close
        self._failed: dict[str, float] = {}  # key -> monotonic not-before:
                                             # failed flushes, retried on
                                             # idle ticks after a backoff
        self._draining = False            # suppress idle retries in drain()
        self._inflight = 0                # keys currently being processed
        self._cv = threading.Condition()  # guards the four fields above
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        #: cross-process coordination (shared_ledger mode only)
        self._coordinated = bool(getattr(fs.config, "shared_ledger", False))
        self._hb_interval = float(getattr(fs.config, "leader_heartbeat_s", 0.5))
        coord_dir = os.path.join(fs.hierarchy.base.roots[0], LEDGER_DIRNAME)
        self._lock_path = os.path.join(coord_dir, "flusher.lock")
        self._hb_path = os.path.join(coord_dir, "flusher.heartbeat")
        self._spool_dir = os.path.join(coord_dir, "spool")
        self._leader_fd: int | None = None
        self._leader_guard = threading.Lock()
        fs.add_close_listener(self._on_close)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Flusher":
        if not self._alive():
            self._stop.clear()
            self._threads = [
                threading.Thread(
                    target=self._run, name=f"sea-flusher-{i}", daemon=True
                )
                for i in range(self.n_workers)
            ]
            if self._coordinated:
                self._try_acquire_leadership()
                self._threads.append(
                    threading.Thread(
                        target=self._coordinate, name="sea-coordinator", daemon=True
                    )
                )
            for t in self._threads:
                t.start()
        return self

    def stop(self) -> None:
        try:
            self._stop.set()
            for _ in self._threads:
                self._q.put(None)
            for t in self._threads:
                t.join(timeout=30)
                if t.is_alive():
                    # a worker wedged in hung I/O must not look like a
                    # clean stop: surface it and count it (the daemon
                    # thread is abandoned; process exit reaps it)
                    print(
                        f"sea: flusher thread {t.name} still alive after a "
                        "30s join — abandoning it",
                        file=sys.stderr,
                    )
                    self.fs.telemetry.record_hung_thread_join()
        finally:
            # leadership MUST be returned even if a worker join blew up,
            # or every surviving follower waits out a dead lockfile holder
            self._release_leadership()

    def _alive(self) -> bool:
        return any(t.is_alive() for t in self._threads)

    # -- leader election (shared_ledger mode) ---------------------------------
    @property
    def is_leader(self) -> bool:
        """In coordinated mode: does this instance hold the flusher lock?
        Uncoordinated instances are trivially their own leader."""
        if not self._coordinated:
            return True
        return self._leader_fd is not None

    def _try_acquire_leadership(self) -> bool:
        with self._leader_guard:
            if self._leader_fd is not None:
                return True
            # realpath: two spellings of the same base root (symlinked
            # scratch dirs) must arbitrate on one registry key, or both
            # "win" the per-process fcntl lock
            lock_key = os.path.realpath(self._lock_path)
            with _HELD_LEADER_LOCKS_GUARD:
                if lock_key in _HELD_LEADER_LOCKS:
                    return False  # another instance in THIS process leads
            os.makedirs(self._spool_dir, exist_ok=True)
            fd = os.open(self._lock_path, os.O_RDWR | os.O_CREAT, 0o644)
            try:
                fcntl.lockf(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
            except OSError:
                os.close(fd)
                return False
            with _HELD_LEADER_LOCKS_GUARD:
                _HELD_LEADER_LOCKS.add(lock_key)
            os.ftruncate(fd, 0)
            os.pwrite(fd, str(os.getpid()).encode(), 0)
            self._leader_fd = fd
        self._write_heartbeat()
        return True

    def _release_leadership(self) -> None:
        with self._leader_guard:
            fd, self._leader_fd = self._leader_fd, None
            if fd is None:
                return
            with _HELD_LEADER_LOCKS_GUARD:
                _HELD_LEADER_LOCKS.discard(os.path.realpath(self._lock_path))
            hb = self._read_heartbeat()
            if hb is not None and hb.get("pid") == os.getpid():
                try:
                    os.unlink(self._hb_path)
                except OSError:
                    pass
            try:
                fcntl.lockf(fd, fcntl.LOCK_UN)
            finally:
                os.close(fd)

    def _write_heartbeat(self) -> None:
        tmp = f"{self._hb_path}.{os.getpid()}{_TMP_SUFFIX}"
        try:
            with open(tmp, "w") as f:
                json.dump({"pid": os.getpid(), "ts": time.time()}, f)
            os.replace(tmp, self._hb_path)  # atomic: readers never see a torn file
        except OSError:
            pass

    def _read_heartbeat(self) -> dict | None:
        try:
            with open(self._hb_path) as f:
                hb = json.load(f)
            return hb if isinstance(hb, dict) else None
        except (OSError, ValueError):
            return None

    def _heartbeat_stale(self) -> bool:
        hb = self._read_heartbeat()
        if hb is None:
            return True
        return time.time() - float(hb.get("ts", 0)) > self._hb_interval

    def _coordinate(self) -> None:
        """Leader: beat + drain the spool. Follower: watch the heartbeat and
        take over once it goes stale (the fcntl lock is only obtainable
        after the leader process actually died, so trying early is safe)."""
        while not self._stop.wait(self._hb_interval / 2):
            if self.fs.federation is not None:
                # piggyback the cluster-membership heartbeat on the
                # coordination tick (leader and follower alike: every
                # instance is its own federation node)
                self.fs.federation.maybe_heartbeat()
            if self.is_leader:
                self._write_heartbeat()
                self._drain_spool()
            elif self._heartbeat_stale() and self._try_acquire_leadership():
                # takeover: recover everything the dead leader left behind
                self.scan()
                self._drain_spool()

    # -- follower spool ---------------------------------------------------------
    def _spool_submit(self, key: str) -> None:
        """Followers don't flush; they hand the key to the leader through
        the spool directory (one file per key — resubmits coalesce)."""
        os.makedirs(self._spool_dir, exist_ok=True)
        path = os.path.join(self._spool_dir, quote(key, safe=""))
        tmp = f"{path}.{os.getpid()}{_TMP_SUFFIX}"
        try:
            with open(tmp, "w") as f:
                f.write(key)
            os.replace(tmp, path)
        except OSError:
            pass

    def _take_spool_entries(self) -> list[str]:
        """Claim (unlink) and return every spooled key."""
        try:
            names = os.listdir(self._spool_dir)
        except FileNotFoundError:
            return []
        keys = []
        for fn in sorted(names):
            if fn.endswith(_TMP_SUFFIX):
                continue
            try:
                os.unlink(os.path.join(self._spool_dir, fn))
            except OSError:
                continue  # another claimant got it first
            keys.append(unquote(fn))
        return keys

    def _drain_spool(self) -> int:
        keys = self._take_spool_entries()
        for key in keys:
            self.submit(key)
        return len(keys)

    def drain(self) -> None:
        """Final flush: process every pending + scannable file, then return.
        Called at application shutdown ('materialize onto long-term
        storage'). Correct under the worker pool: waits on an explicit
        queued+in-flight count rather than poking at the queue's private
        ``unfinished_tasks`` outside its mutex. A follower instead hands
        its keys to the leader and waits for the spool to empty.

        Durability contract: a flush that still fails by the end of the
        drain RAISES to the caller (the seed surfaced this through its
        dying worker's exception) — shutdown must never silently report
        success while a file never reached long-term storage."""
        self._draining = True
        try:
            self._drain_inner()
            self._raise_failed_sync()
        finally:
            self._draining = False

    def _raise_failed_sync(self) -> None:
        """Final synchronous pass over flushes that failed during the
        drain: transient blips heal here; a persistent error propagates
        (``process`` has no handler) so the caller knows durability was
        not achieved."""
        with self._cv:
            failed = sorted(self._failed)
            self._failed.clear()
        for key in failed:
            self.process(key)

    def _drain_inner(self) -> None:
        self.scan()
        if self._coordinated and not self.is_leader:
            if not self._drain_as_follower():
                return
            # became leader mid-drain: fall through and drain like one
        if not self._alive():
            # synchronous fallback: no daemon running
            self._process_all_sync()
            return
        stable = 0
        while True:
            if self._coordinated and self.is_leader:
                self._drain_spool()  # followers may still be handing us work
            with self._cv:
                while self._pending or self._inflight:
                    if not self._alive():
                        break
                    self._cv.wait(timeout=0.5)
            if not self._alive():
                self._process_all_sync()
                return
            if not (self._coordinated and self.is_leader):
                return
            # leader: only finish once spool AND queue are empty twice in a
            # row — a follower's entry can be mid-claim (unlinked by the
            # coordinator thread but not yet queued) at any single glance
            if self._spool_empty() and not self._pending and not self._inflight:
                stable += 1
                if stable >= 2:
                    return
                time.sleep(0.01)
            else:
                stable = 0

    def _spool_empty(self) -> bool:
        try:
            names = os.listdir(self._spool_dir)
        except FileNotFoundError:
            return True
        return all(n.endswith(_TMP_SUFFIX) for n in names)

    def _drain_as_follower(self) -> bool:
        """Wait until the leader drained the spool. Returns True iff this
        instance took leadership over (caller then drains as the leader).
        If no live leader materializes before the deadline, the leftovers
        are processed synchronously — data safety over single-flusher
        purity at shutdown."""
        deadline = time.time() + max(5.0, 10 * self._hb_interval)
        while time.time() < deadline:
            try:
                entries = [
                    n
                    for n in os.listdir(self._spool_dir)
                    if not n.endswith(_TMP_SUFFIX)
                ]
            except FileNotFoundError:
                entries = []
            if not entries:
                return False
            if self._heartbeat_stale() and self._try_acquire_leadership():
                return True
            time.sleep(min(0.05, self._hb_interval / 4))
        for key in self._take_spool_entries():
            self.process(key)
        return False

    # -- event plumbing --------------------------------------------------------
    def _on_close(self, key: str, writing: bool) -> None:
        with self._cv:
            deferred = key in self._deferred
            self._deferred.discard(key)
        if writing or deferred:
            # a read close matters too when a reader held the file busy
            # during an earlier flush attempt
            self.submit(key)

    def submit(self, key: str) -> None:
        if self._coordinated and not self.is_leader:
            self._spool_submit(key)
            return
        with self._cv:
            if key in self._active:
                # a worker is processing this key right now: flag it for
                # one more pass instead of dropping the event (the file
                # may have been rewritten under the in-flight flush)
                self._active[key] = True
                return
            if key in self._pending:
                return
            self._pending.add(key)
        self._q.put(key)

    def scan(self) -> int:
        """Stateless sweep of cache tiers for files needing flush/evict."""
        n = 0
        for tier in self.fs.hierarchy.cache_tiers:
            for root in tier.roots:
                for dirpath, dirs, files in os.walk(root):
                    if LEDGER_DIRNAME in dirs:
                        dirs.remove(LEDGER_DIRNAME)
                    for fn in files:
                        if fn.endswith(_TMP_SUFFIX):
                            # in-flight staging files are not keys; dead
                            # ones (crashed transfers) are reclaimed here
                            self.fs.transfer.maybe_reap_orphan(
                                os.path.join(dirpath, fn)
                            )
                            continue
                        if fn.endswith(PART_SUFFIX):
                            # partial extent replicas are never flush
                            # candidates: their base copy already exists
                            continue
                        key = os.path.relpath(os.path.join(dirpath, fn), root)
                        if self.fs.rules.mode(key) is not Mode.KEEP:
                            self.submit(key)
                            n += 1
        return n

    # -- workers ------------------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                key = self._q.get(timeout=self.config.flush_interval_s)
            except queue.Empty:
                self._maybe_retry_failed()
                continue
            if key is None:
                if self._stop.is_set():
                    break
                continue  # stale sentinel from a previous stop()
            with self._cv:
                self._pending.discard(key)
                self._active[key] = False
                self._inflight += 1
            try:
                try:
                    self.process(key)
                except Exception as e:
                    # a failed flush (exhausted transfer retries, device
                    # error) must not kill the worker thread — but it
                    # must not vanish either: count it, surface the
                    # traceback, and queue the key for a retry on the
                    # next idle tick (drain()/shutdown also re-scan)
                    self.fs.telemetry.record_flush_failure()
                    traceback.print_exc()
                    # backoff: a persistently failing key re-copies (and
                    # tracebacks) at most ~once per second, not once per
                    # idle tick. The shared errno table (repro.core.faults)
                    # stretches it 30x for permanent/capacity classes —
                    # EACCES or a full base tier will not heal in a second,
                    # and cache-root ENOSPC already tripped the breaker
                    # inside the engine instead of burning retries here.
                    backoff = max(1.0, 10 * self.config.flush_interval_s)
                    if classify(e) is not TRANSIENT:
                        backoff *= 30
                    with self._cv:
                        self._failed[key] = time.monotonic() + backoff
            finally:
                requeue = False
                with self._cv:
                    if self._active.pop(key, False):
                        # a submit arrived mid-process: queue one more pass
                        self._pending.add(key)
                        requeue = True
                    self._inflight -= 1
                    self._cv.notify_all()
                if requeue:
                    self._q.put(key)
                # re-check after every task as well as on idle ticks: a
                # sustained submit stream never leaves the queue empty,
                # and a failed key must still get its backed-off retry
                self._maybe_retry_failed()

    def _maybe_retry_failed(self) -> None:
        """Re-submit every failed flush whose backoff has elapsed (the
        engine's own retry/backoff absorbed the fast transients; this
        covers longer outages). The whole eligible backlog goes in one
        tick: after a mass failure — a tier dying and recovering — the
        old one-key-per-idle-tick behaviour drained N keys in
        N*flush_interval_s instead of letting the worker pool chew them
        concurrently. Suspended during drain() — a permanently failing
        key must not keep the pending set non-empty forever."""
        retries: list[str] = []
        with self._cv:
            if not self._draining:
                now = time.monotonic()
                retries = [k for k, nb in self._failed.items() if nb <= now]
                for k in retries:
                    del self._failed[k]
        for k in retries:
            self.submit(k)

    def _process_all_sync(self) -> None:
        while True:
            try:
                key = self._q.get_nowait()
            except queue.Empty:
                return
            if key is None:
                continue
            with self._cv:
                self._pending.discard(key)
                self._active[key] = False
            self.process(key)
            requeue = False
            with self._cv:
                if self._active.pop(key, False):
                    self._pending.add(key)
                    requeue = True
            if requeue:
                self._q.put(key)

    # -- the four modes ------------------------------------------------------------
    def process(self, key: str) -> Mode:
        mode = self.fs.rules.mode(key)
        if mode is Mode.KEEP:
            return mode
        with self.fs.key_lock(key):
            if self.fs.open_count(key):
                # busy: never move a file underneath the application (paper
                # §5.5 limitation). Defer to the NEXT close of this key —
                # an immediate requeue would busy-spin while it stays open.
                with self._cv:
                    self._deferred.add(key)
                return mode
            # ignore_negative: a spooled key from another process may never
            # have been seen locally — a negative entry must not hide it
            located = self.fs.resolver.resolve(key, ignore_negative=True)
            if located is None:
                return mode
            tier, real = located
            if tier.persistent:
                return mode  # already on long-term storage: nothing to do
            if mode in (Mode.COPY, Mode.MOVE):
                self._flush_one(key, real, tier)
            if mode in (Mode.MOVE, Mode.REMOVE):
                if not self._draining and self.fs.prefetcher.is_hot(key):
                    # predicted-hot: the readahead engine staged (or is
                    # staging) this key because the application is about
                    # to read it — evicting now would throw that work
                    # away. The flush above still ran; the evict retries
                    # on an idle tick once the hotness expires. drain()
                    # ignores hotness: shutdown durability wins.
                    with self._cv:
                        self._failed.setdefault(
                            key, time.monotonic() + 2 * self._hb_interval
                        )
                    return mode
                self._evict_one(key, real, tier)
        return mode

    def _flush_one(self, key: str, src: str, src_tier=None) -> None:
        base = self.fs.hierarchy.base
        base_root = base.roots[0]
        dst = os.path.join(base_root, key)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        try:
            sst = os.stat(src)
        except OSError:
            return  # vanished under the key lock's last release: nothing to do
        try:
            dst_st = os.stat(dst)
        except OSError:
            dst_st = None
        if (
            dst_st is not None
            and dst_st.st_mtime_ns >= sst.st_mtime_ns
            and dst_st.st_size == sst.st_size
        ):
            # already materialized and fresh. Nanosecond mtimes + size:
            # a coarse same-second getmtime() compare silently skipped
            # sources rewritten within one mtime tick of the last flush.
            # The engine copystats the source onto the committed copy, so
            # equality here means byte-for-byte freshness.
            return
        # the flusher only ever drains *away from* cache roots: the
        # destination is always the base tier, which the breaker never
        # quarantines — a sick root's files still reach durability while
        # nothing new is staged into it (placement filters it out)
        faults.fire("flusher.flush", path=src)
        result = self.fs.transfer.copy(
            src,
            dst,
            src_tier=src_tier,
            dst_tier=base,
            dst_root=base_root,
            key=key,
            admit="reserve",
        )
        self.fs.telemetry.record_flush(result.nbytes)

    def _evict_one(self, key: str, src: str, tier) -> None:
        try:
            nbytes = os.path.getsize(src)
            os.remove(src)
            root = tier.root_of(src)
            if root is not None:
                tier.note_removed(root, key)
            # one invalidation covers the move: the next resolve re-scans
            # and lands on the base copy (or nothing, for REMOVE mode)
            self.fs.resolver.invalidate(key)
            self.fs._fed_unpublish(key)
            self.fs.telemetry.record_evict(nbytes)
        except OSError:
            pass

    # -- prefetch -----------------------------------------------------------------
    def prefetch(self) -> int:
        """Stage .sea_prefetchlist matches from the base tier into the
        fastest cache tier with room ("For files to be prefetched, they
        must be located within Sea's mountpoint at startup").

        Candidates are collected in one walk, then staged through the
        transfer engine's bounded worker pool — independent copies
        overlap (``transfer_workers`` at a time), which is where the
        wall-clock win over the seed's serial loop lives."""
        base = self.fs.hierarchy.base
        candidates: list[str] = []
        seen: set[str] = set()  # multi-root base: one stage per key
        for root in base.roots:
            for dirpath, dirs, files in os.walk(root):
                if LEDGER_DIRNAME in dirs:
                    dirs.remove(LEDGER_DIRNAME)
                for fn in files:
                    real = os.path.join(dirpath, fn)
                    if fn.endswith(_TMP_SUFFIX):
                        # half-written staging files are not prefetchable
                        # keys; reclaim provably-dead ones
                        self.fs.transfer.maybe_reap_orphan(real)
                        continue
                    if fn.endswith(PART_SUFFIX):
                        continue  # extent plane bookkeeping, not a key
                    key = os.path.relpath(real, root)
                    if key not in seen and self.fs.rules.prefetch_match(key):
                        seen.add(key)
                        candidates.append(key)
        if not candidates:
            return 0
        # SeaFS.stage_to_cache holds the key lock on a transfer worker, so
        # staging stays atomic against evicts/flushes of the same key and
        # shares one code path with the data pipeline
        return sum(self.fs.transfer.map(self.fs.stage_to_cache, candidates))


class Sea:
    """Top-level convenience bundle: SeaFS + running Flusher pool.

    >>> sea = Sea(config).start()
    >>> with sea.fs.open(f"{config.mount}/x.bin", "wb") as f: ...
    >>> sea.shutdown()      # drain & stop (final flush)
    """

    def __init__(self, config):
        self.fs = SeaFS(config)
        self.flusher = Flusher(self.fs)
        self._started = False

    def start(self) -> "Sea":
        if self._started:
            return self  # idempotent: a second start must not re-prefetch
        self.flusher.start()
        if self.fs.config.prefetchlist:
            self.flusher.prefetch()
        self._started = True
        return self

    def shutdown(self) -> None:
        try:
            # stop speculative readahead first: pending predictions are
            # cancelled and counted, and no new staging races the drain
            self.fs.prefetcher.stop()
            # drain may RAISE when a flush never succeeded (durability
            # contract) — leadership and workers must still be released
            try:
                self.flusher.drain()
            finally:
                self.flusher.stop()
        finally:
            self._started = False
            # stop the transfer pool too (it restarts lazily if reused)
            self.fs.transfer.close()
        if self.fs.federation is not None:
            # leave the cluster cleanly: nobody maintains our registry
            # entries once this process exits, so drop them now instead
            # of making peers burn a failed pull + TTL expiry on them
            try:
                self.fs.federation.retire()
            except OSError:
                pass
        if self.fs.config.shared_ledger:
            # leave this process's counters next to the shared store so the
            # workflow can aggregate telemetry across all its workers
            stats_dir = os.path.join(
                self.fs.hierarchy.base.roots[0], LEDGER_DIRNAME, "telemetry"
            )
            try:
                self.fs.telemetry.export(
                    os.path.join(stats_dir, f"{os.getpid()}.json")
                )
            except OSError:
                pass

    def __enter__(self) -> "Sea":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()
