"""Background flush-and-evict daemon + prefetcher (paper §3.3, §5.1).

"If only a single instance of Sea is called on a compute node, there will
only be a single flush and evict process." — one worker thread per SeaFS.

The daemon reacts to file-close events and also runs periodic stateless
scans of the cache tiers (so files written before the daemon started, or
by other processes sharing the tiers, are still picked up). Flushes are
atomic: copy to ``<dst>.sea_tmp`` on the base tier, then ``os.replace``;
eviction of a MOVEd file happens only after the rename commits, so readers
resolving the hierarchy always find a complete copy (fixes the paper's
§5.5 in-flight-access limitation).
"""

from __future__ import annotations

import os
import queue
import shutil
import threading

from .lists import Mode, resolve_mode
from .seafs import SeaFS

_TMP_SUFFIX = ".sea_tmp"


class Flusher:
    def __init__(self, fs: SeaFS):
        self.fs = fs
        self.config = fs.config
        self._q: "queue.Queue[str | None]" = queue.Queue()
        self._pending: set[str] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._thread: threading.Thread | None = None
        fs.add_close_listener(self._on_close)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Flusher":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="sea-flusher", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._q.put(None)
        if self._thread is not None:
            self._thread.join(timeout=30)

    def drain(self) -> None:
        """Final flush: process every pending + scannable file, then return.
        Called at application shutdown ('materialize onto long-term
        storage')."""
        self.scan()
        while True:
            with self._lock:
                empty = not self._pending and self._q.unfinished_tasks == 0
            if empty and self._idle.is_set():
                break
            if self._thread is None or not self._thread.is_alive():
                # synchronous fallback: no daemon running
                self._process_all_sync()
                break
            self._idle.wait(timeout=0.5)

    # -- event plumbing --------------------------------------------------------
    def _on_close(self, key: str, writing: bool) -> None:
        if not writing:
            return
        self.submit(key)

    def submit(self, key: str) -> None:
        with self._lock:
            if key in self._pending:
                return
            self._pending.add(key)
        self._q.put(key)

    def scan(self) -> int:
        """Stateless sweep of cache tiers for files needing flush/evict."""
        n = 0
        for tier in self.fs.hierarchy.cache_tiers:
            for root in tier.roots:
                for dirpath, _dirs, files in os.walk(root):
                    for fn in files:
                        if fn.endswith(_TMP_SUFFIX):
                            continue
                        key = os.path.relpath(os.path.join(dirpath, fn), root)
                        mode = resolve_mode(
                            key, self.config.flushlist, self.config.evictlist
                        )
                        if mode is not Mode.KEEP:
                            self.submit(key)
                            n += 1
        return n

    # -- worker ------------------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                key = self._q.get(timeout=self.config.flush_interval_s)
            except queue.Empty:
                continue
            if key is None:
                self._q.task_done()
                break
            self._idle.clear()
            try:
                self.process(key)
            finally:
                with self._lock:
                    self._pending.discard(key)
                self._q.task_done()
                if self._q.empty():
                    self._idle.set()

    def _process_all_sync(self) -> None:
        while True:
            try:
                key = self._q.get_nowait()
            except queue.Empty:
                return
            if key is not None:
                self.process(key)
            with self._lock:
                self._pending.discard(key)
            self._q.task_done()

    # -- the four modes ------------------------------------------------------------
    def process(self, key: str) -> Mode:
        mode = resolve_mode(key, self.config.flushlist, self.config.evictlist)
        if mode is Mode.KEEP:
            return mode
        with self.fs.key_lock(key):
            if self.fs.open_count(key):
                # busy: requeue for a later pass rather than moving underneath
                # the application (paper §5.5 limitation, handled here).
                self.submit(key)
                return mode
            located = self.fs.hierarchy.locate(key)
            if located is None:
                return mode
            tier, real = located
            if tier.persistent:
                return mode  # already on long-term storage: nothing to do
            if mode in (Mode.COPY, Mode.MOVE):
                self._flush_one(key, real)
            if mode in (Mode.MOVE, Mode.REMOVE):
                self._evict_one(key, real)
        return mode

    def _flush_one(self, key: str, src: str) -> None:
        base_root = self.fs.hierarchy.base.roots[0]
        dst = os.path.join(base_root, key)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        if os.path.exists(dst) and os.path.getmtime(dst) >= os.path.getmtime(src):
            return  # already materialized and fresh
        tmp = dst + _TMP_SUFFIX
        shutil.copyfile(src, tmp)
        os.replace(tmp, dst)  # atomic commit
        self.fs.telemetry.record_flush(os.path.getsize(dst))

    def _evict_one(self, key: str, src: str) -> None:
        try:
            nbytes = os.path.getsize(src)
            os.remove(src)
            self.fs.telemetry.record_evict(nbytes)
        except OSError:
            pass

    # -- prefetch -----------------------------------------------------------------
    def prefetch(self) -> int:
        """Stage .sea_prefetchlist matches from the base tier into the
        fastest cache tier with room ("For files to be prefetched, they
        must be located within Sea's mountpoint at startup")."""
        from .lists import matches

        total = 0
        base = self.fs.hierarchy.base
        for root in base.roots:
            for dirpath, _dirs, files in os.walk(root):
                for fn in files:
                    real = os.path.join(dirpath, fn)
                    key = os.path.relpath(real, root)
                    if not matches(key, self.config.prefetchlist):
                        continue
                    with self.fs.key_lock(key):
                        cur = self.fs.hierarchy.locate(key)
                        if cur is not None and not cur[0].persistent:
                            continue  # already cached
                        nbytes = os.path.getsize(real)
                        slot = self.fs.policy.select_cache_for_prefetch(nbytes)
                        if slot is None:
                            continue
                        _tier, croot = slot
                        dst = os.path.join(croot, key)
                        os.makedirs(os.path.dirname(dst), exist_ok=True)
                        tmp = dst + _TMP_SUFFIX
                        shutil.copyfile(real, tmp)
                        os.replace(tmp, dst)
                        self.fs.telemetry.record_prefetch(nbytes)
                        total += nbytes
        return total


class Sea:
    """Top-level convenience bundle: SeaFS + running Flusher.

    >>> sea = Sea(config).start()
    >>> with sea.fs.open(f"{config.mount}/x.bin", "wb") as f: ...
    >>> sea.shutdown()      # drain & stop (final flush)
    """

    def __init__(self, config):
        self.fs = SeaFS(config)
        self.flusher = Flusher(self.fs)

    def start(self) -> "Sea":
        self.flusher.start()
        if self.fs.config.prefetchlist:
            self.flusher.prefetch()
        return self

    def shutdown(self) -> None:
        self.flusher.drain()
        self.flusher.stop()

    def __enter__(self) -> "Sea":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()
