"""Background flush-and-evict daemon + prefetcher (paper §3.3, §5.1).

"If only a single instance of Sea is called on a compute node, there will
only be a single flush and evict process." — the paper pairs one worker
with each Sea instance; we generalise to a small worker pool
(``SeaConfig.flush_workers``) so flushes of *independent keys* proceed
concurrently while per-key ``key_lock`` serialisation keeps any single
file's flush/evict atomic.

The daemon reacts to file-close events and also runs stateless scans of
the cache tiers on demand (so files written before the daemon started, or
by other processes sharing the tiers, are still picked up). Flushes are
atomic: copy to ``<dst>.sea_tmp`` on the base tier, then ``os.replace``;
eviction of a MOVEd file happens only after the rename commits, so readers
resolving the hierarchy always find a complete copy (fixes the paper's
§5.5 in-flight-access limitation). Every flush/evict transactionally
updates the capacity ledger, keeping placement's O(1) free-space counters
truthful without a rescan.
"""

from __future__ import annotations

import os
import queue
import shutil
import threading

from .lists import Mode, resolve_mode
from .seafs import SeaFS

_TMP_SUFFIX = ".sea_tmp"


class Flusher:
    def __init__(self, fs: SeaFS):
        self.fs = fs
        self.config = fs.config
        self.n_workers = max(1, int(getattr(fs.config, "flush_workers", 1)))
        self._q: "queue.Queue[str | None]" = queue.Queue()
        self._pending: set[str] = set()   # keys queued but not yet picked up
        self._active: dict[str, bool] = {}  # being processed -> resubmit flag
        self._deferred: set[str] = set()  # skipped busy; await any close
        self._inflight = 0                # keys currently being processed
        self._cv = threading.Condition()  # guards the four fields above
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        fs.add_close_listener(self._on_close)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Flusher":
        if not self._alive():
            self._stop.clear()
            self._threads = [
                threading.Thread(
                    target=self._run, name=f"sea-flusher-{i}", daemon=True
                )
                for i in range(self.n_workers)
            ]
            for t in self._threads:
                t.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join(timeout=30)

    def _alive(self) -> bool:
        return any(t.is_alive() for t in self._threads)

    def drain(self) -> None:
        """Final flush: process every pending + scannable file, then return.
        Called at application shutdown ('materialize onto long-term
        storage'). Correct under the worker pool: waits on an explicit
        queued+in-flight count rather than poking at the queue's private
        ``unfinished_tasks`` outside its mutex."""
        self.scan()
        if not self._alive():
            # synchronous fallback: no daemon running
            self._process_all_sync()
            return
        with self._cv:
            while self._pending or self._inflight:
                if not self._alive():
                    break
                self._cv.wait(timeout=0.5)
        if not self._alive():
            self._process_all_sync()

    # -- event plumbing --------------------------------------------------------
    def _on_close(self, key: str, writing: bool) -> None:
        with self._cv:
            deferred = key in self._deferred
            self._deferred.discard(key)
        if writing or deferred:
            # a read close matters too when a reader held the file busy
            # during an earlier flush attempt
            self.submit(key)

    def submit(self, key: str) -> None:
        with self._cv:
            if key in self._active:
                # a worker is processing this key right now: flag it for
                # one more pass instead of dropping the event (the file
                # may have been rewritten under the in-flight flush)
                self._active[key] = True
                return
            if key in self._pending:
                return
            self._pending.add(key)
        self._q.put(key)

    def scan(self) -> int:
        """Stateless sweep of cache tiers for files needing flush/evict."""
        n = 0
        for tier in self.fs.hierarchy.cache_tiers:
            for root in tier.roots:
                for dirpath, _dirs, files in os.walk(root):
                    for fn in files:
                        if fn.endswith(_TMP_SUFFIX):
                            continue
                        key = os.path.relpath(os.path.join(dirpath, fn), root)
                        mode = resolve_mode(
                            key, self.config.flushlist, self.config.evictlist
                        )
                        if mode is not Mode.KEEP:
                            self.submit(key)
                            n += 1
        return n

    # -- workers ------------------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                key = self._q.get(timeout=self.config.flush_interval_s)
            except queue.Empty:
                continue
            if key is None:
                if self._stop.is_set():
                    break
                continue  # stale sentinel from a previous stop()
            with self._cv:
                self._pending.discard(key)
                self._active[key] = False
                self._inflight += 1
            try:
                self.process(key)
            finally:
                requeue = False
                with self._cv:
                    if self._active.pop(key, False):
                        # a submit arrived mid-process: queue one more pass
                        self._pending.add(key)
                        requeue = True
                    self._inflight -= 1
                    self._cv.notify_all()
                if requeue:
                    self._q.put(key)

    def _process_all_sync(self) -> None:
        while True:
            try:
                key = self._q.get_nowait()
            except queue.Empty:
                return
            if key is None:
                continue
            with self._cv:
                self._pending.discard(key)
                self._active[key] = False
            self.process(key)
            requeue = False
            with self._cv:
                if self._active.pop(key, False):
                    self._pending.add(key)
                    requeue = True
            if requeue:
                self._q.put(key)

    # -- the four modes ------------------------------------------------------------
    def process(self, key: str) -> Mode:
        mode = resolve_mode(key, self.config.flushlist, self.config.evictlist)
        if mode is Mode.KEEP:
            return mode
        with self.fs.key_lock(key):
            if self.fs.open_count(key):
                # busy: never move a file underneath the application (paper
                # §5.5 limitation). Defer to the NEXT close of this key —
                # an immediate requeue would busy-spin while it stays open.
                with self._cv:
                    self._deferred.add(key)
                return mode
            located = self.fs.hierarchy.locate(key)
            if located is None:
                return mode
            tier, real = located
            if tier.persistent:
                return mode  # already on long-term storage: nothing to do
            if mode in (Mode.COPY, Mode.MOVE):
                self._flush_one(key, real)
            if mode in (Mode.MOVE, Mode.REMOVE):
                self._evict_one(key, real, tier)
        return mode

    def _flush_one(self, key: str, src: str) -> None:
        base = self.fs.hierarchy.base
        base_root = base.roots[0]
        dst = os.path.join(base_root, key)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        if os.path.exists(dst) and os.path.getmtime(dst) >= os.path.getmtime(src):
            return  # already materialized and fresh
        tmp = dst + _TMP_SUFFIX
        shutil.copyfile(src, tmp)
        os.replace(tmp, dst)  # atomic commit
        nbytes = os.path.getsize(dst)
        base.note_written(base_root, key, nbytes)
        self.fs.telemetry.record_flush(nbytes)

    def _evict_one(self, key: str, src: str, tier) -> None:
        try:
            nbytes = os.path.getsize(src)
            os.remove(src)
            root = tier.root_of(src)
            if root is not None:
                tier.note_removed(root, key)
            self.fs.telemetry.record_evict(nbytes)
        except OSError:
            pass

    # -- prefetch -----------------------------------------------------------------
    def prefetch(self) -> int:
        """Stage .sea_prefetchlist matches from the base tier into the
        fastest cache tier with room ("For files to be prefetched, they
        must be located within Sea's mountpoint at startup")."""
        from .lists import matches

        total = 0
        base = self.fs.hierarchy.base
        for root in base.roots:
            for dirpath, _dirs, files in os.walk(root):
                for fn in files:
                    real = os.path.join(dirpath, fn)
                    key = os.path.relpath(real, root)
                    if not matches(key, self.config.prefetchlist):
                        continue
                    with self.fs.key_lock(key):
                        cur = self.fs.hierarchy.locate(key)
                        if cur is not None and not cur[0].persistent:
                            continue  # already cached
                        nbytes = os.path.getsize(real)
                        slot = self.fs.policy.select_cache_for_prefetch(nbytes)
                        if slot is None:
                            continue
                        ctier, croot = slot
                        dst = os.path.join(croot, key)
                        os.makedirs(os.path.dirname(dst), exist_ok=True)
                        tmp = dst + _TMP_SUFFIX
                        shutil.copyfile(real, tmp)
                        os.replace(tmp, dst)
                        ctier.note_written(croot, key, nbytes)
                        self.fs.telemetry.record_prefetch(nbytes)
                        total += nbytes
        return total


class Sea:
    """Top-level convenience bundle: SeaFS + running Flusher pool.

    >>> sea = Sea(config).start()
    >>> with sea.fs.open(f"{config.mount}/x.bin", "wb") as f: ...
    >>> sea.shutdown()      # drain & stop (final flush)
    """

    def __init__(self, config):
        self.fs = SeaFS(config)
        self.flusher = Flusher(self.fs)

    def start(self) -> "Sea":
        self.flusher.start()
        if self.fs.config.prefetchlist:
            self.flusher.prefetch()
        return self

    def shutdown(self) -> None:
        self.flusher.drain()
        self.flusher.stop()

    def __enter__(self) -> "Sea":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()
