"""Per-root health tracking and circuit breakers for cache roots.

Every cache root gets a sliding-window record of recent I/O outcomes
(success/failure + latency) fed by the seafs open paths, the transfer
engine, the flusher, and federation pulls.  The window drives a circuit
breaker per root:

    closed ── error rate over threshold, ENOSPC, or deadline abort ──▶ open
    open ──────────────── ``open_s`` elapsed ──────────────────▶ half-open
    half-open ── probe success ──▶ closed        ── probe failure ──▶ open

While a breaker is open the root is *quarantined*: `PlacementPolicy`
excludes it from `eligible_roots` / prefetch selection, reads degrade to
other roots, peers, or base, and the flusher keeps draining *from* it but
nothing new is staged *into* it.  The base (persistent) tier is never
tracked — call sites only feed cache-tier events, because base has no
"elsewhere" to degrade to.

Lock discipline (enforced by seacheck's lock_discipline rule): all breaker
state — the ``_roots`` map and each root's ``br_state`` / ``br_opened`` /
``br_probe`` / ``ev_window`` — is mutated only under ``self._lock``.
"""

from __future__ import annotations

import threading
import time
from collections import deque

from .faults import CAPACITY, classify

#: breaker states
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"


class _RootState:
    __slots__ = ("ev_window", "br_state", "br_opened", "br_probe", "lat_sum", "lat_n")

    def __init__(self) -> None:
        self.ev_window: deque = deque()  # (monotonic_ts, is_error)
        self.br_state = CLOSED
        self.br_opened = 0.0  # monotonic ts of last open transition
        self.br_probe = 0.0  # monotonic ts the outstanding half-open probe was claimed
        self.lat_sum = 0.0  # success latency accumulator (window-aligned-ish)
        self.lat_n = 0


class HealthTracker:
    """Sliding-window error stats + a circuit breaker per cache root."""

    def __init__(
        self,
        *,
        window_s: float = 30.0,
        error_threshold: float = 0.5,
        min_events: int = 4,
        open_s: float = 2.0,
        telemetry=None,
    ) -> None:
        self.window_s = float(window_s)
        self.error_threshold = float(error_threshold)
        self.min_events = int(min_events)
        self.open_s = float(open_s)
        self.telemetry = telemetry
        self._lock = threading.Lock()
        self._roots: dict[str, _RootState] = {}

    # -- event feed ---------------------------------------------------------

    def record_success(self, root: str, seconds: float = 0.0) -> None:
        """Feed a successful I/O against `root`; closes a half-open breaker."""
        with self._lock:
            st = self._state_locked(root)
            st.ev_window.append((time.monotonic(), False))
            st.lat_sum += seconds
            st.lat_n += 1
            self._purge_locked(st)
            if st.br_state is not CLOSED:
                # probe (or concurrent straggler) succeeded: re-admit the root
                st.br_state = CLOSED
                st.br_probe = 0.0
                st.ev_window.clear()

    def record_failure(self, root: str, exc: BaseException | None = None) -> None:
        """Feed a failed I/O against `root`; may open the breaker.

        ENOSPC/EDQUOT (capacity) failures trip the breaker immediately —
        retrying cannot free bytes, so the root is routed around at once.
        """
        now = time.monotonic()
        with self._lock:
            st = self._state_locked(root)
            st.ev_window.append((now, True))
            self._purge_locked(st)
            if st.br_state is HALF_OPEN:
                self._open_locked(st, now, requarantine=True)
                return
            if st.br_state is OPEN:
                return
            if exc is not None and classify(exc) == CAPACITY:
                self._open_locked(st, now)
                return
            n = len(st.ev_window)
            errs = sum(1 for _, is_err in st.ev_window if is_err)
            if n >= self.min_events and errs / n >= self.error_threshold:
                self._open_locked(st, now)

    def trip(self, root: str, reason: str = "") -> None:
        """Open the breaker immediately (deadline abort, ENOSPC, operator)."""
        now = time.monotonic()
        with self._lock:
            st = self._state_locked(root)
            st.ev_window.append((now, True))
            self._purge_locked(st)
            if st.br_state is not OPEN:
                self._open_locked(st, now, requarantine=st.br_state is HALF_OPEN)

    # -- queries ------------------------------------------------------------

    def admissible(self, root: str) -> bool:
        """Pure eligibility query: *would* :meth:`allow` admit work on
        `root` right now?  Never mutates breaker state — enumeration
        (``eligible_roots``, spill/eviction eligibility checks) must not
        consume the single half-open probe slot, or a recovered root's
        re-admission can be starved by queries that never touch it.
        Call :meth:`allow` only at the point a root is actually chosen
        for I/O."""
        with self._lock:
            st = self._roots.get(root)
            if st is None or st.br_state is CLOSED:
                return True
            now = time.monotonic()
            if st.br_state is OPEN:
                return now - st.br_opened >= self.open_s
            # half-open: admissible only once the outstanding probe staled
            return now - st.br_probe >= self.open_s

    def allow(self, root: str) -> bool:
        """May new work be placed on `root`?  Claims the probe slot —
        call only when the root is actually chosen for I/O (use
        :meth:`admissible` for side-effect-free filtering).

        Closed → yes.  Open → no, until ``open_s`` has elapsed; then exactly
        one caller is admitted as the half-open probe (a stale unresolved
        probe claim expires after another ``open_s``, admitting a new probe
        so a crashed prober cannot wedge re-admission forever).
        """
        with self._lock:
            st = self._roots.get(root)
            if st is None or st.br_state is CLOSED:
                return True
            now = time.monotonic()
            if st.br_state is OPEN:
                if now - st.br_opened < self.open_s:
                    return False
                st.br_state = HALF_OPEN
                st.br_probe = now
                return True
            # half-open: one outstanding probe at a time
            if now - st.br_probe >= self.open_s:
                st.br_probe = now
                return True
            return False

    def quarantined(self, root: str) -> bool:
        """True while the breaker is open (no probe admission implied)."""
        with self._lock:
            st = self._roots.get(root)
            return st is not None and st.br_state is not CLOSED

    def breaker_state(self, root: str) -> str:
        with self._lock:
            st = self._roots.get(root)
            return CLOSED if st is None else st.br_state

    def snapshot(self) -> dict:
        """Per-root view for telemetry export / debugging."""
        out = {}
        with self._lock:
            now = time.monotonic()
            for root, st in self._roots.items():
                n = len(st.ev_window)
                errs = sum(1 for _, is_err in st.ev_window if is_err)
                out[root] = {
                    "state": st.br_state,
                    "events": n,
                    "errors": errs,
                    "error_rate": (errs / n) if n else 0.0,
                    "mean_latency_s": (st.lat_sum / st.lat_n) if st.lat_n else 0.0,
                    "open_for_s": (now - st.br_opened) if st.br_state is not CLOSED else 0.0,
                }
        return out

    # -- internals ----------------------------------------------------------

    def _state_locked(self, root: str) -> _RootState:  # seacheck: holds-lock
        st = self._roots.get(root)
        if st is None:
            st = self._roots[root] = _RootState()
        return st

    def _purge_locked(self, st: _RootState) -> None:  # seacheck: holds-lock
        horizon = time.monotonic() - self.window_s
        win = st.ev_window
        while win and win[0][0] < horizon:
            win.popleft()
        if st.lat_n > 4096:  # keep the latency mean roughly window-sized
            st.lat_sum /= 2.0
            st.lat_n //= 2

    def _open_locked(self, st: _RootState, now: float, requarantine: bool = False) -> None:  # seacheck: holds-lock
        st.br_state = OPEN
        st.br_opened = now
        st.br_probe = 0.0
        t = self.telemetry
        if t is not None:
            t.record_breaker_open()
            if not requarantine:
                t.record_root_quarantine()
