"""SeaMount — Python-level I/O interception (the LD_PRELOAD analogue).

The paper intercepts POSIX file-system calls made through glibc so that
*unmodified* applications get data placement for free. Our applications are
Python programs, so the equivalent syscall boundary is Python's I/O layer:
``builtins.open`` plus the ``os``/``os.path``/``shutil`` entry points that
take paths. Inside a ``SeaMount`` context every such call whose path falls
under the Sea mountpoint is translated through :class:`SeaFS`; everything
else passes through untouched — exactly the wrapper structure of Fig. 1.

Like the paper's library, interception requires no change to the wrapped
code, no root, and keeps Sea stateless. A real deployment on a TPU fleet
would additionally ship the original C++ LD_PRELOAD library for non-Python
tools; both enter the same placement logic.

    with SeaMount(sea.fs):
        run_unmodified_pipeline()          # open()/np.save()/... redirected
"""

from __future__ import annotations

import builtins
import os
import shutil
import threading

from .seafs import SeaFS

_PATCH_LOCK = threading.Lock()
_ACTIVE: list["SeaMount"] = []


class SeaMount:
    def __init__(self, fs: SeaFS):
        self.fs = fs
        self._saved: dict = {}
        # precompiled mount-prefix rejector: the overwhelmingly common
        # case inside a mount context is a path that has nothing to do
        # with Sea — it must cost ONE str.startswith, not an fspath +
        # abspath round-trip. Definitive only for normalized absolute
        # strings (anything else falls through to the full probe); the
        # heuristic itself lives in SeaFS.fast_path_class so this layer
        # and SeaFS.open can never classify the same path differently.
        classify = fs.fast_path_class

        def fast_nonsea(p):
            return classify(p) is False

        self._fast_nonsea = fast_nonsea

    # -- wrappers --------------------------------------------------------------
    def _wrap_open(self, orig):
        fs = self.fs
        fast_nonsea = self._fast_nonsea

        def sea_open(file, mode="r", *a, **kw):
            if fast_nonsea(file):
                return orig(file, mode, *a, **kw)
            try:
                is_sea = isinstance(file, (str, os.PathLike)) and fs.is_sea_path(
                    os.fspath(file)
                )
            except (TypeError, ValueError):
                is_sea = False
            if not is_sea:
                return orig(file, mode, *a, **kw)
            return fs.open(os.fspath(file), mode, *a, **kw)

        return sea_open

    def _path_fn(self, orig, handler):
        fs = self.fs
        fast_nonsea = self._fast_nonsea

        def wrapper(path, *a, **kw):
            if fast_nonsea(path):
                return orig(path, *a, **kw)
            # the guard covers ONLY the fspath/is_sea_path probe: an error
            # raised by the Sea handler itself must propagate, not silently
            # re-execute the operation against the original function.
            try:
                is_sea = isinstance(path, (str, os.PathLike)) and fs.is_sea_path(
                    os.fspath(path)
                )
            except (TypeError, ValueError):
                is_sea = False
            if is_sea:
                return handler(os.fspath(path), *a, **kw)
            return orig(path, *a, **kw)

        return wrapper

    def _two_path_fn(self, orig, handler):
        fs = self.fs
        fast_nonsea = self._fast_nonsea

        def wrapper(src, dst, *a, **kw):
            if fast_nonsea(src) and fast_nonsea(dst):
                return orig(src, dst, *a, **kw)
            try:
                s = isinstance(src, (str, os.PathLike)) and fs.is_sea_path(
                    os.fspath(src)
                )
                d = isinstance(dst, (str, os.PathLike)) and fs.is_sea_path(
                    os.fspath(dst)
                )
            except (TypeError, ValueError):
                s = d = False
            if s or d:
                return handler(os.fspath(src), os.fspath(dst), *a, **kw)
            return orig(src, dst, *a, **kw)

        return wrapper

    # -- context -----------------------------------------------------------------
    def __enter__(self) -> "SeaMount":
        fs = self.fs
        with _PATCH_LOCK:
            if _ACTIVE:
                raise RuntimeError("nested SeaMount contexts are not supported")
            _ACTIVE.append(self)
            self._saved = {
                "open": builtins.open,
                "os.stat": os.stat,
                "os.remove": os.remove,
                "os.unlink": os.unlink,
                "os.rename": os.rename,
                "os.replace": os.replace,
                "os.listdir": os.listdir,
                "os.makedirs": os.makedirs,
                "os.path.exists": os.path.exists,
                "os.path.getsize": os.path.getsize,
                "os.path.isfile": os.path.isfile,
                "os.path.isdir": os.path.isdir,
                "shutil.copyfile": shutil.copyfile,
                "os.truncate": os.truncate,
                "os.ftruncate": os.ftruncate,
            }
            builtins.open = self._wrap_open(builtins.open)
            os.stat = self._path_fn(os.stat, fs.stat)
            os.remove = self._path_fn(os.remove, fs.remove)
            os.unlink = self._path_fn(os.unlink, fs.remove)
            os.rename = self._two_path_fn(os.rename, fs.rename)
            os.replace = self._two_path_fn(os.replace, fs.rename)
            os.listdir = self._path_fn(os.listdir, fs.listdir)
            # fs.makedirs mirrors os.makedirs(name, mode=0o777,
            # exist_ok=False) exactly — the positional mode argument is
            # forwarded, not dropped (the old lambda routed *a nowhere)
            os.makedirs = self._path_fn(os.makedirs, fs.makedirs)
            os.path.exists = self._path_fn(os.path.exists, fs.exists)
            os.path.getsize = self._path_fn(os.path.getsize, fs.getsize)
            # fs.isfile checks the *located real path* with os.path.isfile:
            # Tier.locate uses lexists, which is also true for directories.
            os.path.isfile = self._path_fn(os.path.isfile, fs.isfile)
            # virtual directories exist wherever any tier placed a child —
            # served from the resolver's directory index
            os.path.isdir = self._path_fn(os.path.isdir, fs.isdir)

            # sea↔sea copies stream through the TransferEngine (chunked
            # copy_file_range, atomic commit, ledger admission) instead
            # of a Python copyfileobj loop; follow_symlinks is honored
            # outward and rejected into the mount, never silently
            # dereferenced
            shutil.copyfile = self._two_path_fn(shutil.copyfile, fs.copyfile)
            # a truncate that bypasses Sea would drift the capacity
            # ledger and leave partial extent replicas serving dead data
            wrapped_truncate = self._path_fn(os.truncate, fs.truncate)

            def sea_truncate(path, length):
                # os.truncate also accepts an int fd: route those through
                # the same fd-index settlement as os.ftruncate
                if isinstance(path, int):
                    return fs.ftruncate(path, length)
                return wrapped_truncate(path, length)

            os.truncate = sea_truncate
            orig_ftruncate = os.ftruncate

            def sea_ftruncate(fd, length):
                if isinstance(fd, int):
                    return fs.ftruncate(fd, length)
                return orig_ftruncate(fd, length)

            os.ftruncate = sea_ftruncate
        return self

    def __exit__(self, *exc) -> None:
        with _PATCH_LOCK:
            builtins.open = self._saved["open"]
            os.stat = self._saved["os.stat"]
            os.remove = self._saved["os.remove"]
            os.unlink = self._saved["os.unlink"]
            os.rename = self._saved["os.rename"]
            os.replace = self._saved["os.replace"]
            os.listdir = self._saved["os.listdir"]
            os.makedirs = self._saved["os.makedirs"]
            os.path.exists = self._saved["os.path.exists"]
            os.path.getsize = self._saved["os.path.getsize"]
            os.path.isfile = self._saved["os.path.isfile"]
            os.path.isdir = self._saved["os.path.isdir"]
            shutil.copyfile = self._saved["shutil.copyfile"]
            os.truncate = self._saved["os.truncate"]
            os.ftruncate = self._saved["os.ftruncate"]
            _ACTIVE.clear()
