"""Capacity-accounting ledger — the O(1) placement hot path.

The seed implementation was literally stateless: every capped-root
eligibility check re-walked the whole root (``os.walk``), so each
``open(..., "w")`` under the mount cost O(files-in-cache) — the exact
metadata-scaling failure the paper designed around. This module replaces
those rescans with per-root used-byte counters that are updated
transactionally on create / write-close / flush / evict / remove, plus
in-flight *write reservations*, so ``free_bytes`` / ``eligible_roots`` /
``select`` become dictionary lookups guarded by per-root (sharded) locks.

The filesystem remains the ultimate source of truth: a periodic (and
on-demand) *reconciliation* scan re-walks a root and rebuilds its account,
absorbing external writers that bypassed Sea (other processes, direct
``os`` calls outside a :class:`~repro.core.intercept.SeaMount`). Between
reconciles the ledger is an optimistically-maintained invariant::

    account.used == sum(size of files under root)        (eventually)
    free(root)   == capacity - used - reserved           (capped roots)

Reservations close the seed's over-commit window: a file opened for write
occupies no bytes on disk until data is flushed, so N concurrent writers
all saw the same ``free`` and could collectively blow past the cap. Each
open-for-write now reserves ``max_file_size`` up front and commits the
actual size on close.
"""

from __future__ import annotations

import os
import threading
import time

#: Per-root metadata directory used by the cross-process shared ledger and
#: the flusher's leader-election/spool machinery. It lives *inside* each
#: root, so every capacity scan must skip it — its journal/heartbeat files
#: are bookkeeping, not cached application data.
LEDGER_DIRNAME = ".sea_ledger"


class Reservation:
    """An in-flight write budget held against one root.

    Created by :meth:`CapacityLedger.reserve`; resolved exactly once via
    :meth:`CapacityLedger.commit` (write finished, actual size known) or
    :meth:`CapacityLedger.release` (write abandoned).
    """

    __slots__ = ("root", "nbytes", "active")

    def __init__(self, root: str, nbytes: int):
        self.root = root
        self.nbytes = nbytes
        self.active = True

    def __repr__(self) -> str:  # pragma: no cover
        state = "active" if self.active else "resolved"
        return f"Reservation({self.root!r}, {self.nbytes}, {state})"


class _RootAccount:
    """Mutable per-root state; every field is guarded by ``lock``."""

    __slots__ = ("lock", "files", "used", "reserved", "last_reconcile", "version")

    def __init__(self):
        self.lock = threading.Lock()
        self.files: dict[str, int] = {}   # relpath -> size in bytes
        self.used = 0                     # == sum(files.values())
        self.reserved = 0                 # in-flight write budgets
        self.last_reconcile: float | None = None  # monotonic; None = never
        self.version = 0                  # bumped by every files/used mutation


#: in-flight staging files of the transfer engine — not data, and a
#: failed transfer unlinks them without a ledger notification, so every
#: capacity scan must skip them or a reconcile racing a chunked copy
#: records phantom bytes nothing ever removes
TMP_SUFFIX = ".sea_tmp"


def file_disk_usage(path: str) -> int:
    """Bytes a file actually occupies on its device: ``st_blocks * 512``
    capped at the logical size. For dense files this is exactly
    ``st_size`` (allocation rounds *up* to the block size, and the cap
    keeps byte-exact accounting for them); for the sparse ``.sea_part``
    partial replicas of the extent plane it counts only the staged
    blocks — a 100 GB part file with one 32 MiB extent staged occupies
    32 MiB, not 100 GB. Raises OSError like ``os.path.getsize``."""
    st = os.stat(path)
    return min(st.st_size, st.st_blocks * 512)


def scan_root(root: str) -> dict[str, int]:
    """Walk one root and return {relpath: disk usage}. This is the seed's
    O(n) scan, demoted from the per-call hot path to the reconcile path.
    Sparse-aware: partial extent replicas count their staged blocks, not
    their (hole-dominated) logical size."""
    files: dict[str, int] = {}
    for dirpath, dirnames, filenames in os.walk(root):
        if LEDGER_DIRNAME in dirnames:
            dirnames.remove(LEDGER_DIRNAME)
        for fn in filenames:
            if fn.endswith(TMP_SUFFIX):
                continue
            p = os.path.join(dirpath, fn)
            try:
                files[os.path.relpath(p, root)] = file_disk_usage(p)
            except OSError:
                pass
    return files


class CapacityLedger:
    """Per-root used/reserved byte accounting, shared by all tiers of one
    :class:`~repro.core.tiers.Hierarchy`. Locks are sharded by root, so
    same-level roots (e.g. 6 local SSDs) never contend with each other."""

    def __init__(
        self,
        reconcile_interval_s: float = 5.0,
        telemetry=None,
    ):
        self.reconcile_interval_s = reconcile_interval_s
        self.telemetry = telemetry  # attached by SeaFS after construction
        self._accounts: dict[str, _RootAccount] = {}
        self._accounts_lock = threading.Lock()

    # -- account plumbing ----------------------------------------------------
    def _account(self, root: str) -> _RootAccount:
        acct = self._accounts.get(root)
        if acct is None:
            with self._accounts_lock:
                acct = self._accounts.setdefault(root, _RootAccount())
        return acct

    def _record_hit(self) -> None:
        if self.telemetry is not None:
            self.telemetry.record_ledger_hit()

    # -- hot-path queries (O(1)) ---------------------------------------------
    def used_bytes(self, root: str) -> int:
        """Used bytes under ``root`` — dictionary lookup, reconciling first
        if the account is stale (or was never initialised)."""
        acct = self._account(root)
        self._maybe_reconcile(root, acct)
        self._record_hit()
        with acct.lock:
            return acct.used

    def reserved_bytes(self, root: str) -> int:
        acct = self._account(root)
        with acct.lock:
            return acct.reserved

    def file_size(self, root: str, key: str) -> int | None:
        acct = self._account(root)
        with acct.lock:
            return acct.files.get(key)

    # -- transactional updates -----------------------------------------------
    def note_written(self, root: str, key: str, nbytes: int) -> None:
        """A file landed (or changed size) under ``root``."""
        acct = self._account(root)
        with acct.lock:
            acct.used += nbytes - acct.files.get(key, 0)
            acct.files[key] = nbytes
            acct.version += 1

    def note_removed(self, root: str, key: str) -> None:
        """A file was evicted/removed from under ``root``."""
        acct = self._account(root)
        with acct.lock:
            old = acct.files.pop(key, None)
            if old is not None:
                acct.used -= old
                acct.version += 1

    def reserve(self, root: str, nbytes: int) -> Reservation:
        """Reserve an in-flight write budget against ``root``."""
        acct = self._account(root)
        with acct.lock:
            acct.reserved += nbytes
        return Reservation(root, nbytes)

    def commit(self, res: Reservation, key: str, nbytes: int) -> None:
        """Write finished: release the reservation and record the actual
        on-disk size — one critical section, so free() never double-counts."""
        acct = self._account(res.root)
        with acct.lock:
            if res.active:
                # clamp: forget() (e.g. Tier.wipe) may have zeroed the
                # account while this write was in flight — going negative
                # would permanently overstate free space
                acct.reserved = max(acct.reserved - res.nbytes, 0)
                res.active = False
            acct.used += nbytes - acct.files.get(key, 0)
            acct.files[key] = nbytes
            acct.version += 1

    def try_reserve(
        self, root: str, nbytes: int, *, capacity: int, required: int
    ) -> Reservation | None:
        """Atomic admission: re-check eligibility and reserve in one
        critical section. A plain check-then-:meth:`reserve` is a TOCTOU
        window — two writers of different keys can both observe enough
        free space and jointly over-commit a capped root.

        The paper's ``required = n_procs * max_file_size`` headroom exists
        to cover every *untracked* concurrent writer; reservations track
        them explicitly, so existing reservations count toward that
        headroom rather than on top of it: admit iff
        ``capacity - used >= max(required, reserved + nbytes)``. With no
        writes in flight this is exactly the paper rule; under concurrency
        it admits writers that provably fit while keeping
        ``used + reserved <= capacity`` invariant."""
        acct = self._account(root)
        self._maybe_reconcile(root, acct)
        self._record_hit()
        with acct.lock:
            if capacity - acct.used >= max(required, acct.reserved + nbytes):
                acct.reserved += nbytes
                return Reservation(root, nbytes)
        return None

    def release(self, res: Reservation) -> None:
        """Write abandoned: return the budget without recording a file."""
        acct = self._account(res.root)
        with acct.lock:
            if res.active:
                acct.reserved = max(acct.reserved - res.nbytes, 0)
                res.active = False

    # -- reconciliation --------------------------------------------------------
    def _maybe_reconcile(self, root: str, acct: _RootAccount) -> None:
        with acct.lock:
            last = acct.last_reconcile
        if last is not None and (
            time.monotonic() - last
        ) < self.reconcile_interval_s:
            return
        self.reconcile(root)

    def reconcile(self, root: str) -> int:
        """Re-walk ``root`` and rebuild its account from the filesystem,
        absorbing external writers/removers. Returns the current used-byte
        count. Reservations are preserved — they track writes that have not
        reached the disk yet, which a walk cannot see.

        The rebuild is version-guarded: if a transactional update lands
        while the walk is in flight, the walk's snapshot is stale and is
        DISCARDED (the deltas are exact for Sea-mediated traffic; external
        writers get absorbed at the next quiet reconcile). Wholesale
        replacement from a racing snapshot would silently lose commits."""
        acct = self._account(root)
        with acct.lock:
            v0 = acct.version
        files = scan_root(root)
        with acct.lock:
            if acct.version == v0:
                acct.files = files
                acct.used = sum(files.values())
            acct.last_reconcile = time.monotonic()
            used = acct.used
        if self.telemetry is not None:
            self.telemetry.record_ledger_reconcile()
        return used

    def forget(self, root: str) -> None:
        """Drop a root's account (e.g. after ``Tier.wipe``)."""
        with self._accounts_lock:
            self._accounts.pop(root, None)

    # -- verification ----------------------------------------------------------
    def verify(self, root: str) -> tuple[int, int]:
        """(ledger_used, fresh_walk_used) *without* reconciling — equal iff
        the ledger is consistent with the filesystem right now."""
        acct = self._account(root)
        walk_used = sum(scan_root(root).values())
        with acct.lock:
            return acct.used, walk_used

    def snapshot(self) -> dict:
        out = {}
        with self._accounts_lock:
            roots = list(self._accounts.items())
        for root, acct in roots:
            with acct.lock:
                out[root] = {
                    "used": acct.used,
                    "reserved": acct.reserved,
                    "files": len(acct.files),
                }
        return out
