"""Flush/evict/prefetch list semantics (paper §3.3, Table 1).

Memory management in Sea is application-specific, configured via glob lists.
A file's *mode* is resolved from membership in the flush and evict lists:

    ============  ==============  ==============
    Mode          .sea_flushlist  .sea_evictlist
    ============  ==============  ==============
    COPY          yes             no
    REMOVE        no              yes
    MOVE          yes             yes
    KEEP          no              no
    ============  ==============  ==============
"""

from __future__ import annotations

import enum
import fnmatch
import os


class Mode(enum.Enum):
    COPY = "copy"      # materialize to base tier, keep in cache
    REMOVE = "remove"  # drop from cache, never persisted
    MOVE = "move"      # materialize then drop from cache (copy-and-remove)
    KEEP = "keep"      # stay in cache, never persisted


def _norm(relpath: str) -> str:
    return relpath.replace(os.sep, "/").lstrip("/")


def matches(relpath: str, patterns: tuple[str, ...]) -> bool:
    """fnmatch against the full mount-relative path and the basename,
    so users can write either ``results/*.npy`` or ``*.log``."""
    rel = _norm(relpath)
    base = os.path.basename(rel)
    for pat in patterns:
        p = _norm(pat)
        if fnmatch.fnmatch(rel, p) or fnmatch.fnmatch(base, p):
            return True
    return False


def resolve_mode(
    relpath: str,
    flushlist: tuple[str, ...],
    evictlist: tuple[str, ...],
) -> Mode:
    flush = matches(relpath, flushlist)
    evict = matches(relpath, evictlist)
    if flush and evict:
        return Mode.MOVE
    if flush:
        return Mode.COPY
    if evict:
        return Mode.REMOVE
    return Mode.KEEP
