"""Flush/evict/prefetch list semantics (paper §3.3, Table 1).

Memory management in Sea is application-specific, configured via glob lists.
A file's *mode* is resolved from membership in the flush and evict lists:

    ============  ==============  ==============
    Mode          .sea_flushlist  .sea_evictlist
    ============  ==============  ==============
    COPY          yes             no
    REMOVE        no              yes
    MOVE          yes             yes
    KEEP          no              no
    ============  ==============  ==============
"""

from __future__ import annotations

import enum
import fnmatch
import os
import re
import threading


class Mode(enum.Enum):
    COPY = "copy"      # materialize to base tier, keep in cache
    REMOVE = "remove"  # drop from cache, never persisted
    MOVE = "move"      # materialize then drop from cache (copy-and-remove)
    KEEP = "keep"      # stay in cache, never persisted


def _norm(relpath: str) -> str:
    return relpath.replace(os.sep, "/").lstrip("/")


def matches(relpath: str, patterns: tuple[str, ...]) -> bool:
    """fnmatch against the full mount-relative path and the basename,
    so users can write either ``results/*.npy`` or ``*.log``."""
    rel = _norm(relpath)
    base = os.path.basename(rel)
    for pat in patterns:
        p = _norm(pat)
        if fnmatch.fnmatch(rel, p) or fnmatch.fnmatch(base, p):
            return True
    return False


def resolve_mode(
    relpath: str,
    flushlist: tuple[str, ...],
    evictlist: tuple[str, ...],
) -> Mode:
    flush = matches(relpath, flushlist)
    evict = matches(relpath, evictlist)
    if flush and evict:
        return Mode.MOVE
    if flush:
        return Mode.COPY
    if evict:
        return Mode.REMOVE
    return Mode.KEEP


def _compile(patterns: tuple[str, ...]) -> re.Pattern | None:
    """One alternation regex for a whole glob list (None when empty).
    ``fnmatch.translate`` anchors each branch with ``\\Z``, so a ``match``
    against the full relpath (and separately the basename) reproduces the
    per-pattern ``fnmatch`` semantics in a single pass."""
    pats = [_norm(p) for p in patterns]
    if not pats:
        return None
    return re.compile("|".join(f"(?:{fnmatch.translate(p)})" for p in pats))


class CompiledRules:
    """Flush/evict/prefetch lists compiled once, mode resolution memoized.

    The seed re-ran O(patterns) ``fnmatch`` calls per file on every close
    and every flusher pass; here each list is one compiled alternation
    regex and each key's :class:`Mode` is computed once. The memo is
    bounded (cleared wholesale past ``_CACHE_MAX``) so pathological
    key churn cannot grow it without limit.
    """

    _CACHE_MAX = 65536

    def __init__(
        self,
        flushlist: tuple[str, ...] = (),
        evictlist: tuple[str, ...] = (),
        prefetchlist: tuple[str, ...] = (),
    ):
        self.flushlist = tuple(flushlist)
        self.evictlist = tuple(evictlist)
        self.prefetchlist = tuple(prefetchlist)
        self._flush = _compile(self.flushlist)
        self._evict = _compile(self.evictlist)
        self._prefetch = _compile(self.prefetchlist)
        self._modes: dict[str, Mode] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _match(rx: re.Pattern | None, rel: str, base: str) -> bool:
        return rx is not None and (
            rx.match(rel) is not None or rx.match(base) is not None
        )

    def mode(self, relpath: str) -> Mode:
        """Memoized Table-1 mode of one mount-relative key."""
        m = self._modes.get(relpath)
        if m is not None:
            return m
        rel = _norm(relpath)
        base = os.path.basename(rel)
        flush = self._match(self._flush, rel, base)
        evict = self._match(self._evict, rel, base)
        if flush and evict:
            m = Mode.MOVE
        elif flush:
            m = Mode.COPY
        elif evict:
            m = Mode.REMOVE
        else:
            m = Mode.KEEP
        with self._lock:
            if len(self._modes) >= self._CACHE_MAX:
                self._modes.clear()
            self._modes[relpath] = m
        return m

    def prefetch_match(self, relpath: str) -> bool:
        rel = _norm(relpath)
        return self._match(self._prefetch, rel, os.path.basename(rel))
