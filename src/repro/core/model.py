"""The Sea and Lustre makespan performance model (paper §3.4, Eqs. 1–11).

All data quantities are bytes, bandwidths bytes/s, times seconds.
Variable names follow the paper:

    c   compute nodes                 N   network bandwidth per node
    s   Lustre storage nodes          d   Lustre storage disks (OSTs)
    p   parallel processes per node   d_r/d_w  per-OST read/write bandwidth
    C_r/C_w  page-cache (memory) read/write bandwidth per node
    g   local disks per compute node  G_r/G_w  local-disk read/write bandwidth
    t   tmpfs capacity per node       r   capacity per local disk
    F   size of a single workflow file

Workload:
    D_I  input bytes         D_m  intermediate bytes       D_f  final bytes
"""

from __future__ import annotations

from dataclasses import dataclass, replace

MiB = float(1 << 20)
GiB = float(1 << 30)


@dataclass(frozen=True)
class ClusterSpec:
    c: int = 5              # compute nodes (paper default: 5)
    s: int = 4              # Lustre data nodes
    d: int = 44             # OSTs (4 nodes x 11 disks)
    N: float = 3125 * MiB   # 25 GbE
    d_r: float = 250 * MiB  # per-OST HDD read bw
    d_w: float = 121 * MiB  # per-OST write bw (Table 2 single-stream write)
    C_r: float = 6676.48 * MiB   # tmpfs/page-cache read (Table 2)
    C_w: float = 2560.00 * MiB   # tmpfs/page-cache write (Table 2)
    G_r: float = 501.70 * MiB    # local SSD read (Table 2)
    G_w: float = 426.00 * MiB    # local SSD write (Table 2)
    g: int = 6              # local disks per node
    t: float = 126 * GiB    # tmpfs space per node
    r: float = 447 * GiB    # capacity per local disk
    p: int = 6              # parallel processes per node
    # --- simulator-only calibration (not part of the paper's model) ------
    # Per-stream client limits and aggregate backend limits, calibrated so
    # the simulated cluster reproduces the paper's measured behaviour
    # (speedup ~1x at c=1, ~2.4x at the base condition, ~3x at p=32, and
    # the Exp-4 above-model-bounds Lustre degradation at 30+ processes).
    L_stream_w: float = 430 * MiB   # single client write stream to Lustre
    L_stream_r: float = 1381 * MiB  # single client read stream (Table 2)
    L_backend_w: float = 44 * 90 * MiB   # OSS/HDD collective write limit
    L_backend_r: float = 44 * 250 * MiB  # OSS/HDD collective read limit
    # User-space copy streams (the flush daemon) lack the client's
    # write-behind aggregation; their collective backend efficiency is
    # lower. Calibrated against the paper's Fig. 3 ratios (3.5x / 1.3x).
    flush_efficiency: float = 0.75
    # MDS/RPC contention: once concurrent write streams exceed the OST
    # count, collective backend throughput degrades (paper §4.2: 'too many
    # incoming requests to the server at 30+ parallel processes, that
    # performance declined above model bounds').
    mds_beta: float = 0.06

    def with_(self, **kw) -> "ClusterSpec":
        return replace(self, **kw)


@dataclass(frozen=True)
class Workload:
    """The incrementation application (paper Alg. 1): B blocks of F bytes,
    n iterations; iteration i reads file i-1 and writes file i (tasks
    communicate via the file system), the n-th file is the final output."""

    B: int = 1000
    F: float = 617 * MiB
    n: int = 10

    @property
    def D_I(self) -> float:
        return self.B * self.F

    @property
    def D_m(self) -> float:
        return (self.n - 1) * self.B * self.F

    @property
    def D_f(self) -> float:
        return self.B * self.F

    @property
    def total_written(self) -> float:
        return self.D_m + self.D_f


# ----------------------------------------------------------------- Lustre
def lustre_read_bw(cl: ClusterSpec) -> float:
    """Eq. 2:  L_r = min(cN, sN, d_r * min(d, cp))"""
    return min(cl.c * cl.N, cl.s * cl.N, cl.d_r * min(cl.d, cl.c * cl.p))


def lustre_write_bw(cl: ClusterSpec) -> float:
    """Eq. 3:  L_w = min(cN, sN, d_w * min(d, cp))"""
    return min(cl.c * cl.N, cl.s * cl.N, cl.d_w * min(cl.d, cl.c * cl.p))


def lustre_makespan(w: Workload, cl: ClusterSpec) -> float:
    """Eq. 1:  M_l = D_r/L_r + D_w/L_w  (no page-cache benefit).

    D_r = input + re-read intermediates; D_w = intermediates + finals.
    """
    D_r = w.D_I + w.D_m
    D_w = w.D_m + w.D_f
    return D_r / lustre_read_bw(cl) + D_w / lustre_write_bw(cl)


def pagecache_makespan(w: Workload, cl: ClusterSpec) -> float:
    """Eq. 4:  M_c = D_cr/(c*C_r) + D_cw/(c*C_w) — all I/O in memory."""
    return w.D_m / (cl.c * cl.C_r) + (w.D_m + w.D_f) / (cl.c * cl.C_w)


def lustre_cached_makespan(w: Workload, cl: ClusterSpec) -> float:
    """Eq. 5:  M_lc = D_I/L_r + M_c — everything but the first read cached."""
    return w.D_I / lustre_read_bw(cl) + pagecache_makespan(w, cl)


# -------------------------------------------------------------------- Sea
def sea_tier_volumes(w: Workload, cl: ClusterSpec) -> dict:
    """Spill-over volumes of Eqs. 8–10 (no eviction, as in the paper's
    experiments: only last-iteration files were flushed/evicted)."""
    reserve = cl.p * w.F
    # Eq. 8 volumes — tmpfs
    tmpfs_room = max(cl.c * (cl.t - reserve), 0.0)
    D_tr = min(w.D_m, tmpfs_room)
    D_tw = min(w.D_m + w.D_f, tmpfs_room)
    # Eq. 9 volumes — local disks
    disk_room = max(cl.c * (cl.g * cl.r - reserve), 0.0)
    D_gr = min(max(w.D_m - D_tr, 0.0), disk_room)
    D_gw = min(max(w.D_m + w.D_f - D_tw, 0.0), disk_room)
    # Eq. 10 volumes — Lustre spill
    D_Lr = max(w.D_m - D_gr - D_tr, 0.0)
    D_Lw = max(w.D_m + w.D_f - D_gw - D_tw, 0.0)
    return dict(D_tr=D_tr, D_tw=D_tw, D_gr=D_gr, D_gw=D_gw, D_Lr=D_Lr, D_Lw=D_Lw)


def sea_makespan(w: Workload, cl: ClusterSpec) -> float:
    """Eqs. 7–10:  M_S = M_SL + M_Sg + M_St (upper bound: no page cache)."""
    v = sea_tier_volumes(w, cl)
    M_St = v["D_tr"] / (cl.c * cl.C_r) + v["D_tw"] / (cl.c * cl.C_w)       # Eq. 8
    M_Sg = v["D_gr"] / (cl.g * cl.c * cl.G_r) + v["D_gw"] / (cl.g * cl.c * cl.G_w)  # Eq. 9
    M_SL = (
        w.D_I / lustre_read_bw(cl)
        + v["D_Lr"] / lustre_read_bw(cl)
        + v["D_Lw"] / lustre_write_bw(cl)
    )                                                                       # Eq. 10
    return M_SL + M_Sg + M_St                                               # Eq. 7


def sea_cached_makespan(w: Workload, cl: ClusterSpec) -> float:
    """Eq. 11:  M_Sc = D_I/L_r + D_m/(c*C_r) + (D_m+D_f)/(c*C_w)
    — identical lower bound to Lustre's."""
    return (
        w.D_I / lustre_read_bw(cl)
        + w.D_m / (cl.c * cl.C_r)
        + (w.D_m + w.D_f) / (cl.c * cl.C_w)
    )


# ------------------------------------------------------------------ bounds
def lustre_bounds(w: Workload, cl: ClusterSpec) -> tuple[float, float]:
    """(best, worst) = (Eq. 5 page-cache bound, Eq. 1 no-cache bound)."""
    return lustre_cached_makespan(w, cl), lustre_makespan(w, cl)


def sea_bounds(w: Workload, cl: ClusterSpec) -> tuple[float, float]:
    """(best, worst) = (Eq. 11, Eq. 7)."""
    return sea_cached_makespan(w, cl), sea_makespan(w, cl)


def sea_flush_all_extra(w: Workload, cl: ClusterSpec) -> float:
    """Copy-all mode: every byte written must ALSO be read back from its
    cache tier and written to Lustre (the paper's Fig. 3 overhead when no
    compute masks the flush)."""
    v = sea_tier_volumes(w, cl)
    flush_src_read = (
        v["D_tw"] / (cl.c * cl.C_r) + v["D_gw"] / (cl.g * cl.c * cl.G_r)
    )
    flush_write = (v["D_tw"] + v["D_gw"]) / lustre_write_bw(cl)
    return flush_src_read + flush_write


def sea_flush_all_makespan(w: Workload, cl: ClusterSpec) -> float:
    return sea_makespan(w, cl) + sea_flush_all_extra(w, cl)
