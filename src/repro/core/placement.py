"""Tier-selection policy (paper §3.1.2).

"Sea will then go through the hierarchy of available storage devices and
select the fastest storage device with sufficient available space."

Eligibility: a root is eligible if ``free >= n_procs * max_file_size`` —
Sea cannot predict output sizes, so it reserves worst-case room for every
concurrent writer ("the number of threads multiplied by the file size does
not exceed storage space"). Same-level roots are picked by random shuffle:
no metadata server, no locking — decentralization over optimal packing.

With the capacity ledger attached (the default), ``free`` is an O(1)
counter lookup and additionally discounts *in-flight write reservations*:
each open-for-write holds a ``max_file_size`` budget against its root
(:meth:`reserve_write`) until the close commits the actual size. This
tracks the ``n_procs * max_file_size`` headroom per-root as writes happen,
instead of re-deriving it from a filesystem walk on every call.
"""

from __future__ import annotations

import random

from .ledger import Reservation
from .tiers import Hierarchy, Tier


class PlacementPolicy:
    def __init__(
        self,
        hierarchy: Hierarchy,
        *,
        max_file_size: int,
        n_procs: int,
        rng: random.Random | None = None,
        health=None,
    ):
        self.hierarchy = hierarchy
        self.max_file_size = max_file_size
        self.n_procs = n_procs
        self.rng = rng or random.Random()
        #: HealthTracker (bound by SeaFS): quarantined cache roots are
        #: excluded from selection until their breaker re-admits them —
        #: the base tier is never filtered (unconditional fallback)
        self.health = health

    def _root_allowed(self, tier: Tier, root: str) -> bool:
        """Side-effect-free eligibility filter: never claims the breaker's
        half-open probe slot (enumeration must not starve re-admission —
        see :meth:`HealthTracker.admissible`)."""
        if self.health is None or tier.spec.persistent:
            return True
        return self.health.admissible(root)

    def claim_root(self, tier: Tier, root: str) -> bool:
        """Claim `root` for I/O that is actually about to happen: a closed
        breaker is a free pass; a re-admitting breaker hands this caller
        the single half-open probe slot (False = someone else holds it,
        re-select). Call only at the point a root is *chosen*, never while
        merely enumerating candidates."""
        if self.health is None or tier.spec.persistent:
            return True
        return self.health.allow(root)

    @property
    def required_bytes(self) -> int:
        return self.max_file_size * self.n_procs

    def eligible_roots(self, tier: Tier) -> list[str]:
        roots = list(tier.roots)
        self.rng.shuffle(roots)  # paper: "selected by Sea via a random shuffling"
        return [
            r
            for r in roots
            if self._root_allowed(tier, r)
            and tier.admissible(
                r, required=self.required_bytes, nbytes=self.max_file_size
            )
        ]

    def select(self) -> tuple[Tier, str]:
        """Fastest tier/root with sufficient space; the base tier is the
        unconditional fallback (there is nowhere slower to go)."""
        for tier in self.hierarchy.cache_tiers:
            roots = self.eligible_roots(tier)
            if roots:
                return tier, roots[0]
        base = self.hierarchy.base
        roots = self.eligible_roots(base)
        return base, roots[0] if roots else base.roots[0]

    def place_new(
        self, *, reserve: bool, make_room=None
    ) -> tuple[Tier, str, Reservation | None]:
        """Full placement of a *new* file: select the fastest eligible
        root and (optionally) atomically admit the write against it.

        ``make_room`` (LRU eviction hook) is consulted whenever selection
        falls through to the base tier while cache tiers exist: if it
        frees space, selection re-runs. A lost admission race re-selects
        (up to 8 attempts) so concurrent writers of different keys can
        never jointly over-commit a capped root; the base tier is the
        unconditional fallback.
        """
        for _attempt in range(8):
            tier, root = self.select()
            if (
                make_room is not None
                and tier is self.hierarchy.base
                and self.hierarchy.cache_tiers
            ):
                if make_room():
                    tier, root = self.select()
            if not reserve:
                if tier is self.hierarchy.base or self.claim_root(tier, root):
                    return tier, root, None
                continue  # lost the half-open probe slot: re-select
            if tier is self.hierarchy.base:
                # unconditional fallback: there is nowhere slower to go
                return tier, root, self.reserve_write(tier, root)
            admitted, res = self.acquire_write(tier, root)
            if admitted:
                # the root is definitely getting this write: claim the
                # breaker probe slot last, so a lost admission race never
                # burns the probe without I/O happening
                if self.claim_root(tier, root):
                    return tier, root, res
                self.release_write(tier, res)
        tier = self.hierarchy.base
        root = tier.roots[0]
        return tier, root, self.reserve_write(tier, root)

    # -- in-flight write budgets (ledger-backed; no-ops when stateless) -----
    def reserve_write(self, tier: Tier, root: str) -> Reservation | None:
        """Hold a worst-case (``max_file_size``) budget for one in-flight
        write so concurrent writers cannot collectively over-commit a root
        whose bytes have not reached the disk yet."""
        return tier.reserve_write(root, self.max_file_size)

    def acquire_write(
        self, tier: Tier, root: str
    ) -> tuple[bool, Reservation | None]:
        """Admission for a *new* file on a selected root: atomically
        re-check eligibility and reserve. Returns (admitted, reservation).
        Capped roots use the ledger's single-critical-section check; on a
        lost race the caller re-selects. Uncapped roots (statvfs-backed)
        cannot meaningfully over-commit at this scale, so they reserve
        unconditionally."""
        if tier.ledger is None:
            return True, None
        if tier.spec.capacity is None:
            return True, tier.reserve_write(root, self.max_file_size)
        res = tier.ledger.try_reserve(
            root,
            self.max_file_size,
            capacity=tier.spec.capacity,
            required=self.required_bytes,
        )
        return (res is not None), res

    def commit_write(
        self, tier: Tier, res: Reservation | None, root: str, key: str, nbytes: int
    ) -> None:
        """Write finished: swap the reservation for the actual file size."""
        tier.commit_write(res, root, key, nbytes)

    def release_write(self, tier: Tier, res: Reservation | None) -> None:
        """Write abandoned: return the budget untouched."""
        tier.release_write(res)

    def select_cache_for_prefetch(self, nbytes: int) -> tuple[Tier, str] | None:
        """Fastest cache root that can hold ``nbytes`` (prefetch staging)."""
        for tier in self.hierarchy.cache_tiers:
            roots = list(tier.roots)
            self.rng.shuffle(roots)
            for r in roots:
                if (
                    self._root_allowed(tier, r)
                    and tier.free_bytes(r) >= max(nbytes, self.required_bytes)
                    and self.claim_root(tier, r)  # chosen: claim the probe
                ):
                    return tier, r
        return None
