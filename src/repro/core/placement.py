"""Tier-selection policy (paper §3.1.2).

"Sea will then go through the hierarchy of available storage devices and
select the fastest storage device with sufficient available space."

Eligibility: a root is eligible if ``free >= n_procs * max_file_size`` —
Sea cannot predict output sizes, so it reserves worst-case room for every
concurrent writer ("the number of threads multiplied by the file size does
not exceed storage space"). Same-level roots are picked by random shuffle:
no metadata server, no locking — decentralization over optimal packing.
"""

from __future__ import annotations

import random

from .tiers import Hierarchy, Tier


class PlacementPolicy:
    def __init__(
        self,
        hierarchy: Hierarchy,
        *,
        max_file_size: int,
        n_procs: int,
        rng: random.Random | None = None,
    ):
        self.hierarchy = hierarchy
        self.max_file_size = max_file_size
        self.n_procs = n_procs
        self.rng = rng or random.Random()

    @property
    def required_bytes(self) -> int:
        return self.max_file_size * self.n_procs

    def eligible_roots(self, tier: Tier) -> list[str]:
        roots = list(tier.roots)
        self.rng.shuffle(roots)  # paper: "selected by Sea via a random shuffling"
        return [r for r in roots if tier.free_bytes(r) >= self.required_bytes]

    def select(self) -> tuple[Tier, str]:
        """Fastest tier/root with sufficient space; the base tier is the
        unconditional fallback (there is nowhere slower to go)."""
        for tier in self.hierarchy.cache_tiers:
            roots = self.eligible_roots(tier)
            if roots:
                return tier, roots[0]
        base = self.hierarchy.base
        roots = self.eligible_roots(base)
        return base, roots[0] if roots else base.roots[0]

    def select_cache_for_prefetch(self, nbytes: int) -> tuple[Tier, str] | None:
        """Fastest cache root that can hold ``nbytes`` (prefetch staging)."""
        for tier in self.hierarchy.cache_tiers:
            roots = list(tier.roots)
            self.rng.shuffle(roots)
            for r in roots:
                if tier.free_bytes(r) >= max(nbytes, self.required_bytes):
                    return tier, r
        return None
