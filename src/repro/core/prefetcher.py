"""Prefetcher — access-pattern-driven predictive readahead.

PRs 1–4 made Sea's metadata and data planes fast, but staging stayed
*reactive*: a file reaches a cache tier only through a static
``.sea_prefetchlist`` glob or an explicit ``stage_to_cache`` call, so any
workload not hand-annotated reads cold from the base tier forever. The
HSM follow-up work (Hayot-Sasson & Glatard, arXiv:2404.11556) shows that
*automatic, access-driven* staging is what makes tiering pay off for
unmodified pipelines, and the openPMD/ADIOS2 streaming results
(arXiv:2107.06108) show the wall-clock win lives in overlapping staging
with compute. This module is that layer:

* **Online access-pattern predictor.** Every ``SeaFS.open(..., "r")``
  under the mount reports its key (a lock-free deque append — the open
  hot path never blocks on the predictor). A background thread digests
  the stream with two models:

  - *Numeric-sequence runs.* Keys are split around their last digit run
    (``shard_00007.npy`` → ``("shard_", 7, ".npy")``). Two consecutive
    accesses with the same non-zero stride establish a run; confidence
    grows as ``1 - 1/run_length``, and once it clears
    ``readahead_min_confidence`` the next ``depth`` keys of the run are
    predicted (``shard_00008 .. shard_0000{7+depth}``).
  - *First-order successor graph.* For non-numeric orders a bounded
    ``key -> {next_key: count}`` graph predicts the most likely
    successor once its empirical probability clears the confidence bar.

* **Asynchronous speculative staging.** Predictions are staged
  base→cache through the existing :class:`TransferEngine` worker pool
  via ``SeaFS.stage_to_cache`` — key-locked, ledger-admitted before
  bytes move, atomically committed — so a speculative copy can never
  over-commit a capped tier or expose a partial file.

* **Cooperative cancellation.** Every prediction carries a cancel
  event, checked before admission and between chunks. A direction
  change cancels the whole run's outstanding predictions; accesses
  overtaking an unconsumed prediction cancel it as stale.

* **Accuracy feedback.** A predicted key that is subsequently opened is
  a *hit* and widens that run's readahead depth (up to
  ``readahead_depth``); an expired or cancelled prediction is *waste*
  and narrows it (down to 1). Hit/staged/wasted bytes land in telemetry
  (``readahead_*`` counters) so the speculation budget is observable.

* **Eviction shielding.** Keys with an in-flight or recently-consumed
  prediction report :meth:`is_hot`; the flusher's evict step and the
  LRU room-maker deprioritise them so speculative work is not thrown
  away before the application arrives (bounded by ``hot_ttl_s``).

``SeaConfig(readahead=True)`` enables the whole layer; it is off by
default (beyond-paper behaviour).
"""

from __future__ import annotations

import re
import sys
import threading
import time
from collections import OrderedDict, deque

from .extents import extent_token, split_extent_token

#: last run of digits in a key, e.g. "a/shard_00042.npy" -> ("a/shard_",
#: "00042", ".npy"); the suffix may not contain further digits
_NUM_RE = re.compile(r"^(.*?)(\d+)(\D*)$")

#: model bounds — pathological key churn must not grow memory forever
_MAX_RUNS = 64
_MAX_SUCC_KEYS = 512
_MAX_SUCC_PER_KEY = 8
_MAX_RECENT = 4096


class _Run:
    """State of one numeric key sequence ``(prefix, suffix, width)``."""

    __slots__ = ("last", "stride", "length", "depth", "last_ts")

    def __init__(self, n: int, now: float):
        self.last = n  # last observed sequence number
        self.stride = 0  # confirmed stride (0 = not yet established)
        self.length = 1  # consecutive accesses confirming the stride
        self.depth = 1  # adaptive readahead depth, 1..max_depth
        self.last_ts = now

    def confidence(self) -> float:
        """Empirical confidence that the next access continues the run."""
        if self.stride == 0:
            return 0.0
        return 1.0 - 1.0 / self.length


class _Prediction:
    """One speculative key: its cancel event and staging outcome."""

    __slots__ = ("key", "ts", "nbytes", "cancel", "seq", "num", "outcome",
                 "counted")

    def __init__(self, key: str, ts: float, seq, num: int | None):
        self.key = key
        self.ts = ts
        self.nbytes = 0  # bytes actually staged (0 until the copy commits)
        self.cancel = threading.Event()
        self.seq = seq  # run id for depth feedback (None = successor graph)
        self.num = num  # sequence number (None = successor graph)
        self.outcome = None  # None (pending) | "hit" | "waste"
        self.counted = 0  # bytes already attributed to the outcome ledger
        # (a stage commit racing the settlement records only the rest)


class Prefetcher:
    """Per-process predictive readahead engine bound to one ``SeaFS``.

    ``observe`` is the only hot-path entry point and is O(1) lock-free
    (deque append + event set); everything else runs on one background
    thread plus the transfer engine's bounded worker pool.
    """

    def __init__(self, fs, *, hot_ttl_s: float = 30.0):
        self.fs = fs
        cfg = fs.config
        self.enabled = bool(getattr(cfg, "readahead", False))
        # the extent plane reuses this predictor at block granularity
        # (within-file readahead) even when whole-file readahead is off
        self.extent_enabled = bool(getattr(cfg, "extent_map", False))
        self.max_depth = max(1, int(getattr(cfg, "readahead_depth", 4)))
        self.min_confidence = float(
            getattr(cfg, "readahead_min_confidence", 0.5)
        )
        self.hot_ttl_s = float(hot_ttl_s)
        self.telemetry = fs.telemetry
        self._events: deque[str] = deque()  # lock-free producer side
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._lock = threading.Lock()  # guards the model + pending below
        self._runs: "OrderedDict[tuple, _Run]" = OrderedDict()
        self._succ: "OrderedDict[str, OrderedDict[str, int]]" = OrderedDict()
        self._last_key: str | None = None
        self._pending: dict[str, _Prediction] = {}
        self._recent: dict[str, float] = {}  # consumed predictions (hot TTL)
        self._thread: threading.Thread | None = None
        self._thread_lock = threading.Lock()
        # staging jobs submitted but not yet finished: a cap on how much
        # of the (shared) transfer pool speculation may occupy. Combined
        # with the non-blocking try_submit below, the digestion thread
        # can never stall — expiry/cancellation keep running exactly
        # when the devices are saturated
        self._inflight = 0
        self._max_inflight = max(2, fs.transfer.n_workers * 2)

    # -- hot path -----------------------------------------------------------
    def observe(self, key: str) -> None:
        """Report one read-open of ``key``. Called from ``SeaFS.open`` —
        must never block: an unbounded deque append plus (at most) one
        event set; the model update happens on the background thread."""
        if not self.enabled or self._stop.is_set():
            return
        self._enqueue(key)

    def observe_extent(self, key: str, idx: int) -> None:
        """Report the read stream entering extent ``idx`` of ``key``
        (called from the extent read object on each block boundary).
        The block index rides the SAME numeric-run predictor as shard
        file names — an :func:`~repro.core.extents.extent_token` is just
        a synthetic key whose digit run is the extent index — so a
        sequential or strided scan *within* one file predicts and stages
        the next ``depth`` extents ahead of the reader."""
        if not (self.enabled or self.extent_enabled) or self._stop.is_set():
            return
        self._enqueue(extent_token(key, idx))

    def _enqueue(self, key: str) -> None:
        if len(self._events) > 4096:
            return  # digestion far behind: shed observations, not memory
        self._events.append(key)
        if not self._wake.is_set():
            self._wake.set()
        if self._thread is None:
            self._ensure_thread()

    def is_hot(self, key: str) -> bool:
        """True while ``key`` has an in-flight prediction or was consumed
        as a prediction hit within the hot TTL — eviction paths
        deprioritise such keys so speculative staging is not thrown away
        just before the application arrives."""
        if not (self.enabled or self.extent_enabled):
            return False
        if key in self._pending:  # GIL-atomic read; advisory only
            return True
        ts = self._recent.get(key)
        return ts is not None and time.monotonic() - ts < self.hot_ttl_s

    # -- lifecycle ----------------------------------------------------------
    def _ensure_thread(self) -> None:
        with self._thread_lock:
            if self._thread is None or not self._thread.is_alive():
                self._stop.clear()
                self._thread = threading.Thread(
                    target=self._run, name="sea-readahead", daemon=True
                )
                self._thread.start()

    def stop(self) -> None:
        """Stop the predictor and settle accounting: every still-pending
        prediction is cancelled and (if staged) counted as waste."""
        self._stop.set()
        self._wake.set()
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout=10)
            if t.is_alive():
                # a digestion thread wedged in hung I/O must not look
                # like a clean stop: surface it and count it (the daemon
                # thread is abandoned; process exit reaps it)
                print(
                    f"sea: readahead thread {t.name} still alive after a "
                    "10s join — abandoning it",
                    file=sys.stderr,
                )
                self.fs.telemetry.record_hung_thread_join()
        self.finalize()

    def finalize(self) -> None:
        """Expire every outstanding prediction now (cancel + count
        waste). Used at shutdown and by benchmarks that want final
        hit/waste accounting."""
        self._cancel_where(lambda _p: True)

    # -- background digestion ------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            self._wake.wait(timeout=0.1)
            self._wake.clear()
            while True:
                try:
                    key = self._events.popleft()
                except IndexError:
                    break
                try:
                    self._observe_one(key)
                except Exception:  # the predictor must never kill reads
                    pass
            self._expire(time.monotonic())

    def _observe_one(self, key: str) -> None:
        now = time.monotonic()
        hit = None
        with self._lock:
            hit = self._pending.pop(key, None)
            if hit is not None:
                hit.outcome = "hit"
                hit_amount = hit.counted = hit.nbytes
                if len(self._recent) >= _MAX_RECENT:
                    self._recent.clear()
                self._recent[key] = now
        if hit is not None:
            hit.cancel.set()  # no longer worth staging if still queued
            self.telemetry.record_readahead_hit(hit_amount)
            self._adjust_depth(hit.seq, +1)
        predictions = self._update_numeric(key, now)
        if not predictions:
            predictions = self._update_successor(key)
        else:
            self._update_successor(key, predict=False)
        for pk, seq, num in predictions:
            self._maybe_stage(pk, seq, num, now)

    # -- model: numeric runs -------------------------------------------------
    def _update_numeric(self, key: str, now: float) -> list:
        m = _NUM_RE.match(key)
        if m is None:
            return []
        prefix, digits, suffix = m.groups()
        seq = (prefix, suffix, len(digits))
        n = int(digits)
        run = self._runs.get(seq)
        if run is None:
            if len(self._runs) >= _MAX_RUNS:
                self._runs.popitem(last=False)
            self._runs[seq] = _Run(n, now)
            return []
        self._runs.move_to_end(seq)
        delta = n - run.last
        if delta == 0:
            return []  # re-read of the same file: no sequence evidence
        if delta == run.stride:
            run.length += 1
        else:
            # direction/stride change: outstanding predictions of this
            # run are stale — cancel them before re-establishing
            self._cancel_run(seq)
            run.stride = delta
            run.length = 1
        run.last = n
        run.last_ts = now
        if run.confidence() < self.min_confidence:
            return []
        self._cancel_overtaken(seq, n, run.stride)
        width = len(digits)
        out = []
        for j in range(1, run.depth + 1):
            nn = n + j * run.stride
            if nn < 0:
                break
            out.append((f"{prefix}{nn:0{width}d}{suffix}", seq, nn))
        return out

    # -- model: successor graph ----------------------------------------------
    def _update_successor(self, key: str, *, predict: bool = True) -> list:
        prev, self._last_key = self._last_key, key
        if prev is not None and prev != key:
            succs = self._succ.get(prev)
            if succs is None:
                if len(self._succ) >= _MAX_SUCC_KEYS:
                    self._succ.popitem(last=False)
                succs = self._succ[prev] = OrderedDict()
            else:
                self._succ.move_to_end(prev)
            succs[key] = succs.get(key, 0) + 1
            if len(succs) > _MAX_SUCC_PER_KEY:
                # drop the weakest edge, not the oldest
                weakest = min(succs, key=succs.get)
                del succs[weakest]
        if not predict:
            return []
        succs = self._succ.get(key)
        if not succs:
            return []
        total = sum(succs.values())
        best_key = max(succs, key=succs.get)
        best = succs[best_key]
        if total < 2 or best / total < self.min_confidence:
            return []
        return [(best_key, None, None)]

    # -- staging --------------------------------------------------------------
    def _maybe_stage(self, key: str, seq, num, now: float) -> None:
        with self._lock:
            if key in self._pending:
                return
            ts = self._recent.get(key)
            if ts is not None and now - ts < self.hot_ttl_s:
                return  # just consumed: staging again buys nothing
            if self._inflight >= self._max_inflight:
                # our own speculation is saturated: drop the prediction
                # rather than pile further onto the pool — the key can
                # be re-predicted on the next observation.
                return
            self._inflight += 1
            pred = _Prediction(key, now, seq, num)
            self._pending[key] = pred
        self.telemetry.record_readahead_prediction()
        # NEVER block: the transfer queue is shared with other producers
        # (flusher prefetch/flush), and blocking this thread would freeze
        # expiry/cancellation exactly when stale speculation is most
        # expensive. A full queue drops the speculative job instead.
        if self.fs.transfer.try_submit(self._stage_one, pred) is None:
            with self._lock:
                self._pending.pop(key, None)
                self._inflight -= 1

    def _stage_one(self, pred: _Prediction) -> int:
        """Runs on a transfer worker: the actual speculative copy."""
        try:
            if pred.cancel.is_set() or self._stop.is_set():
                return 0
            tok = split_extent_token(pred.key)
            try:
                if tok is not None:
                    nbytes = self.fs.stage_extent(
                        tok[0], tok[1], cancel=pred.cancel
                    )
                else:
                    nbytes = self.fs.stage_to_cache(
                        pred.key, cancel=pred.cancel
                    )
            except OSError:
                nbytes = 0
            late = 0
            with self._lock:
                pred.nbytes = nbytes
                outcome = pred.outcome
                if outcome is not None:
                    # the prediction was settled while this copy was past
                    # its last cancel checkpoint: attribute the committed
                    # bytes the settlement (which saw nbytes=0) missed,
                    # so staged == hit + wasted stays an invariant
                    late = nbytes - pred.counted
                    pred.counted = nbytes
            if nbytes:
                self.telemetry.record_readahead_staged(nbytes)
            if late > 0:
                if outcome == "waste":
                    self.telemetry.record_readahead_waste(late)
                else:
                    self.telemetry.record_readahead_hit(late, count=False)
            return nbytes
        finally:
            with self._lock:
                self._inflight -= 1

    # -- feedback / cancellation ----------------------------------------------
    def _adjust_depth(self, seq, direction: int) -> None:
        if seq is None:
            return
        run = self._runs.get(seq)
        if run is None:
            return
        if direction > 0:
            run.depth = min(run.depth + 1, self.max_depth)
        else:
            run.depth = max(run.depth - 1, 1)

    def _cancel_where(self, predicate) -> None:
        """One settlement protocol for every way a prediction dies
        unconsumed: drop it from pending under the lock, fire its cancel
        event, account its staged bytes (if the copy committed) as
        waste, and narrow the owning run's depth. A copy that commits
        AFTER this settlement records its own bytes (``_stage_one``
        checks ``outcome``), so staged bytes can never escape both
        ledgers or be counted twice."""
        settled = []
        with self._lock:
            stale = [p for p in self._pending.values() if predicate(p)]
            for p in stale:
                del self._pending[p.key]
                p.outcome = "waste"
                amount = p.counted = p.nbytes
                settled.append((p, amount))
        for p, amount in settled:
            p.cancel.set()
            self.telemetry.record_readahead_waste(amount)
            self._adjust_depth(p.seq, -1)

    def _cancel_run(self, seq) -> None:
        """Cancel every outstanding prediction of one numeric run."""
        self._cancel_where(lambda p: p.seq == seq)

    def _cancel_overtaken(self, seq, n: int, stride: int) -> None:
        """Cancel predictions of this run the access stream has already
        passed without consuming (the application skipped them)."""
        direction = 1 if stride > 0 else -1
        self._cancel_where(
            lambda p: p.seq == seq
            and p.num is not None
            and (p.num - n) * direction <= 0
        )

    def _expire(self, now: float) -> None:
        self._cancel_where(lambda p: now - p.ts > self.hot_ttl_s)

    # -- introspection ---------------------------------------------------------
    def pending_count(self) -> int:
        return len(self._pending)
