"""Namespace resolver — O(1) key→location resolution with verify-on-hit.

The paper's design is stateless: "a file's location IS its state on the
file systems" — resolution probes every root of every tier with ``lexists``
until it finds the file. That cascade is correct but costs O(tiers × roots)
metadata round-trips on **every** ``open``/``stat``/``exists``/``listdir``,
and it is exactly the metadata-path latency that dominates read-heavy
scientific workloads (cf. the HSM follow-up paper in PAPERS.md).

This layer keeps the statelessness *as the source of truth* while making
the common case O(1):

- **Location index.** A sharded in-process map ``key -> (tier, real)``
  populated by every placement/commit and by every full-scan miss.
- **Verify-on-hit.** A cached hit is trusted only after one ``lstat`` of
  the cached real path. If the file moved (cross-process flusher MOVE,
  external eviction), the verify fails, the entry is dropped, and the
  resolver falls back to the full probe cascade — so no metadata server
  is needed and concurrent movers stay correct by construction.
- **Verify trust window.** A successful verify (or an in-process
  mutation) stamps the entry; for ``max_age_s`` seconds further hits
  skip even the verify ``lstat`` — the hit path is then a pure dict
  lookup, independent of tiers, roots, *and* syscall latency. Operations
  that touch the file anyway (``open``, ``stat``) use their own ENOENT
  as the failed verify and *heal* via :meth:`refresh`, so a data read
  can never be stale or spuriously missing: only pure existence
  introspection can lag an **external** mutation, bounded by the
  window. In-process mutations always invalidate/overwrite the entry
  immediately. ``max_age_s=0`` restores the strict one-lstat-per-hit
  discipline.
- **Negative caching.** A full scan that finds nothing records a negative
  entry for ``negative_ttl_s`` seconds, absorbing read-miss storms
  (repeated ``exists()`` polling) at a bounded staleness cost.
- **Faster-copy probe for writes.** Overwrites must land on the *true*
  fastest replica (the hierarchy must never diverge). A write-side
  resolve therefore additionally probes only the tiers *above* the cached
  hit — zero extra cost when the hit is already on the fastest tier.
- **Directory child index.** ``listdir`` of a virtual directory is the
  union over every root of every tier. The resolver caches that union
  keyed by the per-root directory signatures (mtime_ns + inode): a hit is
  verified with one ``stat`` per candidate root — O(roots) stats instead
  of O(roots) ``listdir`` calls + O(entries) set unions — and any external
  create/delete bumps a directory mtime, failing the verify.

Every mutation path (write placement, close/commit, ``remove``,
``rename``, LRU eviction, flusher flush/evict/move, prefetch staging,
``wipe``) notes or invalidates entries; the index never needs to be
trusted blindly, so a stale entry costs one wasted ``lstat``, never a
stale read. ``SeaConfig(resolver_cache=False)`` restores the seed's pure
probe cascade (the benchmark baseline).
"""

from __future__ import annotations

import os
import stat as stat_mod
import threading
import time

from .tiers import Hierarchy, Tier


class _Entry:
    """Positive location entry: where the key was last seen, and when the
    real path was last verified to exist (monotonic; 0 = never)."""

    __slots__ = ("tier", "real", "verified_at")

    def __init__(self, tier: Tier, real: str, verified_at: float = 0.0):
        self.tier = tier
        self.real = real
        self.verified_at = verified_at


class _Negative:
    """Negative entry: a full scan found nothing at ``ts`` (monotonic)."""

    __slots__ = ("ts",)

    def __init__(self, ts: float):
        self.ts = ts


class _DirEntry:
    """Cached virtual-directory union + the per-root signatures it is
    conditional on. ``stamps[i]`` is ``(mtime_ns, ino)`` of candidate
    directory i, or None when that root had no such directory."""

    __slots__ = ("stamps", "entries")

    def __init__(self, stamps: tuple, entries: frozenset):
        self.stamps = stamps
        self.entries = entries


class Resolver:
    """Cached key→location resolution over a :class:`Hierarchy`.

    Thread-safe; shards the index by key hash so concurrent resolutions of
    different keys do not serialize. All entries are advisory: correctness
    comes from verify-on-hit plus the full-scan fallback, never from the
    cache itself.
    """

    def __init__(
        self,
        hierarchy: Hierarchy,
        telemetry=None,
        *,
        enabled: bool = True,
        negative_ttl_s: float = 0.05,
        verify_window_s: float = 0.05,
        n_shards: int = 16,
    ):
        self.hierarchy = hierarchy
        self.telemetry = telemetry
        self.enabled = enabled
        self.negative_ttl_s = max(float(negative_ttl_s), 0.0)
        self.verify_window_s = max(float(verify_window_s), 0.0)
        self._shards: list[dict[str, object]] = [{} for _ in range(n_shards)]
        self._locks = [threading.Lock() for _ in range(n_shards)]
        # per-shard invalidation generation: a scan result is only stored
        # if no invalidation/note landed in its shard while the (unlocked)
        # scan ran — an index entry must never outlive the mutation that
        # invalidated it
        self._gens = [0] * n_shards
        self._dirs: dict[str, _DirEntry] = {}
        self._dir_lock = threading.Lock()

        #: bound by SeaFS when the extent plane is enabled — the extent
        #: maps are placement state (like the tiers themselves), not a
        #: cache, so resolve_extent serves even with ``enabled=False``
        self.extent_store = None

        #: bound by SeaFS when cache federation is enabled — the third
        #: resolution tier (local hit -> peer hit -> base fallback); like
        #: extent maps, the registry is cluster state and serves even
        #: with ``enabled=False``
        self.federation = None

        # don't cache a directory whose mtime is this close to "now": a
        # same-mtime-tick mutation on a coarse-granularity filesystem
        # would otherwise be invisible to the signature check forever
        # (stable directories — the metadata-read-heavy case — do cache)
        self._racy_dir_ns = 2_000_000_000

    #: wholesale-clear bound per shard / for the dir cache (mirrors
    #: CompiledRules: pathological key churn must not grow memory forever)
    _SHARD_MAX = 8192
    _DIRS_MAX = 4096

    # -- telemetry plumbing -------------------------------------------------
    def _record(self, method: str, **kw) -> None:
        if self.telemetry is not None:
            getattr(self.telemetry, method)(**kw)

    # -- index shards -------------------------------------------------------
    def _shard_index(self, key: str) -> int:
        return hash(key) % len(self._shards)

    def _store(self, key: str, i: int, gen0: int, found) -> None:
        """Record a scan result, unless the shard was invalidated while
        the scan ran (the scan may have observed pre-mutation state)."""
        with self._locks[i]:
            if self._gens[i] != gen0:
                return
            shard = self._shards[i]
            if len(shard) >= self._SHARD_MAX:
                shard.clear()
            if found is not None:
                shard[key] = _Entry(found[0], found[1], time.monotonic())
            else:
                shard[key] = _Negative(time.monotonic())

    # -- file resolution ----------------------------------------------------
    def resolve(
        self,
        key: str,
        *,
        check_faster: bool = False,
        ignore_negative: bool = False,
        trust_window: bool = False,
    ) -> tuple[Tier, str] | None:
        """Locate ``key``, fastest tier first — O(1) on the hit path.

        ``check_faster=True`` (write-side resolution) additionally probes
        the tiers above a cached hit so an overwrite can never miss a
        faster replica; the probe is free when the hit is already on tier
        0. ``ignore_negative=True`` (flusher/prefetch paths) bypasses the
        negative cache so externally-created files are never skipped.
        ``trust_window=True`` (read-side hot path) skips the verify
        ``lstat`` while the entry's last verify is younger than
        ``verify_window_s`` — callers that subsequently touch the file
        must treat their own ENOENT as a failed verify and call
        :meth:`refresh` (operation-as-verify).
        """
        if not self.enabled:
            return self.hierarchy.locate(key)
        i = self._shard_index(key)
        shard = self._shards[i]
        lock = self._locks[i]
        with lock:
            e = shard.get(key)
        if isinstance(e, _Negative):
            if (
                not ignore_negative
                and time.monotonic() - e.ts <= self.negative_ttl_s
            ):
                self._record("record_resolve", hit=True, negative=True)
                return None
            e = None  # expired (or bypassed): fall through to the scan
        if isinstance(e, _Entry):
            now = time.monotonic()
            if (
                trust_window
                and not check_faster
                and now - e.verified_at <= self.verify_window_s
            ):
                self._record("record_resolve", hit=True)
                return e.tier, e.real
            try:
                os.lstat(e.real)
            except OSError:
                # the file moved under us (cross-process flusher MOVE,
                # external delete): drop the entry, fall back to the scan
                with lock:
                    if shard.get(key) is e:
                        del shard[key]
                self._record("record_resolve", hit=False, verify_failed=True)
            else:
                e.verified_at = now
                if check_faster and e.tier.level > 0:
                    above = self.hierarchy.locate_above(key, e.tier.level)
                    if above is not None:
                        self.note_location(key, above[0], above[1])
                        self._record("record_resolve", hit=True)
                        return above
                self._record("record_resolve", hit=True)
                return e.tier, e.real
        else:
            self._record("record_resolve", hit=False)
        with lock:
            gen0 = self._gens[i]
        found = self.hierarchy.locate(key)
        self._store(key, i, gen0, found)
        return found

    def resolve_fast(self, key: str) -> tuple[Tier, str] | None:
        """Lock-free trust-window hit, or None for *anything* else — the
        ``open`` fast path. A single GIL-atomic dict read: no shard lock,
        no telemetry, no verify ``lstat``, no fallback scan. Only a
        positive entry whose last verify is inside the trust window is
        served; the caller's own ``io.open`` doubles as the verify (its
        ENOENT sends the caller to the full slow path, which heals). With
        ``verify_window_s == 0`` (strict verify-on-hit) this never hits,
        so the fast path composes with the strict discipline."""
        if not self.enabled or self.verify_window_s <= 0.0:
            return None
        e = self._shards[self._shard_index(key)].get(key)
        if (
            type(e) is _Entry
            and time.monotonic() - e.verified_at <= self.verify_window_s
        ):
            return e.tier, e.real
        return None

    def resolve_extent(
        self, key: str, offset: int, *, trust_window: bool = True
    ) -> tuple[Tier, str] | None:
        """Locate the tier holding byte ``offset`` of ``key`` at extent
        granularity: the cache tier's sparse part file when the covering
        extent is staged-and-valid, else None (the byte is served from
        whatever :meth:`resolve` returns — the whole-file plane).

        Same verify-on-hit discipline as :meth:`resolve`: a hit inside
        the verify trust window is a pure in-memory lookup; past it, one
        ``lstat`` of the part file re-verifies (an externally evicted
        part file drops the whole map — per-extent validity without its
        backing file is meaningless)."""
        store = self.extent_store
        if store is None:
            return None
        em = store.get(key)
        if em is None:
            return None
        if not em.is_valid(em.index_of(offset)):
            return None
        now = time.monotonic()
        if not (
            trust_window and now - em.verified_at <= self.verify_window_s
        ):
            try:
                os.lstat(em.part_real)
            except OSError:
                store.discard(key)
                self._record("record_resolve", hit=False, verify_failed=True)
                return None
            em.verified_at = now
        return em.tier, em.part_real

    def resolve_peer(self, key: str) -> list[tuple[str, str, int]]:
        """The third resolution tier (local hit -> **peer hit** -> base
        fallback): live cluster peers holding a cache replica of ``key``,
        as ``(node, real_path, size)`` candidates for a peer->cache pull.
        Empty when federation is off or the registry is unreachable —
        callers then fall through to the base tier. Peer entries are
        advisory like everything else in the resolver: a stale candidate
        costs one failed pull (the caller expunges it and falls back),
        never a wrong read."""
        fed = self.federation
        if fed is None:
            return []
        return fed.lookup(key)

    def refresh(self, key: str) -> tuple[Tier, str] | None:
        """A caller's own operation hit ENOENT on a resolved path (the
        operation doubled as the verify and failed): drop the entry,
        count the verify failure, and re-scan from scratch."""
        if not self.enabled:
            return self.hierarchy.locate(key)
        i = self._shard_index(key)
        with self._locks[i]:
            self._shards[i].pop(key, None)
            gen0 = self._gens[i]
        self._record("record_resolve", hit=False, verify_failed=True)
        found = self.hierarchy.locate(key)
        self._store(key, i, gen0, found)
        return found

    def note_location(
        self, key: str, tier: Tier, real: str, *, verified: bool = True
    ) -> None:
        """A mutation placed ``key`` at ``real`` on ``tier`` (write
        placement, close/commit, rename destination, prefetch staging).
        ``verified=False`` (placement before the file is materialized)
        forces the first read hit to verify. Entries are advisory: if the
        caller never materializes the file, the next resolve's verify
        simply falls back to the scan."""
        if not self.enabled:
            return
        i = self._shard_index(key)
        with self._locks[i]:
            self._gens[i] += 1  # a racing scan must not clobber this note
            shard = self._shards[i]
            if len(shard) >= self._SHARD_MAX:
                shard.clear()
            shard[key] = _Entry(
                tier, real, time.monotonic() if verified else 0.0
            )
        self._drop_parent_dirs(key)

    def invalidate(self, key: str) -> None:
        """``key`` was removed/evicted/renamed away: drop whatever the
        index believes about it (one invalidation covers all replicas).
        A scan racing this mutation is fenced by the shard generation:
        its (possibly pre-mutation) result will not be stored."""
        if not self.enabled:
            return
        i = self._shard_index(key)
        with self._locks[i]:
            self._gens[i] += 1
            dropped = self._shards[i].pop(key, None) is not None
        self._drop_parent_dirs(key)
        if dropped:
            self._record("record_resolver_invalidate")

    def invalidate_all(self) -> None:
        """Full reset (``wipe``)."""
        for i, (shard, lock) in enumerate(zip(self._shards, self._locks)):
            with lock:
                self._gens[i] += 1
                shard.clear()
        with self._dir_lock:
            self._dirs.clear()

    def _drop_parent_dirs(self, key: str) -> None:
        """An in-process mutation of ``key`` changes the listing of every
        ancestor directory: drop their cached unions immediately (the
        mtime signature would also catch it, but not within the same
        mtime tick on coarse-granularity filesystems)."""
        if not self._dirs:
            return
        parents = []
        d = os.path.dirname(key)
        while d:
            parents.append(d)
            d = os.path.dirname(d)
        parents.append("")
        with self._dir_lock:
            for p in parents:
                self._dirs.pop(p, None)

    # -- virtual directories ------------------------------------------------
    def _dir_candidates(self, key: str) -> list[str]:
        """Real directory paths that could contribute children of ``key``,
        fastest tier first (one per root of every tier)."""
        return [
            os.path.join(root, key) if key else root
            for tier in self.hierarchy
            for root in tier.roots
        ]

    @staticmethod
    def _dir_signature(paths: list[str]) -> tuple:
        """Per-candidate ``(mtime_ns, ino)`` (None where absent or not a
        directory). Any create/delete/rename in a directory bumps its
        mtime, so equal signatures imply an unchanged union."""
        sig = []
        for p in paths:
            try:
                st = os.stat(p)
            except OSError:
                sig.append(None)
            else:
                sig.append(
                    (st.st_mtime_ns, st.st_ino)
                    if stat_mod.S_ISDIR(st.st_mode)
                    else None
                )
        return tuple(sig)

    def listdir(self, key: str) -> set[str] | None:
        """Union of children of virtual directory ``key`` across every
        root of every tier, or None when no tier has such a directory.
        Cached; a hit costs one ``stat`` per candidate root instead of a
        ``listdir`` + set union."""
        key = "" if key == "." else key
        candidates = self._dir_candidates(key)
        stamps = None
        if self.enabled:
            with self._dir_lock:
                e = self._dirs.get(key)
            # signature FIRST, union second: a mutation racing the walk
            # makes the stored stamp stale, so the next hit re-verifies —
            # never the other way around (a post-walk stamp could mask a
            # missed entry)
            stamps = self._dir_signature(candidates)
            if e is not None and stamps == e.stamps:
                self._record("record_dir_resolve", hit=True)
                return set(e.entries)
            self._record("record_dir_resolve", hit=False)
        seen: set[str] = set()
        found = False
        for p in candidates:
            try:
                names = os.listdir(p)
            except OSError:
                continue
            found = True
            seen.update(names)
        if not found:
            return None
        if self.enabled and not self._racy_stamps(stamps):
            with self._dir_lock:
                if len(self._dirs) >= self._DIRS_MAX:
                    self._dirs.clear()
                self._dirs[key] = _DirEntry(stamps, frozenset(seen))
        return seen

    def _racy_stamps(self, stamps: tuple | None) -> bool:
        """True when any contributing directory's mtime is within the
        racy window of "now": a mutation landing in the same mtime tick
        (coarse-granularity filesystems) would be invisible to the
        signature check, so such a union must not be cached."""
        if stamps is None:
            return True
        now_ns = time.time_ns()
        return any(
            s is not None and now_ns - s[0] < self._racy_dir_ns for s in stamps
        )

    def locate_dir(self, key: str) -> str | None:
        """Real path of the fastest-tier copy of virtual directory ``key``
        (the ``_any_dir`` probe of the seed), served from the directory
        index when its signature still verifies."""
        key = "" if key == "." else key
        candidates = self._dir_candidates(key)
        if self.enabled:
            with self._dir_lock:
                e = self._dirs.get(key)
            if e is not None:
                sig = self._dir_signature(candidates)
                if sig == e.stamps:
                    self._record("record_dir_resolve", hit=True)
                    for p, s in zip(candidates, sig):
                        if s is not None:
                            return p
                    return None
        for p in candidates:
            if os.path.isdir(p):
                return p
        return None
