"""SeaFS — stateless path translation + file operations over the hierarchy.

This is the Python-level equivalent of the paper's glibc wrappers: "The
wrappers take any input filepath that is located within the user-provided
Sea mountpoint and convert it to a filepath pointing to the best available
storage device." Every operation resolves mount-relative keys against the
tier hierarchy at call time; the file systems themselves are the only state
(decentralized/stateless, per the paper's design vs. BurstFS/GekkoFS).
"""

from __future__ import annotations

import errno
import io
import os
import shutil as _shutil
import stat as stat_mod
import threading
import time
from collections import defaultdict

from . import faults
from .config import SeaConfig
from .extents import PART_SUFFIX, ExtentStore, extent_token, punch_hole
from .faults import CAPACITY, FaultPlane, classify
from .federation import FederationRegistry
from .health import HealthTracker
from .ledger import LEDGER_DIRNAME, TMP_SUFFIX, file_disk_usage
from .lists import CompiledRules, Mode
from .placement import PlacementPolicy
from .prefetcher import Prefetcher
from .resolver import Resolver
from .telemetry import Stopwatch, Telemetry
from .tiers import Hierarchy, Tier
from .transfer import TransferEngine

_WRITE_CHARS = ("w", "a", "x", "+")
_STRIPE_MANIFEST_SUFFIX = ".sea_stripe.json"
_TMP_SUFFIX = TMP_SUFFIX  # atomic-commit staging (one canonical suffix)

# bound at import time: SeaFS's own truncate paths must reach the real
# syscalls even while a SeaMount context has os.truncate/os.ftruncate
# patched (the wrappers route mount paths back here — recursion otherwise)
_os_truncate = os.truncate
_os_ftruncate = os.ftruncate


def _is_write_mode(mode: str) -> bool:
    return any(c in mode for c in _WRITE_CHARS)


class _SeaFile:
    """Proxy around a real file object: forwards everything, and notifies
    SeaFS on close so the flush-and-evict daemon can pick the file up.
    Open files are refcounted — the flusher never moves a busy file
    (beyond-paper fix for the paper's §5.5 known limitation). A write
    handle additionally carries its capacity reservation, committed (with
    the actual on-disk size) when the file closes."""

    def __init__(
        self,
        fs: "SeaFS",
        key: str,
        raw,
        tier: Tier,
        writing: bool,
        real: str,
        reservation=None,
        fast: bool = False,
    ):
        self._fs = fs
        self._key = key
        self._raw = raw
        self._tier = tier
        self._writing = writing
        self._real = real
        self._reservation = reservation
        self._fast = fast
        self._t0 = time.perf_counter()
        self._closed = False
        self._fd = None
        if writing:
            # register the fd so an os.ftruncate against this handle can
            # be routed back through SeaFS for ledger/extent settlement
            try:
                self._fd = raw.fileno()
            except (OSError, ValueError, AttributeError):
                self._fd = None
            if self._fd is not None:
                fs._fd_index[self._fd] = (key, tier, real)

    @property
    def sea_tier(self) -> str:
        """Name of the tier this handle was opened against (benchmarks
        and tools use this to see where a read was actually served)."""
        return self._tier.name

    def __getattr__(self, name):
        return getattr(self._raw, name)

    def write(self, data):
        raw = self._raw
        if not self._writing:
            return raw.write(data)
        pre_pos = None
        if not self._tier.spec.persistent:
            # logical position before the write: a large buffered write
            # goes straight to the raw fd, so ENOSPC can strike after a
            # prefix of `data` already landed — post-failure tell() counts
            # those bytes, and relocation trusting it would carry the
            # prefix over AND rewrite the full data after it (silent
            # duplication). Migration must rewind to here instead.
            try:
                pre_pos = raw.tell()
            except (OSError, ValueError):
                pre_pos = None
        try:
            faults.fire("seafs.write", path=self._real)
            return raw.write(data)
        except OSError as e:
            if (
                self._tier.spec.persistent
                or classify(e) != CAPACITY
                or pre_pos is None
            ):
                raise
            # the cache root filled mid-stream: migrate the half-written
            # handle to the next eligible root (or base) and keep going
            return self._fs._relocate_write(self, data, e, pre_pos)

    def __iter__(self):
        return iter(self._raw)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        if self._closed:
            return
        self._closed = True
        if self._fd is not None:
            self._fs._fd_index.pop(self._fd, None)
        try:
            try:
                pos = self._raw.tell()
            except (OSError, ValueError):
                pos = 0
            self._raw.close()
        finally:
            dt = time.perf_counter() - self._t0
            self._fs._on_close(
                self._key,
                self._tier,
                self._writing,
                pos,
                dt,
                self._real,
                self._reservation,
                self._fast,
            )

    @property
    def closed(self):
        return self._raw.closed

    def __repr__(self):  # pragma: no cover
        return f"<SeaFile key={self._key!r} tier={self._tier.name}>"


class _ExtentRaw(io.RawIOBase):
    """Raw composite reader of the extent plane (``SeaFS.open`` wraps it
    in a :class:`io.BufferedReader`): staged extents are served with a
    ``pread`` of the sparse cache part file; a missing extent is faulted
    synchronously through the transfer engine on first touch (O(1 extent)
    time-to-first-byte) and served from cache; when staging is refused
    (no room, I/O error) the bytes stream straight from the base replica
    — the reader never waits on more than one extent and never fails
    because the cache is full. Every first touch of a new extent also
    feeds the within-file readahead predictor, so sequential scans find
    the next extents already staged.

    Hit reads take the map's lock around the validity check + ``pread``
    pair, which excludes the punch-hole eviction path — a reader can see
    an extent either fully staged or invalid, never a half-punched hole.
    Concurrent-overwrite semantics match POSIX reads of a file being
    rewritten: torn, but never blocking."""

    def __init__(self, fs: "SeaFS", key: str, em, base_real: str, base_tier):
        super().__init__()
        self._fs = fs
        self._key = key
        self._em = em
        self._base_real = base_real
        self._base_tier = base_tier
        self._size = em.size
        self._pos = 0
        self._part_fd = os.open(em.part_real, os.O_RDONLY)
        self._base_fd = -1  # lazy: an all-hit stream never opens the base
        self._last_idx = -1

    def readable(self) -> bool:
        return True

    def seekable(self) -> bool:
        return True

    def seek(self, offset: int, whence: int = os.SEEK_SET) -> int:
        if whence == os.SEEK_SET:
            pos = offset
        elif whence == os.SEEK_CUR:
            pos = self._pos + offset
        elif whence == os.SEEK_END:
            pos = self._size + offset
        else:
            raise ValueError(f"invalid whence: {whence}")
        if pos < 0:
            raise OSError(errno.EINVAL, "negative seek position")
        self._pos = pos
        return pos

    def tell(self) -> int:
        return self._pos

    def _base(self) -> int:
        if self._base_fd < 0:
            self._base_fd = os.open(self._base_real, os.O_RDONLY)
        return self._base_fd

    def readinto(self, b) -> int:
        if self._pos >= self._size:
            return 0
        fs, em = self._fs, self._em
        idx = em.index_of(self._pos)
        start, length = em.extent_range(idx)
        # serve within one extent per call (RawIOBase short reads are the
        # contract; BufferedReader re-calls across the boundary)
        want = min(len(b), start + length - self._pos)
        if want <= 0:  # zero-length destination buffer
            return 0
        if idx != self._last_idx:
            self._last_idx = idx
            fs.prefetcher.observe_extent(self._key, idx)
        data = None
        hit = False
        with em.lock:
            if em.is_valid(idx):
                data = os.pread(self._part_fd, want, self._pos)
                hit = True
        if not hit:
            if fs._fault_extent(em, idx):
                with em.lock:
                    if em.is_valid(idx):
                        data = os.pread(self._part_fd, want, self._pos)
            if data is None:
                data = os.pread(self._base(), want, self._pos)
        n = len(data)
        b[:n] = data
        self._pos += n
        em.touch(idx)
        fs.telemetry.record_extent_read(hit=hit, nbytes=n)
        return n

    def close(self) -> None:
        if not self.closed:
            try:
                os.close(self._part_fd)
                if self._base_fd >= 0:
                    os.close(self._base_fd)
            except OSError:
                pass
        super().close()


class SeaFS:
    """One Sea instance (one per node, as in the paper)."""

    def __init__(self, config: SeaConfig, *, telemetry: Telemetry | None = None):
        self.config = config
        self.hierarchy: Hierarchy = config.build_hierarchy()
        self.telemetry = telemetry or Telemetry()
        if self.hierarchy.ledger is not None:
            self.hierarchy.ledger.telemetry = self.telemetry
        # failure-domain layer: per-root sliding-window health feeding a
        # circuit breaker; quarantined cache roots drop out of placement
        # until a half-open probe succeeds (the base tier is never gated)
        self.health = HealthTracker(
            window_s=config.health_window_s,
            error_threshold=config.health_error_threshold,
            min_events=config.health_min_events,
            open_s=config.health_open_s,
            telemetry=self.telemetry,
        )
        self.policy = PlacementPolicy(
            self.hierarchy,
            max_file_size=config.max_file_size,
            n_procs=config.n_procs,
            health=self.health,
        )
        self.resolver = Resolver(
            self.hierarchy,
            self.telemetry,
            enabled=config.resolver_cache,
            negative_ttl_s=config.resolver_negative_ttl_s,
            verify_window_s=config.resolver_verify_window_s,
        )
        self.rules = CompiledRules(
            config.flushlist, config.evictlist, config.prefetchlist
        )
        # the data plane: every tier-to-tier byte moves through here
        self.transfer = TransferEngine(config, self.telemetry, self.policy)
        self.transfer.health = self.health
        # fault-injection plane (tests/chaos benches only): activates the
        # process-wide plane from the config spec string
        if getattr(config, "faults", ""):
            faults.activate(
                FaultPlane.from_spec(config.faults, seed=config.fault_seed)
            )
        self.mount = config.mount
        os.makedirs(self.mount, exist_ok=True)
        self._mount_prefix = self.mount + os.sep
        self._open_counts: dict[str, int] = defaultdict(int)
        self._open_writers: dict[str, int] = {}  # keys open for write
        self._lock = threading.RLock()
        self._key_locks: dict[str, threading.RLock] = {}
        self._close_listeners: list = []  # flusher subscribes here
        self._access_clock: dict[str, float] = {}  # LRU bookkeeping (opt-in)
        self._fast_open = bool(getattr(config, "open_fast_path", True))
        self._readahead = bool(getattr(config, "readahead", False))
        # extent-granular data plane (opt-in): partial sparse replicas on
        # cache tiers, per-extent staging/eviction, streaming reads
        self.extents: ExtentStore | None = (
            ExtentStore(config.extent_bytes, self.telemetry)
            if getattr(config, "extent_map", False)
            else None
        )
        self.resolver.extent_store = self.extents
        # cluster-scale cache federation (opt-in): publish cache replicas
        # to the shared registry on the base tier and pull peer->cache on
        # a local miss (third resolution tier: local -> peer -> base)
        self.federation: FederationRegistry | None = (
            FederationRegistry(
                self.hierarchy.base.roots[0],
                config.federation_node or None,
                heartbeat_s=config.federation_heartbeat_s,
                node_ttl_s=config.federation_node_ttl_s,
                telemetry=self.telemetry,
            )
            if getattr(config, "federation", False)
            else None
        )
        self.resolver.federation = self.federation
        #: fd -> (key, tier, real) of open Sea write handles, so the
        #: ftruncate intercept can settle accounting for fd-only calls
        self._fd_index: dict[int, tuple[str, Tier, str]] = {}
        # predictive readahead (observes read opens, stages speculatively
        # through the transfer pool); inert unless config.readahead
        self.prefetcher = Prefetcher(self)

    # -- path plumbing -------------------------------------------------------
    def is_sea_path(self, path: str) -> bool:
        ap = os.path.abspath(path)
        return ap == self.mount or ap.startswith(self._mount_prefix)

    def fast_path_class(self, path) -> bool | None:
        """One-``startswith`` mount classification for already-normalized
        absolute strings: True = definitively under the mount, False =
        definitively outside, None = undecided (relative, non-``str``,
        or containing ``//``/dot components that normalization could
        collapse — run the ``abspath`` probe). The single source of this
        heuristic: ``SeaFS.open``'s fast path and the ``SeaMount``
        wrappers both classify through here, so they can never drift."""
        if (
            path.__class__ is not str
            or not path.startswith(os.sep)
            or "/." in path
            or "//" in path
            or path.endswith(os.sep)
        ):
            return None
        if path.startswith(self._mount_prefix) or path == self.mount:
            return True
        return False

    def _fast_key(self, path) -> str | None:
        """Mount-relative key when ``path`` is an already-normalized
        absolute string strictly under the mount; None = undecided or
        not a plain key (the caller takes the abspath-based slow path,
        so a miss here is a de-opt, never a misroute)."""
        if self.fast_path_class(path) is True and path != self.mount:
            return path[len(self._mount_prefix) :]
        return None

    def key_of(self, path: str) -> str:
        """Mount-relative key of a path under the mountpoint."""
        return os.path.relpath(os.path.abspath(path), self.mount)

    def key_lock(self, key: str) -> threading.RLock:
        with self._lock:
            lk = self._key_locks.get(key)
            if lk is None:
                lk = self._key_locks[key] = threading.RLock()
            return lk

    def open_count(self, key: str) -> int:
        with self._lock:
            return self._open_counts.get(key, 0)

    def add_close_listener(self, fn) -> None:
        self._close_listeners.append(fn)

    # -- resolution ----------------------------------------------------------
    def resolve_read(self, key: str) -> tuple[Tier, str] | None:
        """Locate an existing file, fastest tier first — a pure dict
        lookup within the verify trust window, one verify ``lstat`` past
        it, the full probe cascade only on a cold/invalidated key.
        Callers that open the returned path should treat ENOENT as a
        failed verify and re-resolve (``SeaFS.open`` does)."""
        with self.key_lock(key):
            return self.resolver.resolve(key, trust_window=True)

    def resolve_write(self, key: str) -> tuple[Tier, str]:
        """Pick the destination for a (re)write.

        If the file already exists somewhere, overwrite in place (the
        hierarchy must never hold two divergent copies); otherwise select
        the fastest tier with space.
        """
        tier, real, res = self._resolve_write(key, reserve=False)
        assert res is None
        return tier, real

    def _resolve_write(
        self, key: str, *, reserve: bool
    ) -> tuple[Tier, str, object | None]:
        """``resolve_write`` plus (optionally) an atomic admission: the
        eligibility re-check and the in-flight reservation happen in one
        critical section per root, and a lost race re-selects — so
        concurrent writers of *different* keys can never jointly
        over-commit a capped root."""
        with self.key_lock(key):
            # check_faster: an overwrite must land on the TRUE fastest
            # replica, so a cached hit additionally probes the tiers above
            # it (free when the hit is already on tier 0)
            found = self.resolver.resolve(key, check_faster=True)
            if found is not None:
                tier, real = found
                res = None
                if reserve:
                    root = tier.root_of(real)
                    if root is not None:
                        # overwrite in place: no admission, just hold the
                        # in-flight budget until close commits the size
                        res = self.policy.reserve_write(tier, root)
                return tier, real, res
            make_room = self._lru_make_room if self.config.lru_evict else None
            tier, root, res = self.policy.place_new(
                reserve=reserve, make_room=make_room
            )
            real = os.path.join(root, key)
            os.makedirs(os.path.dirname(real), exist_ok=True)
            # verified=False: the file is not materialized until the
            # caller's io.open — the first read hit must verify
            self.resolver.note_location(key, tier, real, verified=False)
            return tier, real, res

    def resolve(self, path: str, mode: str = "r") -> str:
        """Public path-translation API (for tools that want the real path
        without going through ``open``)."""
        if not self.is_sea_path(path):
            return path
        key = self.key_of(path)
        if _is_write_mode(mode):
            return self.resolve_write(key)[1]
        found = self.resolve_read(key)
        if found is not None:
            return found[1]
        # Not found anywhere: report the base-tier path so the caller gets
        # POSIX ENOENT semantics against the persistent location.
        return os.path.join(self.hierarchy.base.roots[0], key)

    # -- file operations ------------------------------------------------------
    def open(self, path: str, mode: str = "r", **kw):
        writing = _is_write_mode(mode)
        if not writing:
            f = self._open_read_fast(path, mode, kw)
            if f is not None:
                return f
        if not self.is_sea_path(path):
            self.telemetry.record_redirect(False)
            return io.open(path, mode, **kw)
        self.telemetry.record_redirect(True)
        key = self.key_of(path)
        if self._readahead and not writing:
            self.prefetcher.observe(key)
        with self.key_lock(key):
            reservation = None
            if writing:
                tier, real, reservation = self._resolve_write(key, reserve=True)
                # register the writer BEFORE the (truncating) io.open so
                # read fast paths divert to the key-locked slow path for
                # the whole write, not just after the open returns
                with self._lock:
                    self._open_writers[key] = self._open_writers.get(key, 0) + 1
                # a partial extent replica of the old content is stale the
                # moment a writer opens the key
                self._discard_extents(key)
            else:
                found = self.resolve_read(key)
                if found is None:
                    # a fresh negative entry may hide a file another
                    # process created moments ago: one authoritative
                    # scan before declaring the miss — open() must never
                    # spuriously fail because of the cache
                    found = self.resolver.resolve(key, ignore_negative=True)
                if self.federation is not None and (
                    found is None or found[0].persistent
                ):
                    # third resolution tier: a key staged on a live peer
                    # is pulled peer->cache instead of read cold from base
                    pulled = self._pull_from_peer(key)
                    if pulled is not None:
                        found = pulled
                if found is None:
                    return self._open_base_miss(key, mode, **kw)
                tier, real = found
                if (
                    self.extents is not None
                    and tier.persistent
                    and "b" in mode
                    and not kw
                ):
                    f = self._open_extent_read(key, tier, real)
                    if f is not None:
                        return f
            try:
                if not writing:
                    faults.fire("seafs.open", path=real)
                raw = io.open(real, mode, **kw)
            except FileNotFoundError:
                if reservation is not None:
                    self.policy.release_write(tier, reservation)
                if writing:
                    self._drop_writer(key)
                    raise
                # the open doubled as the verify and failed (the file
                # moved between resolution and open): heal and retry once
                found = self.resolver.refresh(key)
                if found is None:
                    return self._open_base_miss(key, mode, **kw)
                tier, real = found
                try:
                    raw = io.open(real, mode, **kw)
                except FileNotFoundError:
                    # removed again mid-retry: raise the canonical error
                    # against the persistent location, like a plain miss
                    return self._open_base_miss(key, mode, **kw)
            except OSError as e:
                if reservation is not None:
                    self.policy.release_write(tier, reservation)
                if writing:
                    self._drop_writer(key)
                    raise
                if tier.persistent:
                    raise  # the base is the last resort; nothing slower
                # a real I/O error from a cache device (EIO, dead mount):
                # feed the breaker and degrade to any other replica
                return self._open_read_degraded(key, mode, kw, tier, real, e)
            except Exception:
                if reservation is not None:
                    self.policy.release_write(tier, reservation)
                if writing:
                    self._drop_writer(key)
                raise
            with self._lock:
                self._open_counts[key] += 1
                self._access_clock[key] = time.monotonic()
        return _SeaFile(self, key, raw, tier, writing, real, reservation)

    def _drop_writer(self, key: str) -> None:
        with self._lock:
            n = self._open_writers.get(key, 0) - 1
            if n <= 0:
                self._open_writers.pop(key, None)
            else:
                self._open_writers[key] = n

    def _open_read_fast(self, path, mode: str, kw):
        """Read-hit fast path: a single lock-free resolver lookup, the
        ``io.open`` itself, and one counts update — no key lock, no
        telemetry mutex (per-thread batched counters), no ``abspath``.

        Correctness: served only for (a) normalized absolute paths under
        the mount, (b) keys with **no registered writer** (writers
        register before their truncating open, re-checked after ours),
        and (c) resolver entries inside the verify trust window. The
        ``io.open`` doubles as the verify — any failure returns None and
        the caller re-runs the full key-locked slow path, which heals
        moved files and settles races. A fast hit therefore observes
        either a complete committed file or nothing (the atomic-commit
        invariant of the data plane); it can never see a mid-flush move
        as a partial file or a spurious miss."""
        if not self._fast_open:
            return None
        key = self._fast_key(path)
        if not key:
            return None
        if self._open_writers.get(key):
            return None
        found = self.resolver.resolve_fast(key)
        if found is None:
            return None
        tier, real = found
        if self.extents is not None and tier.persistent:
            # a base-resolved read may belong to the extent plane (partial
            # replica, streaming fault-in): always route through the
            # key-locked slow path, which owns that decision
            return None
        try:
            faults.fire("seafs.open", path=real)
            raw = io.open(real, mode, **kw)
        except OSError:
            return None  # the open doubled as the verify: slow path heals
        if self._open_writers.get(key):
            # a writer registered between the check and the open: drop
            # the handle and serialize through the key-locked slow path
            raw.close()
            return None
        with self._lock:
            self._open_counts[key] += 1
            self._access_clock[key] = time.monotonic()
        lc = self.telemetry.local()
        lc.redirect_hits += 1
        lc.fastpath_opens += 1
        if self._readahead:
            self.prefetcher.observe(key)
        return _SeaFile(self, key, raw, tier, False, real, fast=True)

    def _open_base_miss(self, key: str, mode: str, **kw):
        """The canonical miss: open against the persistent location so the
        caller gets POSIX ENOENT semantics (or creates the file there,
        for write modes reaching this fallback)."""
        return io.open(
            os.path.join(self.hierarchy.base.roots[0], key), mode, **kw
        )

    def _open_read_degraded(self, key: str, mode: str, kw, tier, real, exc):
        """A cache-tier read open failed with a genuine I/O error (not
        ENOENT). Called under the key lock. Feed the root's breaker, then
        serve the read from any OTHER replica — another root or tier, a
        live peer, or the base copy — so a sick device degrades service
        instead of failing the application. Re-raises the original error
        only when no healthy replica exists anywhere (a cache-only key
        whose sole copy sits on the dead root is genuinely lost)."""
        root = tier.root_of(real)
        if root is not None:
            self.health.record_failure(root, exc)
        bad = os.path.abspath(real)
        self.resolver.invalidate(key)
        for vtier, vreal in self.hierarchy.locate_all(key):
            if os.path.abspath(vreal) == bad:
                continue
            if not vtier.persistent:
                vroot = vtier.root_of(vreal)
                if vroot is not None and self.health.quarantined(vroot):
                    continue
            try:
                raw = io.open(vreal, mode, **kw)
            except OSError:
                continue
            self.telemetry.record_degraded_read()
            self.resolver.note_location(key, vtier, vreal)
            with self._lock:
                self._open_counts[key] += 1
                self._access_clock[key] = time.monotonic()
            return _SeaFile(self, key, raw, vtier, False, vreal)
        if self.federation is not None:
            pulled = self._pull_from_peer(key)
            if pulled is not None:
                vtier, vreal = pulled
                try:
                    raw = io.open(vreal, mode, **kw)
                except OSError:
                    raw = None
                if raw is not None:
                    self.telemetry.record_degraded_read()
                    with self._lock:
                        self._open_counts[key] += 1
                        self._access_clock[key] = time.monotonic()
                    return _SeaFile(self, key, raw, vtier, False, vreal)
        raise exc

    def _relocate_write(self, sf: _SeaFile, data, exc: OSError, pre_pos: int) -> int:
        """A cache-root write hit ENOSPC/EDQUOT mid-stream: trip the
        root's breaker (capacity exhaustion opens it instantly — retrying
        cannot make room) and migrate the half-written handle to wherever
        placement now lands (another root, a slower tier, or base),
        carrying the already-flushed prefix over. ``pre_pos`` is the
        handle's logical position captured *before* the failed write —
        the failure may have pushed a prefix of ``data`` through to the
        raw fd (post-failure ``tell()`` counts those bytes), so the
        migrated handle is rewound to ``pre_pos`` and ``data`` rewritten
        from there, overwriting any partially-landed prefix the copy
        carried over instead of duplicating it. Returns the write's
        byte count on success; re-raises the original error when the
        buffered prefix cannot be flushed (the device is genuinely full
        and holds bytes we cannot recover), the handle is text-mode, or
        placement offers nowhere new to go."""
        key = sf._key
        raw = sf._raw
        if isinstance(raw, io.TextIOBase):
            raise exc  # opaque text-mode positions: no safe migration
        with self.key_lock(key):
            old_tier, old_real, old_res = sf._tier, sf._real, sf._reservation
            root = old_tier.root_of(old_real)
            if root is not None:
                self.health.trip(root, "enospc")
            try:
                # bytes written *before* this call must reach the disk so
                # the prefix copy below captures them; a failing flush
                # means the buffer still holds bytes we cannot recover
                raw.flush()
            except (OSError, ValueError):
                raise exc from None
            make_room = self._lru_make_room if self.config.lru_evict else None
            new_tier, new_root, new_res = self.policy.place_new(
                reserve=True, make_room=make_room
            )
            new_real = os.path.join(new_root, key)
            if os.path.abspath(new_real) == os.path.abspath(old_real):
                # single-root hierarchy with no base room: nowhere to go
                self.policy.release_write(new_tier, new_res)
                raise exc
            try:
                os.makedirs(os.path.dirname(new_real), exist_ok=True)
                # written in place like any application write handle: the
                # registered writer + key lock already divert readers for
                # the whole open, exactly as the normal write path does
                with open(old_real, "rb") as fi, open(  # seacheck: ignore[atomic-commit]
                    new_real, "wb"
                ) as fo:  # seacheck: ignore[atomic-commit]
                    _shutil.copyfileobj(fi, fo)
                new_raw = io.open(new_real, "r+b")  # seacheck: ignore[atomic-commit]
                new_raw.seek(pre_pos)
            except OSError:
                self.policy.release_write(new_tier, new_res)
                try:
                    os.unlink(new_real)
                except OSError:
                    pass
                raise exc from None
            # settle the abandoned placement: reservation back, partial
            # file gone, stale ledger entry (overwrite-in-place) dropped
            if sf._fd is not None:
                self._fd_index.pop(sf._fd, None)
            try:
                raw.close()
            except OSError:
                pass
            self.policy.release_write(old_tier, old_res)
            try:
                os.unlink(old_real)
            except OSError:
                pass
            if root is not None:
                old_tier.note_removed(root, key)
            self._fed_unpublish(key)  # close re-publishes the new replica
            self.resolver.invalidate(key)
            self.resolver.note_location(key, new_tier, new_real, verified=False)
            sf._raw = new_raw
            sf._tier = new_tier
            sf._real = new_real
            sf._reservation = new_res
            try:
                sf._fd = new_raw.fileno()
            except (OSError, ValueError, AttributeError):
                sf._fd = None
            if sf._fd is not None:
                self._fd_index[sf._fd] = (key, new_tier, new_real)
            return new_raw.write(data)

    # -- federation (peer-aware miss resolution) -----------------------------
    def _fed_publish(self, key: str, root: str, nbytes: int) -> None:
        """Advertise a cache replica to the cluster registry (no-op when
        federation is off; best-effort — registry failures never fail the
        data path)."""
        if self.federation is not None:
            self.federation.publish(key, root, nbytes)

    def _fed_unpublish(self, key: str) -> None:
        if self.federation is not None:
            self.federation.unpublish(key)

    def _fed_republish(self, key: str, tier: Tier, real: str) -> None:
        """Re-advertise ``key`` after a mutation landed at ``real``: cache
        destinations publish the new replica (new size), persistent ones
        just drop this node's stale entry."""
        if self.federation is None:
            return
        root = tier.root_of(real) if not tier.persistent else None
        if root is None:
            self.federation.unpublish(key)
            return
        try:
            nbytes = os.path.getsize(real)
        except OSError:
            self.federation.unpublish(key)
            return
        self.federation.publish(key, root, nbytes)

    def _pull_from_peer(self, key: str) -> tuple[Tier, str] | None:
        """Pull a live peer's cache replica of ``key`` into a local cache
        tier (the peer-hit resolution tier). Called under the key lock.
        Returns ``(tier, real)`` of the new local replica, or None — the
        caller then falls through to whatever it already had (base
        replica, or a genuine miss).

        Degradation is always toward the base tier: a candidate whose
        pull fails (peer died or evicted mid-pull — the engine's atomic
        commit guarantees no partial file and no leaked reservation) is
        expunged from the registry and the next candidate tried; a full
        local cache skips the pull entirely rather than evicting for it."""
        fed = self.federation
        if fed is None:
            return None
        for node, src, size in self.resolver.resolve_peer(key):
            choice = self.policy.select_cache_for_prefetch(size)
            if choice is None:
                return None  # no cache room: serve from base
            ctier, croot = choice
            dst = os.path.join(croot, key)
            try:
                os.makedirs(os.path.dirname(dst), exist_ok=True)
                result = self.transfer.peer_pull(
                    src, dst, dst_tier=ctier, dst_root=croot, key=key
                )
            except OSError:
                self.telemetry.record_peer_fallback()
                fed.expunge(key, node)
                continue
            self.resolver.note_location(key, ctier, dst)
            fed.publish(key, croot, result.nbytes)
            self.telemetry.record_peer_hit(result.nbytes)
            return ctier, dst
        return None

    def _on_close(
        self,
        key: str,
        tier: Tier,
        writing: bool,
        nbytes: int,
        dt: float,
        real: str | None = None,
        reservation=None,
        fast: bool = False,
    ):
        if writing:
            if real is not None:
                # commit the actual on-disk size against the reservation
                # BEFORE dropping the open-count: once the count hits zero
                # the flusher may evict the file, and a late commit would
                # resurrect a ghost ledger entry.
                root = tier.root_of(real)
                try:
                    actual = os.path.getsize(real)
                except OSError:
                    actual = max(nbytes, 0)
                if root is not None:
                    self.policy.commit_write(tier, reservation, root, key, actual)
                else:
                    self.policy.release_write(tier, reservation)
                self.resolver.note_location(key, tier, real)
                if root is not None and not tier.persistent:
                    self._fed_publish(key, root, actual)
                    # a committed application write is health evidence —
                    # this is what lets a half-open probe write re-admit
                    # a recovered root
                    self.health.record_success(root, dt)
            self.telemetry.record_io(tier.name, written=max(nbytes, 0), seconds=dt)
        elif fast:
            # fast-path reads batch their I/O counters per thread — no
            # telemetry mutex on the hot close either
            self.telemetry.local().record_read(tier.name, max(nbytes, 0), dt)
        else:
            self.telemetry.record_io(tier.name, read=max(nbytes, 0), seconds=dt)
        with self._lock:
            if writing:
                self._drop_writer(key)  # self._lock is reentrant
            self._open_counts[key] -= 1
            if self._open_counts[key] <= 0:
                del self._open_counts[key]
            remaining = self._open_counts.get(key, 0)
        if remaining == 0:
            for fn in self._close_listeners:
                fn(key, writing)

    # convenience wrappers used by the framework ------------------------------
    def write_bytes(self, path: str, data: bytes) -> str:
        if (
            self.config.stripe_chunk_bytes > 0
            and len(data) > self.config.stripe_chunk_bytes
            and self.is_sea_path(path)
        ):
            if self._write_striped(path, data):
                return path
        with Stopwatch() as sw:
            with self.open(path, "wb") as f:
                f.write(data)
        del sw
        return path

    def read_bytes(self, path: str) -> bytes:
        if self.is_sea_path(path) and self.exists(path + _STRIPE_MANIFEST_SUFFIX):
            return self._read_striped(path)
        with self.open(path, "rb") as f:
            return f.read()

    # -- striping (paper §6: 'splitting of individual files, as seen with
    # the other burst buffer file systems' — implemented as a beyond-paper
    # extension, opt-in via SeaConfig.stripe_chunk_bytes) ---------------------
    def _write_striped(self, path: str, data: bytes) -> bool:
        """Split across the same-level roots of the fastest eligible tier
        (round-robin); parts parallelize device bandwidth the way BurstFS/
        GekkoFS stripe. Returns False when no multi-root tier is eligible
        (caller falls back to whole-file placement)."""
        import json as _json

        chunk = self.config.stripe_chunk_bytes
        key = self.key_of(path)
        n_parts = -(-len(data) // chunk)
        target = None
        for tier in self.hierarchy.cache_tiers:
            roots = self.policy.eligible_roots(tier)
            if len(roots) >= 2:
                # every stripe root is about to take writes: claim each
                # breaker probe now (a root that loses the half-open race
                # drops out of this stripe set)
                roots = [r for r in roots if self.policy.claim_root(tier, r)]
                if len(roots) >= 2:
                    target = (tier, roots)
                    break
        if target is None:
            return False
        tier, roots = target
        with self.key_lock(key):
            for i in range(n_parts):
                root = roots[i % len(roots)]
                pkey = f"{key}.sea_stripe.{i:04d}"
                real = os.path.join(root, pkey)
                os.makedirs(os.path.dirname(real), exist_ok=True)
                part = data[i * chunk : (i + 1) * chunk]
                # stage + rename: a crash mid-write leaves only a .sea_tmp
                # orphan (reaped later), never a short part under the
                # resolvable stripe name
                tmp = f"{real}.{os.getpid()}{_TMP_SUFFIX}"
                with open(tmp, "wb") as f:
                    f.write(part)
                os.replace(tmp, real)
                tier.note_written(root, pkey, len(part))
                self.resolver.note_location(pkey, tier, real)
            manifest = {"n_parts": n_parts, "chunk": chunk, "total": len(data),
                        "tier": tier.name}
            with self.open(path + _STRIPE_MANIFEST_SUFFIX, "w") as f:
                f.write(_json.dumps(manifest))
        self.telemetry.record_io(tier.name, written=len(data))
        return True

    def _read_striped(self, path: str) -> bytes:
        import json as _json

        key = self.key_of(path)
        with self.open(path + _STRIPE_MANIFEST_SUFFIX) as f:
            manifest = _json.loads(f.read())
        parts = []
        with self.key_lock(key):
            for i in range(manifest["n_parts"]):
                pkey = f"{key}.sea_stripe.{i:04d}"
                located = self.resolver.resolve(pkey)
                if located is None:
                    raise FileNotFoundError(f"missing stripe part {i} of {path}")
                with open(located[1], "rb") as f:
                    parts.append(f.read())
        data = b"".join(parts)
        if len(data) != manifest["total"]:
            raise IOError(f"striped read size mismatch for {path}")
        return data

    # -- metadata ops (the other glibc wrappers) -------------------------------
    def exists(self, path: str) -> bool:
        """Existence across the hierarchy. Served from the location index
        (positive AND negative entries): answers about files mutated by
        *other* processes may lag by up to the verify window / negative
        TTL; in-process mutations are always reflected immediately."""
        if not self.is_sea_path(path):
            return os.path.exists(path)
        key = self.key_of(path)
        return (
            self.resolver.resolve(key, trust_window=True) is not None
            or self.resolver.locate_dir(key) is not None
        )

    def _any_dir(self, key: str) -> str:
        found = self.resolver.locate_dir(key)
        if found is not None:
            return found
        return os.path.join(self.hierarchy.base.roots[0], key)

    def isfile(self, path: str) -> bool:
        """True iff the path resolves to a *regular file* on some tier.
        (``locate`` uses ``lexists``, which is also true for directories —
        checking the located real path keeps POSIX ``isfile`` semantics.)"""
        if not self.is_sea_path(path):
            return os.path.isfile(path)
        key = self.key_of(path)
        found = self.resolver.resolve(key, trust_window=True)
        if found is None:
            return False
        try:
            st = os.stat(found[1])
        except FileNotFoundError:
            # the stat doubled as the verify and failed: heal and retry
            found = self.resolver.refresh(key)
            if found is None:
                return False
            try:
                st = os.stat(found[1])
            except OSError:
                return False
        except OSError:
            return False
        return stat_mod.S_ISREG(st.st_mode)

    def isdir(self, path: str) -> bool:
        """True iff some tier holds a directory at this key (a virtual
        directory exists wherever any of its children were placed)."""
        if not self.is_sea_path(path):
            return os.path.isdir(path)
        return self.resolver.locate_dir(self.key_of(path)) is not None

    def stat(self, path: str):
        """``os.stat`` over the hierarchy. A partially-staged key reports
        its full LOGICAL size either way: resolution only ever sees whole
        replicas (part files carry :data:`PART_SUFFIX`), and the sparse
        part file's ``st_size`` equals the logical size by construction —
        staging state is a placement detail, never visible in metadata."""
        if not self.is_sea_path(path):
            return os.stat(path)
        key = self.key_of(path)
        found = self.resolver.resolve(key, trust_window=True)
        if found is None:
            # the negative cache must not turn a just-created file into a
            # spurious ENOENT: one authoritative scan before falling back
            found = self.resolver.resolve(key, ignore_negative=True)
        if found is not None:
            try:
                return os.stat(found[1])
            except FileNotFoundError:
                # the stat doubled as the verify and failed: heal, retry
                found = self.resolver.refresh(key)
                if found is not None:
                    try:
                        return os.stat(found[1])
                    except FileNotFoundError:
                        pass  # removed again mid-retry: fall through
        try:
            return os.stat(self._any_dir(key))
        except FileNotFoundError:
            # report the user's mount path, not the translated tier path
            raise FileNotFoundError(
                errno.ENOENT, os.strerror(errno.ENOENT), path
            ) from None

    def getsize(self, path: str) -> int:
        return self.stat(path).st_size

    def listdir(self, path: str) -> list[str]:
        """Union of entries across tiers (a directory is virtual: its
        children may be spread over several devices). Served from the
        resolver's per-directory child index when its per-root signatures
        still verify."""
        if not self.is_sea_path(path):
            return os.listdir(path)
        seen = self.resolver.listdir(self.key_of(path))
        if seen is None:
            raise FileNotFoundError(errno.ENOENT, os.strerror(errno.ENOENT), path)
        # the shared ledger / flusher-coordination store is bookkeeping
        # living inside each root, not application data — and an in-flight
        # flush's .sea_tmp staging file must never leak into the union
        seen.discard(LEDGER_DIRNAME)
        return sorted(
            n
            for n in seen
            if not n.endswith(_TMP_SUFFIX) and not n.endswith(PART_SUFFIX)
        )

    def makedirs(
        self, path: str, mode: int = 0o777, exist_ok: bool = False
    ) -> None:
        """Directories are created lazily per tier on write; creating them
        on the base tier gives tools a POSIX-visible directory. Mirrors
        ``os.makedirs`` — including the positional ``mode`` argument,
        which the intercept layer forwards verbatim."""
        if not self.is_sea_path(path):
            os.makedirs(path, mode, exist_ok=exist_ok)
            return
        key = self.key_of(path)
        os.makedirs(
            os.path.join(self.hierarchy.base.roots[0], key),
            mode,
            exist_ok=exist_ok,
        )

    def _drop_replicas(
        self, key: str, *, keep: str | None = None, replicas=None
    ) -> int:
        """Remove every on-disk replica of ``key`` across every root of
        every tier (``locate_all`` — a tier may hold copies on several
        roots), except ``keep``. ``replicas`` lets a caller that already
        ran the locate cascade pass its result in. The caller holds the
        key lock and owns the resolver invalidation. Returns the number
        dropped."""
        keep_ap = os.path.abspath(keep) if keep is not None else None
        dropped = 0
        if replicas is None:
            replicas = self.hierarchy.locate_all(key)
        for tier, real in replicas:
            if keep_ap is not None and os.path.abspath(real) == keep_ap:
                continue
            try:
                # callers own the resolver invalidation + fed unpublish
                # (contract in the docstring above)
                os.remove(real)  # seacheck: ignore[invalidation-completeness]
            except FileNotFoundError:
                continue  # raced an evict: already gone
            root = tier.root_of(real)
            if root is not None:
                tier.note_removed(root, key)
            dropped += 1
        return dropped

    def remove(self, path: str) -> None:
        if not self.is_sea_path(path):
            os.remove(path)
            return
        key = self.key_of(path)
        with self.key_lock(key):
            # one full-scan pass enumerates EVERY replica (COPY mode keeps
            # a base copy; a tier may even hold copies on several roots —
            # the seed's per-tier ``locate`` probe stopped at the first),
            # then all of them go atomically under the key lock with a
            # single resolver invalidation.
            replicas = self.hierarchy.locate_all(key)
            if not replicas:
                self.resolver.invalidate(key)
                raise FileNotFoundError(
                    errno.ENOENT, os.strerror(errno.ENOENT), path
                )
            self._drop_replicas(key, replicas=replicas)
            self._discard_extents(key)
            self.resolver.invalidate(key)
            self._fed_unpublish(key)

    def rmdir(self, path: str) -> None:
        """Remove an (empty) directory under the mount. A directory is a
        virtual union, so the removal visits every root of every tier;
        roots where it is empty are pruned even if another root still
        holds entries, in which case ENOTEMPTY is raised afterwards (the
        union still lists the survivors). FileNotFoundError if the
        directory existed on no root."""
        if not self.is_sea_path(path):
            os.rmdir(path)
            return
        key = self.key_of(path)
        found = False
        not_empty = False
        for tier in self.hierarchy.tiers:
            for root in tier.roots:
                real = os.path.join(root, key)
                if not os.path.isdir(real):
                    continue
                found = True
                try:
                    os.rmdir(real)
                except OSError as e:
                    if e.errno == errno.ENOTEMPTY:
                        not_empty = True
                    else:
                        raise
        if not found:
            raise FileNotFoundError(
                errno.ENOENT, os.strerror(errno.ENOENT), path
            )
        if not_empty:
            raise OSError(errno.ENOTEMPTY, os.strerror(errno.ENOTEMPTY), path)

    def rename(self, src: str, dst: str) -> None:
        s_in, d_in = self.is_sea_path(src), self.is_sea_path(dst)
        if not s_in and not d_in:
            os.replace(src, dst)
            return
        if s_in and d_in:
            skey, dkey = self.key_of(src), self.key_of(dst)
            # sorted-by-key acquisition, matching copyfile: two-key
            # operations must share one global lock order or a rename
            # and a copy of the same pair can ABBA-deadlock
            locks = [self.key_lock(k) for k in sorted({skey, dkey})]
            for lk in locks:
                lk.acquire()
            try:
                found = self.resolver.resolve(skey, check_faster=True)
                if found is None:
                    raise FileNotFoundError(src)
                tier, real = found
                # same-tier rename keeps the file on its device (cheap)
                droot = real[: -len(skey)] if real.endswith(skey) else None
                if droot is None:
                    droot = tier.roots[0]
                dreal = os.path.join(droot, dkey)
                os.makedirs(os.path.dirname(dreal), exist_ok=True)
                # drop stale copies of dst on other tiers/roots first
                self._drop_replicas(dkey, keep=dreal)
                self._discard_extents(skey)
                self._discard_extents(dkey)
                os.replace(real, dreal)
                self.resolver.invalidate(skey)
                self._fed_unpublish(skey)
                sroot = tier.root_of(real)
                if sroot is not None:
                    tier.note_removed(sroot, skey)
                owner = self.hierarchy.owner_of(dreal)
                self._fed_unpublish(dkey)
                if owner is not None:
                    self.resolver.note_location(dkey, owner[0], dreal)
                    try:
                        nbytes = os.path.getsize(dreal)
                        owner[0].note_written(owner[1], dkey, nbytes)
                        if not owner[0].persistent:
                            self._fed_publish(dkey, owner[1], nbytes)
                    except OSError:
                        pass
                else:
                    self.resolver.invalidate(dkey)
            finally:
                for lk in reversed(locks):
                    lk.release()
            return
        # crossing the mount boundary (exactly one side is inside): copy
        # semantics, routed through the transfer engine — the destination
        # appears atomically via .sea_tmp + os.replace, with ledger
        # admission held against the destination root before bytes move,
        # so a concurrent reader (or a crash) never observes a partial
        # file and capped roots cannot be over-committed.
        if d_in:
            dkey = self.key_of(dst)
            with self.key_lock(dkey):
                # _resolve_write creates the destination's parent dir and
                # holds the admission reservation (released by the engine
                # on any failure, committed with the actual size)
                dtier, rdst, res = self._resolve_write(dkey, reserve=True)
                self.transfer.copy(
                    src,
                    rdst,
                    src_tier=None,
                    dst_tier=dtier,
                    dst_root=dtier.root_of(rdst),
                    key=dkey,
                    reservation=res,
                )
                # drop stale replicas of dst on other tiers/roots (mirrors
                # the in-mount rename): the overwrite landed on the
                # fastest copy, and an old slower replica must not
                # resurface after an eviction
                self._drop_replicas(dkey, keep=rdst)
                self._discard_extents(dkey)
                self.resolver.invalidate(dkey)
                self.resolver.note_location(dkey, dtier, rdst)
                self._fed_republish(dkey, dtier, rdst)
            os.remove(src)
        else:
            skey = self.key_of(src)
            with self.key_lock(skey):
                # hold the key lock across resolve + copy: the flusher
                # must not move/evict the source mid-transfer
                found = self.resolver.resolve(skey, ignore_negative=True)
                if found is None:
                    raise FileNotFoundError(
                        errno.ENOENT, os.strerror(errno.ENOENT), src
                    )
                stier, rsrc = found
                os.makedirs(
                    os.path.dirname(os.path.abspath(dst)), exist_ok=True
                )
                self.transfer.copy(rsrc, dst, src_tier=stier, dst_tier=None)
            self.remove(src)

    def copyfile(self, src: str, dst: str, *, follow_symlinks: bool = True) -> str:
        """``shutil.copyfile`` semantics over the hierarchy, with the
        bytes moved through the transfer engine: chunked zero-copy
        streaming, atomic ``.sea_tmp`` + ``os.replace`` commit, and
        ledger admission held against the destination root before bytes
        move (the seed's intercepted ``copyfileobj`` loop had none of
        these, and readers could observe a partial destination).

        ``follow_symlinks`` is handled explicitly instead of being
        silently dereferenced: a symlink source is re-created with
        ``os.symlink`` when the destination is outside the mount, and
        **rejected** when it is inside (the hierarchy stores regular
        files — a symlink cannot be placed, flushed, or staged)."""
        s_in, d_in = self.is_sea_path(src), self.is_sea_path(dst)
        if not s_in and not d_in:
            return _shutil.copyfile(src, dst, follow_symlinks=follow_symlinks)
        skey = self.key_of(src) if s_in else None
        if s_in and d_in and skey == self.key_of(dst):
            # shutil parity: copying a file onto itself raises and is a
            # no-op — checked by KEY (two spellings of one mount path
            # must not reach the replica-dropping overwrite below)
            raise _shutil.SameFileError(f"{src!r} and {dst!r} are the same file")
        if not follow_symlinks:
            sprobe = src
            if s_in:
                located = self.resolver.resolve(skey, ignore_negative=True)
                sprobe = located[1] if located is not None else None
            if sprobe is not None and os.path.islink(sprobe):
                if d_in:
                    raise NotImplementedError(
                        "copyfile(follow_symlinks=False): symlink copies "
                        "into a Sea mount are not supported"
                    )
                os.symlink(os.readlink(sprobe), dst)
                return dst
        if d_in:
            dkey = self.key_of(dst)
            # deterministic (sorted-by-key) acquisition order: concurrent
            # opposite-direction copies of the same pair must not ABBA
            keys = sorted({skey, dkey} if s_in else {dkey})
            locks = [self.key_lock(k) for k in keys]
            for lk in locks:
                lk.acquire()
            try:
                if s_in:
                    located = self.resolver.resolve(skey, ignore_negative=True)
                    if located is None:
                        raise FileNotFoundError(
                            errno.ENOENT, os.strerror(errno.ENOENT), src
                        )
                    stier, rsrc = located
                else:
                    stier, rsrc = None, src
                dtier, rdst, res = self._resolve_write(dkey, reserve=True)
                if os.path.abspath(rdst) == os.path.abspath(rsrc):
                    self.policy.release_write(dtier, res)
                    raise _shutil.SameFileError(
                        f"{src!r} and {dst!r} are the same file"
                    )
                # preserve_stat=False: shutil.copyfile copies DATA only —
                # destination permissions come from the umask and the
                # mtime is fresh (copy2 is the stat-preserving variant)
                self.transfer.copy(
                    rsrc,
                    rdst,
                    src_tier=stier,
                    dst_tier=dtier,
                    dst_root=dtier.root_of(rdst),
                    key=dkey,
                    reservation=res,
                    preserve_stat=False,
                )
                # the overwrite landed on the fastest copy: stale slower
                # replicas must not resurface after an eviction
                self._drop_replicas(dkey, keep=rdst)
                self._discard_extents(dkey)
                self.resolver.invalidate(dkey)
                self.resolver.note_location(dkey, dtier, rdst)
                self._fed_republish(dkey, dtier, rdst)
            finally:
                for lk in reversed(locks):
                    lk.release()
            # the destination is a committed write: the flusher must
            # learn about it exactly as it learns about a closed write
            # handle (the replaced intercept path flushed via that close
            # event; without this, a flushlist destination would sit
            # cache-only until drain)
            if self.open_count(dkey) == 0:
                for fn in self._close_listeners:
                    fn(dkey, True)
            return dst
        # src inside the mount, dst external
        with self.key_lock(skey):
            located = self.resolver.resolve(skey, ignore_negative=True)
            if located is None:
                raise FileNotFoundError(
                    errno.ENOENT, os.strerror(errno.ENOENT), src
                )
            stier, rsrc = located
            if os.path.exists(dst) and os.path.samefile(rsrc, dst):
                raise _shutil.SameFileError(
                    f"{src!r} and {dst!r} are the same file"
                )
            self.transfer.copy(
                rsrc, dst, src_tier=stier, dst_tier=None, preserve_stat=False
            )
        return dst

    # -- LRU room-making (beyond-paper, opt-in) --------------------------------
    def _lru_make_room(self) -> bool:
        """Evict least-recently-used closed files from cache tiers until a
        cache root becomes eligible again. Only files whose mode is KEEP or
        REMOVE (i.e. not awaiting flush) are candidates."""
        candidates: list = []  # (hot, atime, key, real, tier, root)
        for tier in self.hierarchy.cache_tiers:
            for root in tier.roots:
                for dirpath, dirnames, files in os.walk(root):
                    if LEDGER_DIRNAME in dirnames:
                        dirnames.remove(LEDGER_DIRNAME)
                    for fn in files:
                        real = os.path.join(dirpath, fn)
                        if fn.endswith(_TMP_SUFFIX):
                            # never evict an in-flight staging file out
                            # from under a racing os.replace; dead ones
                            # are reclaimed on the spot
                            self.transfer.maybe_reap_orphan(real)
                            continue
                        if fn.endswith(PART_SUFFIX):
                            # partial extent replicas are evicted block-
                            # wise (punch pass below), never whole-file
                            continue
                        key = os.path.relpath(real, root)
                        if self.open_count(key):
                            continue
                        mode = self.rules.mode(key)
                        if mode in (Mode.KEEP, Mode.REMOVE):
                            at = self._access_clock.get(key, 0.0)
                            # predicted-hot keys (speculatively staged,
                            # application expected imminently) are
                            # evicted LAST — room-making must not throw
                            # readahead work away moments before it pays
                            hot = self.prefetcher.is_hot(key)
                            candidates.append((hot, at, key, real, tier, root))
        candidates.sort(key=lambda c: (c[0], c[1], c[2], c[3]))
        freed_any = False
        for _hot, _at, key, real, vtier, vroot in candidates:
            with self.key_lock(key):
                if self.open_count(key):
                    continue
                try:
                    nbytes = os.path.getsize(real)
                    os.remove(real)
                    vtier.note_removed(vroot, key)
                    self.resolver.invalidate(key)
                    self._fed_unpublish(key)
                    self.telemetry.record_evict(nbytes)
                    freed_any = True
                except OSError:
                    continue
            for tier in self.hierarchy.cache_tiers:
                if self.policy.eligible_roots(tier):
                    return True
        if self.extents is not None:
            # whole files alone didn't make a root eligible: punch cold
            # staged extents too (block-granular room-making)
            for tier in self.hierarchy.cache_tiers:
                for root in tier.roots:
                    if self._extent_make_room(root, self.policy.required_bytes):
                        freed_any = True
                    if self.policy.eligible_roots(tier):
                        return True
        return freed_any

    def stage_to_cache(self, key: str, *, cancel=None) -> int:
        """Stage one base-tier file into the fastest cache root with room
        (the prefetch/staging primitive shared by ``Flusher.prefetch``,
        the readahead predictor, and the data pipeline): under the key
        lock — a racing evict/flusher move can't pull the source out
        from under the copy — with ledger admission reserved before
        bytes move and the staging tmp cleaned up on failure. ``cancel``
        (speculative staging) aborts cooperatively before admission and
        between chunks. Best-effort: returns the bytes staged, or 0 when
        the key is gone, already cached, out of room, cancelled, or the
        transfer failed (callers fall back to the base copy)."""
        with self.key_lock(key):
            if cancel is not None and cancel.is_set():
                return 0  # stale prediction: don't even resolve
            if self.extents is not None and self.extents.get(key) is not None:
                # the key streams through a partial replica: staging is
                # per-extent (stage_extent), not whole-file
                return 0
            located = self.resolver.resolve(key, ignore_negative=True)
            if located is None or not located[0].persistent:
                return 0  # gone, or already cached
            try:
                nbytes = os.path.getsize(located[1])
            except OSError:
                return 0  # removed since resolution
            slot = self.policy.select_cache_for_prefetch(nbytes)
            if slot is None:
                return 0
            ctier, croot = slot
            dst = os.path.join(croot, key)
            os.makedirs(os.path.dirname(dst), exist_ok=True)
            try:
                result = self.transfer.copy(
                    located[1],
                    dst,
                    src_tier=located[0],
                    dst_tier=ctier,
                    dst_root=croot,
                    key=key,
                    admit="require",
                    cancel=cancel,
                )
            except OSError:
                # admission lost to a racing writer, a cancellation, or
                # an I/O error (engine errors preserve their POSIX
                # class): staging is best-effort — the file simply stays
                # on the base tier
                return 0
            # staging created a faster replica: point the index straight
            # at it
            self.resolver.note_location(key, ctier, dst)
            self._fed_publish(key, croot, result.nbytes)
            self.telemetry.record_prefetch(result.nbytes)
            return result.nbytes

    # -- extent plane (block-granular staging; opt-in via extent_map) ----------
    def _discard_extents(self, key: str) -> None:
        """Drop a key's partial replica (overwrite/remove/rename/truncate
        make per-extent state stale) and settle its ledger entry."""
        if self.extents is None:
            return
        em = self.extents.discard(key)
        if em is not None:
            em.tier.note_removed(em.root, em.part_rel)

    def _open_extent_read(self, key: str, tier: Tier, real: str):
        """Route one binary read open through the extent plane (caller
        holds the key lock; ``real`` resolved on the persistent base).
        Returns None to fall back to the whole-file path: size
        unreadable, file fits in one extent, or no cache root has room
        for even one extent."""
        try:
            size = os.path.getsize(real)
        except OSError:
            return None
        if size <= self.extents.extent_bytes:
            return None  # single extent: whole-file staging is equivalent
        em = self.extents.load(key, self.hierarchy.cache_tiers)
        if em is not None and (em.size != size or em.dead):
            self._discard_extents(key)  # base rewritten: journal is stale
            em = None
        if em is None:
            slot = self._select_extent_root(self.extents.extent_bytes)
            if slot is None:
                return None  # no room for one extent: stream from base
            ctier, croot = slot
            em = self.extents.create(key, ctier, croot, size)
        em.tier.note_written(
            em.root, em.part_rel, ExtentStore.disk_usage(em)
        )
        try:
            raw = _ExtentRaw(self, key, em, real, tier)
        except OSError:
            self._discard_extents(key)
            return None
        with self._lock:
            self._open_counts[key] += 1
            self._access_clock[key] = time.monotonic()
        return _SeaFile(
            self, key, io.BufferedReader(raw), em.tier, False, em.part_real
        )

    def _fault_extent(self, em, idx: int) -> bool:
        """Synchronous read-fault of one extent — the reader blocks for
        O(1 extent), never O(file). Best-effort: False streams the read
        from the base replica instead."""
        if em.dead:
            return False
        with self.key_lock(em.key):
            if em.dead:
                return False
            if em.is_valid(idx):
                return True
            return self._stage_extent_locked(em, idx) > 0

    def stage_extent(self, key: str, idx: int, *, cancel=None) -> int:
        """Stage one extent of ``key``'s partial replica — the per-extent
        analogue of :meth:`stage_to_cache`, driven by the within-file
        readahead predictor. Returns the bytes staged (0 = gone, already
        staged, out of room, cancelled, or failed)."""
        if self.extents is None:
            return 0
        with self.key_lock(key):
            if cancel is not None and cancel.is_set():
                return 0
            em = self.extents.get(key)
            if (
                em is None
                or em.dead
                or idx >= em.n_extents
                or em.is_valid(idx)
            ):
                return 0
            return self._stage_extent_locked(em, idx, cancel=cancel)

    def _stage_extent_locked(self, em, idx: int, *, cancel=None) -> int:
        """The staging step (caller holds the key lock): admission at
        EXTENT granularity — ``required`` is one extent, not the paper's
        whole-file headroom, which is what admits files bigger than the
        tier — then a ranged copy committed by the validity journal."""
        start, length = em.extent_range(idx)
        located = self.resolver.resolve(em.key, ignore_negative=True)
        if located is None or not located[0].persistent:
            return 0  # base replica gone, or a full cache replica exists
        admitted, res = self._admit_extent(em.tier, em.root, length)
        if not admitted and self.config.lru_evict:
            if self._extent_make_room(em.root, length):
                admitted, res = self._admit_extent(em.tier, em.root, length)
        if not admitted:
            return 0
        try:
            faults.fire("extents.stage", path=em.part_real, cancel=cancel)
            self.transfer.copy_range(
                located[1],
                em.part_real,
                start,
                length,
                src_tier=located[0],
                dst_tier=em.tier,
                dst_root=em.root,
                cancel=cancel,
            )
        except OSError:
            # cancelled, or an I/O error (engine errors keep their POSIX
            # class): per-extent staging is best-effort — the reader
            # falls back to the base replica. The failed attempt may have
            # committed chunks into the sparse file: punch them back out
            # (best-effort) and re-note the REAL disk usage, or the walk
            # and the ledger would disagree by the torn chunks.
            em.tier.release_write(res)
            try:
                fd = os.open(em.part_real, os.O_RDWR)
                try:
                    # punches an extent that was never marked valid — the
                    # resolver and peers never saw it, nothing to invalidate
                    punch_hole(fd, start, length)  # seacheck: ignore[invalidation-completeness]
                finally:
                    os.close(fd)
            except OSError:
                pass
            em.tier.note_written(
                em.root, em.part_rel, ExtentStore.disk_usage(em)
            )
            return 0
        self.extents.mark_valid(em, idx)
        em.tier.commit_write(
            res, em.root, em.part_rel, ExtentStore.disk_usage(em)
        )
        self.telemetry.record_extent_staged(length)
        if em.complete:
            self._promote_extents(em)
        return length

    def _promote_extents(self, em) -> None:
        """Every extent landed: the partial replica becomes a plain
        whole-file replica (atomic rename) and the ledger swaps the part
        entry for the final file — a fully-staged key degenerates to
        exactly the whole-file plane's state."""
        try:
            final = self.extents.promote(em)
        except OSError:
            return
        em.tier.note_removed(em.root, em.part_rel)
        try:
            em.tier.note_written(em.root, em.key, file_disk_usage(final))
        except OSError:
            pass
        self.resolver.note_location(em.key, em.tier, final)

    def _admit_extent(self, tier: Tier, root: str, nbytes: int):
        """Atomic per-extent admission. Returns (admitted, reservation)."""
        if tier.spec.capacity is None or tier.ledger is None:
            if not tier.admissible(root, required=nbytes, nbytes=nbytes):
                return False, None
            return True, tier.reserve_write(root, nbytes)
        res = tier.ledger.try_reserve(
            root, nbytes, capacity=tier.spec.capacity, required=nbytes
        )
        return res is not None, res

    def _select_extent_root(self, nbytes: int) -> tuple[Tier, str] | None:
        """Fastest cache root with room for ONE extent. (The whole-file
        planes demand the ``n_procs * max_file_size`` headroom; the
        extent plane admits block by block, so a tier smaller than the
        largest file still qualifies.)"""
        for tier in self.hierarchy.cache_tiers:
            roots = list(tier.roots)
            self.policy.rng.shuffle(roots)
            for r in roots:
                if (
                    self.policy._root_allowed(tier, r)
                    and tier.free_bytes(r) >= nbytes
                    and self.policy.claim_root(tier, r)  # chosen for I/O
                ):
                    return tier, r
        return None

    def _extent_make_room(self, root: str, need: int) -> bool:
        """Punch the least-recently-read staged extents under ``root``
        until ``need`` bytes are deallocated — extent-granular eviction:
        cold blocks of hot (even currently-open) files go first, with
        predicted-hot extents shielded the way whole files are."""
        if self.extents is None:
            return False
        cands: list = []
        for em in self.extents.maps():
            if em.dead or em.root != root:
                continue
            for idx in sorted(em.valid):
                hot = self.prefetcher.is_hot(extent_token(em.key, idx))
                cands.append((hot, em.atime.get(idx, 0.0), em.key, idx, em))
        cands.sort(key=lambda c: (c[0], c[1], c[2], c[3]))
        freed = 0
        for _hot, _at, _key, idx, em in cands:
            n = self.extents.punch(em, idx)
            if n <= 0:
                continue
            self.telemetry.record_extent_punched(n)
            em.tier.note_written(
                em.root, em.part_rel, ExtentStore.disk_usage(em)
            )
            freed += n
            if freed >= need:
                return True
        return freed >= need

    # -- truncate (ledger-settled; bypassing it drifts used-bytes) -------------
    def truncate(self, path: str, length: int) -> None:
        """``os.truncate`` over the hierarchy: applied to the fastest
        replica, stale slower replicas dropped, the ledger re-noted with
        the new size, and resolver/extent state invalidated — a truncate
        that bypasses Sea otherwise drifts used-bytes until the next
        reconcile and leaves partial extent replicas serving dead data."""
        if not self.is_sea_path(path):
            _os_truncate(path, length)
            return
        key = self.key_of(path)
        with self.key_lock(key):
            found = self.resolver.resolve(
                key, check_faster=True, ignore_negative=True
            )
            if found is None:
                raise FileNotFoundError(
                    errno.ENOENT, os.strerror(errno.ENOENT), path
                )
            tier, real = found
            _os_truncate(real, length)
            self._drop_replicas(key, keep=real)
            self._discard_extents(key)
            root = tier.root_of(real)
            if root is not None:
                try:
                    tier.note_written(root, key, file_disk_usage(real))
                except OSError:
                    pass
            self.resolver.invalidate(key)
            self.resolver.note_location(key, tier, real)
            self._fed_republish(key, tier, real)

    def ftruncate(self, fd: int, length: int) -> None:
        """``os.ftruncate`` for fds opened through SeaFS: the syscall,
        then the same ledger/extent settlement as :meth:`truncate`.
        Foreign fds get the plain syscall and no bookkeeping."""
        _os_ftruncate(fd, length)
        info = self._fd_index.get(fd)
        if info is None:
            return
        key, tier, real = info
        self._discard_extents(key)
        root = tier.root_of(real)
        if root is not None:
            try:
                tier.note_written(root, key, file_disk_usage(real))
            except OSError:
                pass
        self._fed_republish(key, tier, real)

    def persist(self, path: str) -> str:
        """Ensure a durable copy exists on the base (persistent) tier,
        keeping any cache copy (explicit COPY — used for input datasets
        that eviction must never orphan). Bytes move through the transfer
        engine: chunked, atomically committed, ledger-accounted."""
        key = self.key_of(path)
        with self.key_lock(key):
            located = self.resolver.resolve(key)
            if located is None:
                raise FileNotFoundError(
                    errno.ENOENT, os.strerror(errno.ENOENT), path
                )
            tier, real = located
            base = self.hierarchy.base
            base_root = base.roots[0]
            dst = os.path.join(base_root, key)
            if tier.persistent or os.path.abspath(real) == os.path.abspath(dst):
                return dst
            os.makedirs(os.path.dirname(dst), exist_ok=True)
            result = self.transfer.copy(
                real,
                dst,
                src_tier=tier,
                dst_tier=base,
                dst_root=base_root,
                key=key,
                admit="reserve",
            )
            self.telemetry.record_flush(result.nbytes)
            return dst

    # -- introspection ----------------------------------------------------------
    def where(self, path: str) -> str | None:
        """Tier name currently holding the file (fastest hit), or None."""
        if not self.is_sea_path(path):
            return None
        # a COPY-flushed file keeps its fast replica: probe above the
        # cached hit so introspection reports the true fastest tier
        found = self.resolver.resolve(self.key_of(path), check_faster=True)
        return found[0].name if found else None

    def wipe(self) -> None:
        if self.extents is not None:
            self.extents.clear()  # on-disk parts/journals go with the roots
        if self.federation is not None:
            # peers must stop pulling from roots that are about to vanish
            self.federation.unpublish_all()
        for tier in self.hierarchy:
            tier.wipe()
        self.resolver.invalidate_all()
