"""Cross-process shared capacity ledger (multi-process deployments).

Sea's target deployment is ``n_procs`` concurrent application processes
sharing the same tmpfs/local-disk tiers on one HPC node — the paper's
performance model (Eqs. 8-10) is explicitly parameterized by ``n_procs``.
The in-process :class:`~repro.core.ledger.CapacityLedger` keeps each
process honest with *itself*; two ``Sea`` instances mounting the same
hierarchy would still silently double-spend capped-root capacity because
neither sees the other's in-flight reservations.

This module persists per-root accounting in a small file-backed store
under each root (``<root>/.sea_ledger/``), exposing the exact
``reserve / commit / release / note_written / note_removed / reconcile``
transactional API of the in-process ledger so :class:`~repro.core.tiers.Tier`
and the placement policy select it via ``SeaConfig.shared_ledger`` with no
call-site changes.

Store layout (per root)::

    <root>/.sea_ledger/journal    append-truncate journal, fcntl-guarded
    <root>/.sea_ledger/res/       one marker file per in-flight reservation

The **journal** starts with a header line ``SEALEDGER1 <generation>
<last_reconcile_unix>`` followed by ``W <size> <quoted-key>`` (file landed)
and ``D <quoted-key>`` (file removed) records. Every mutation appends one
record while holding an exclusive ``fcntl`` lock; readers replay only the
suffix they have not seen (tracked by byte offset), so steady-state cost is
O(1) per operation. When the journal grows past a few multiples of the
live-file count it is compacted *in place* (truncate + snapshot rewrite,
generation bump) — the "append-truncate" design: peers detect the bump and
reload. A torn trailing record (writer SIGKILLed mid-append) is repaired by
truncating to the last complete line under the lock; the filesystem remains
the source of truth, so any corruption degrades to a reconcile walk, never
to wrong placement forever.

**Reservations** are marker files named ``<pid>.<seq>.<nbytes>.res``:
creating/unlinking one is atomic, the reserved total is the sum over the
directory, and crash recovery is structural — :meth:`reconcile` expires
markers whose PID is dead, so a killed writer's budget is returned within
one reconcile interval instead of leaking forever.
"""

from __future__ import annotations

import fcntl
import itertools
import os
import threading
import time
from contextlib import contextmanager
from urllib.parse import quote, unquote

from . import faults
from .ledger import LEDGER_DIRNAME, scan_root

_MAGIC = "SEALEDGER1"
_JOURNAL_NAME = "journal"
_RES_DIRNAME = "res"


def pid_alive(pid: int) -> bool:
    """Is a process with this PID currently running (signal-0 probe)?"""
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True


class SharedReservation:
    """An in-flight write budget held against one root, backed by a marker
    file other processes (and crash recovery) can see. API-compatible with
    :class:`~repro.core.ledger.Reservation`."""

    __slots__ = ("root", "nbytes", "active", "path")

    def __init__(self, root: str, nbytes: int, path: str):
        self.root = root
        self.nbytes = nbytes
        self.active = True
        self.path = path

    def __repr__(self) -> str:  # pragma: no cover
        state = "active" if self.active else "resolved"
        return f"SharedReservation({self.root!r}, {self.nbytes}, {state})"


class _SharedAccount:
    """Per-root, per-*process* replica of the journal state.

    ``fd`` is the journal file descriptor the process locks through. POSIX
    ``fcntl`` locks are owned per (process, inode) — a second descriptor on
    the same inode would silently "succeed" and closing it would drop the
    first one's lock — so accounts live in a process-global registry keyed
    by journal path: every ledger instance in the process shares one fd and
    one thread lock per root.
    """

    __slots__ = (
        "lock",
        "fd",
        "loaded",
        "files",
        "used",
        "generation",
        "offset",
        "lines",
        "reconcile_ts",
        "synced_at",
        "res_cache_ts",
        "res_cache_total",
    )

    def __init__(self):
        self.lock = threading.Lock()
        self.fd: int | None = None
        self.loaded = False
        self.files: dict[str, int] = {}
        self.used = 0
        self.generation = 0
        self.offset = 0          # bytes of journal replayed so far
        self.lines = 0           # records since last compaction
        self.reconcile_ts = 0.0  # shared wall-clock; 0 = never reconciled
        self.synced_at = 0.0     # monotonic time of the last journal sync
        self.res_cache_ts = 0.0  # monotonic time of the last reservation scan
        self.res_cache_total = 0


_ACCOUNTS: dict[str, _SharedAccount] = {}
_ACCOUNTS_LOCK = threading.Lock()

#: process-wide reservation sequence — per-instance counters would let two
#: ledger instances in one process mint the same '<pid>.<seq>.<nbytes>.res'
#: marker name and silently merge (then double-free) their budgets
_RES_SEQ = itertools.count()


def _global_account(journal_path: str) -> _SharedAccount:
    key = os.path.realpath(journal_path)
    acct = _ACCOUNTS.get(key)
    if acct is None:
        with _ACCOUNTS_LOCK:
            acct = _ACCOUNTS.setdefault(key, _SharedAccount())
    return acct


class SharedCapacityLedger:
    """Drop-in replacement for :class:`~repro.core.ledger.CapacityLedger`
    whose counters are shared by every process mounting the hierarchy."""

    def __init__(
        self,
        reconcile_interval_s: float = 5.0,
        telemetry=None,
        compact_min_records: int = 1024,
        hint_window_s: float = 0.05,
    ):
        self.reconcile_interval_s = reconcile_interval_s
        self.telemetry = telemetry  # attached by SeaFS after construction
        self.compact_min_records = compact_min_records
        # Advisory reads (used/reserved feeding tier *selection*) may serve
        # the local replica for up to this long before re-syncing. Admission
        # of a write on a capped root always goes through the fully locked
        # try_reserve, so staleness here can skew which root select() ranks
        # first — never the used+reserved<=capacity invariant.
        self.hint_window_s = hint_window_s
        # root -> account memo: the process-global registry resolves paths
        # through realpath() (correct but ~100µs of lstat calls), far too
        # slow for the per-open hot path
        self._acct_cache: dict[str, _SharedAccount] = {}

    # -- store paths ---------------------------------------------------------
    def _dir(self, root: str) -> str:
        return os.path.join(root, LEDGER_DIRNAME)

    def _journal_path(self, root: str) -> str:
        return os.path.join(self._dir(root), _JOURNAL_NAME)

    def _res_dir(self, root: str) -> str:
        return os.path.join(self._dir(root), _RES_DIRNAME)

    def _account(self, root: str) -> _SharedAccount:
        acct = self._acct_cache.get(root)
        if acct is None:
            acct = self._acct_cache[root] = _global_account(self._journal_path(root))
        return acct

    def _record_hit(self) -> None:
        if self.telemetry is not None:
            self.telemetry.record_ledger_hit()

    # -- locking -------------------------------------------------------------
    @contextmanager
    def _locked(self, root: str):
        """Thread lock + exclusive fcntl lock on the root's journal. Handles
        the journal being replaced/deleted underneath us (``Tier.wipe``):
        after locking, the held fd must still be the inode at the path."""
        acct = self._account(root)
        with acct.lock:
            while True:
                if acct.fd is None:
                    os.makedirs(self._res_dir(root), exist_ok=True)
                    acct.fd = os.open(
                        self._journal_path(root), os.O_RDWR | os.O_CREAT, 0o644
                    )
                    acct.loaded = False
                fcntl.lockf(acct.fd, fcntl.LOCK_EX)
                try:
                    ino = os.stat(self._journal_path(root)).st_ino
                except FileNotFoundError:
                    ino = -1
                if ino == os.fstat(acct.fd).st_ino:
                    break
                fcntl.lockf(acct.fd, fcntl.LOCK_UN)
                os.close(acct.fd)
                acct.fd = None
            try:
                yield acct
            finally:
                fcntl.lockf(acct.fd, fcntl.LOCK_UN)

    # -- journal replay / append (all called with the lock held) --------------
    def _sync(self, acct: _SharedAccount) -> None:
        """Bring the in-memory replica up to date with the journal."""
        self._sync_inner(acct)
        acct.synced_at = time.monotonic()

    # seacheck: holds-lock
    def _sync_inner(self, acct: _SharedAccount) -> None:
        size = os.fstat(acct.fd).st_size
        if size == 0:
            # brand-new store: write the header so peers see a valid journal
            header = f"{_MAGIC} 1 0\n".encode()
            os.pwrite(acct.fd, header, 0)
            acct.loaded = True
            acct.files = {}
            acct.used = 0
            acct.generation = 1
            acct.offset = len(header)
            acct.lines = 0
            acct.reconcile_ts = 0.0
            return
        if acct.loaded:
            head = os.pread(acct.fd, 128, 0).split(b"\n", 1)[0]
            if self._parse_header(head)[0] == acct.generation:
                self._replay_from(acct, acct.offset, size)
                return
        self._reload(acct, size)

    def _parse_header(self, line: bytes) -> tuple[int, float]:
        parts = line.decode("utf-8", "replace").split()
        try:
            if parts[0] != _MAGIC:
                return -1, 0.0
            return int(parts[1]), float(parts[2])
        except (IndexError, ValueError):
            return -1, 0.0

    # seacheck: holds-lock
    def _reload(self, acct: _SharedAccount, size: int) -> None:
        data = os.pread(acct.fd, size, 0)
        nl = data.find(b"\n")
        gen, ts = self._parse_header(data[:nl] if nl >= 0 else data)
        if gen < 0:
            # corrupt header: reset the store; the filesystem is the source
            # of truth, so force a reconcile walk on next use
            os.ftruncate(acct.fd, 0)
            self._sync(acct)
            return
        acct.generation = gen
        acct.reconcile_ts = ts
        acct.files = {}
        acct.used = 0
        acct.lines = 0
        acct.offset = nl + 1
        acct.loaded = True
        self._replay_from(acct, acct.offset, size)

    # seacheck: holds-lock
    def _replay_from(self, acct: _SharedAccount, start: int, size: int) -> None:
        if size <= start:
            return
        data = os.pread(acct.fd, size - start, start)
        if not data.endswith(b"\n"):
            # torn trailing record (writer died mid-append): repair by
            # truncating to the last complete line — we hold the lock, and
            # the dead writer's bytes never formed a committed record
            cut = data.rfind(b"\n") + 1
            os.ftruncate(acct.fd, start + cut)
            data = data[:cut]
        for line in data.decode("utf-8", "replace").splitlines():
            self._apply(acct, line)
            acct.lines += 1
        acct.offset = start + len(data)

    # seacheck: holds-lock
    def _apply(self, acct: _SharedAccount, line: str) -> None:
        if line.startswith("W "):
            try:
                _, sz, qkey = line.split(" ", 2)
                nbytes = int(sz)
            except ValueError:
                return
            key = unquote(qkey)
            acct.used += nbytes - acct.files.get(key, 0)
            acct.files[key] = nbytes
        elif line.startswith("D "):
            old = acct.files.pop(unquote(line[2:]), None)
            if old is not None:
                acct.used -= old

    # seacheck: holds-lock
    def _append(self, acct: _SharedAccount, line: str) -> None:
        data = line.encode()
        faults.fire("shared_ledger.append")
        os.pwrite(acct.fd, data, acct.offset)
        acct.offset += len(data)
        acct.lines += 1
        if acct.lines > max(self.compact_min_records, 4 * len(acct.files)):
            self._rewrite(acct)

    # seacheck: holds-lock
    def _rewrite(self, acct: _SharedAccount, reconcile_ts: float | None = None) -> None:
        """Compact: truncate and rewrite header + one W record per live file
        (the 'truncate' half of the append-truncate journal)."""
        acct.generation += 1
        if reconcile_ts is not None:
            acct.reconcile_ts = reconcile_ts
        buf = [f"{_MAGIC} {acct.generation} {acct.reconcile_ts}\n"]
        buf.extend(
            f"W {sz} {quote(key, safe='/')}\n" for key, sz in acct.files.items()
        )
        data = "".join(buf).encode()
        os.ftruncate(acct.fd, 0)
        os.pwrite(acct.fd, data, 0)
        acct.offset = len(data)
        acct.lines = 0

    # -- reservation marker files ---------------------------------------------
    def _create_reservation(self, root: str, nbytes: int) -> SharedReservation:
        while True:
            path = os.path.join(
                self._res_dir(root), f"{os.getpid()}.{next(_RES_SEQ)}.{nbytes}.res"
            )
            try:
                # O_EXCL: a marker must never alias another live reservation
                os.close(os.open(path, os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644))
                break
            except FileExistsError:
                continue  # stale marker from a recycled pid: pick a new seq
        self._account(root).res_cache_ts = 0.0
        return SharedReservation(root, nbytes, path)

    def _drop_reservation(self, res: SharedReservation) -> None:
        if res.active:
            res.active = False
            try:
                os.unlink(res.path)
            except OSError:
                pass
            self._account(res.root).res_cache_ts = 0.0

    def _scan_reserved(self, root: str, *, live_only: bool = False) -> int:
        total = 0
        try:
            names = os.listdir(self._res_dir(root))
        except FileNotFoundError:
            return 0
        for fn in names:
            if not fn.endswith(".res"):
                continue
            parts = fn[: -len(".res")].split(".")
            try:
                pid, nbytes = int(parts[0]), int(parts[2])
            except (IndexError, ValueError):
                continue
            if live_only and not pid_alive(pid):
                continue
            total += nbytes
        return total

    def _expire_orphans(self, root: str) -> int:
        """Crash recovery: unlink reservation markers whose PID is dead —
        their writes will never commit, so their budget must be returned."""
        expired = 0
        try:
            names = os.listdir(self._res_dir(root))
        except FileNotFoundError:
            return 0
        for fn in names:
            if not fn.endswith(".res"):
                continue
            try:
                pid = int(fn.split(".", 1)[0])
            except ValueError:
                continue
            if not pid_alive(pid):
                try:
                    os.unlink(os.path.join(self._res_dir(root), fn))
                    expired += 1
                except OSError:
                    pass
        if expired:
            self._account(root).res_cache_ts = 0.0
        return expired

    # -- hot-path queries ------------------------------------------------------
    def used_bytes(self, root: str) -> int:
        self._maybe_reconcile(root)
        self._record_hit()
        acct = self._account(root)
        if acct.loaded and time.monotonic() - acct.synced_at < self.hint_window_s:
            return acct.used  # advisory fast path (see hint_window_s)
        with self._locked(root) as acct:
            self._sync(acct)
            return acct.used

    def reserved_bytes(self, root: str) -> int:
        acct = self._account(root)
        if time.monotonic() - acct.res_cache_ts < self.hint_window_s:
            return acct.res_cache_total
        total = self._scan_reserved(root)
        acct.res_cache_total = total
        acct.res_cache_ts = time.monotonic()
        return total

    def file_size(self, root: str, key: str) -> int | None:
        with self._locked(root) as acct:
            self._sync(acct)
            return acct.files.get(key)

    # -- transactional updates -------------------------------------------------
    def note_written(self, root: str, key: str, nbytes: int) -> None:
        with self._locked(root) as acct:
            self._sync(acct)
            self._apply_write(acct, key, nbytes)

    # seacheck: holds-lock
    def _apply_write(self, acct: _SharedAccount, key: str, nbytes: int) -> None:
        acct.used += nbytes - acct.files.get(key, 0)
        acct.files[key] = nbytes
        self._append(acct, f"W {nbytes} {quote(key, safe='/')}\n")

    def note_removed(self, root: str, key: str) -> None:
        with self._locked(root) as acct:
            self._sync(acct)
            old = acct.files.pop(key, None)
            if old is not None:
                acct.used -= old
                self._append(acct, f"D {quote(key, safe='/')}\n")

    def reserve(self, root: str, nbytes: int) -> SharedReservation:
        with self._locked(root):
            return self._create_reservation(root, nbytes)

    def commit(self, res: SharedReservation, key: str, nbytes: int) -> None:
        with self._locked(res.root) as acct:
            self._sync(acct)
            self._drop_reservation(res)
            self._apply_write(acct, key, nbytes)

    def try_reserve(
        self, root: str, nbytes: int, *, capacity: int, required: int
    ) -> SharedReservation | None:
        """Atomic admission across every process sharing the root: the
        eligibility re-check and the reservation-marker creation happen
        under one fcntl critical section, so concurrent writers anywhere on
        the node can never jointly over-commit a capped root. Same headroom
        rule as the in-process ledger: existing reservations count toward
        the ``n_procs * max_file_size`` worst case, not on top of it."""
        self._maybe_reconcile(root)
        self._record_hit()
        with self._locked(root) as acct:
            self._sync(acct)
            reserved = self._scan_reserved(root)
            if capacity - acct.used >= max(required, reserved + nbytes):
                return self._create_reservation(root, nbytes)
        return None

    def release(self, res: SharedReservation) -> None:
        self._drop_reservation(res)

    # -- reconciliation ----------------------------------------------------------
    def _maybe_reconcile(self, root: str) -> None:
        acct = self._account(root)
        if not acct.loaded:
            with self._locked(root):
                self._sync(acct)
        # reconcile_ts is shared through the journal header, so one walk by
        # any process satisfies the staleness bound for all of them
        if (
            acct.reconcile_ts
            and (time.time() - acct.reconcile_ts) < self.reconcile_interval_s
        ):
            return
        self.reconcile(root)

    def reconcile(self, root: str) -> int:
        """Re-walk the root, rebuild the shared account, and expire orphaned
        reservations of dead PIDs. Version-guarded like the in-process
        ledger: if any record lands in the journal while the walk is in
        flight, the walk's snapshot is stale and is discarded (the deltas
        are exact for Sea-mediated traffic). A discarded walk is retried a
        few times before the interval clock is reset — otherwise sustained
        Sea traffic could starve absorption of external writers forever."""
        self._expire_orphans(root)
        used = 0
        for _attempt in range(3):
            with self._locked(root) as acct:
                self._sync(acct)
                v0 = (acct.generation, acct.offset)
            files = scan_root(root)
            with self._locked(root) as acct:
                self._sync(acct)
                applied = (acct.generation, acct.offset) == v0
                if applied:
                    acct.files = files
                    acct.used = sum(files.values())
                    self._rewrite(acct, reconcile_ts=time.time())
                used = acct.used
            if applied:
                break
        else:
            # every walk raced a commit: keep the exact Sea-mediated deltas
            # and reset the clock so the next interval tries again anyway
            with self._locked(root) as acct:
                self._sync(acct)
                self._rewrite(acct, reconcile_ts=time.time())
                used = acct.used
        if self.telemetry is not None:
            self.telemetry.record_ledger_reconcile()
        return used

    def forget(self, root: str) -> None:
        """Drop the root's replica (e.g. after ``Tier.wipe`` removed the
        store with the root). The registry entry survives — other ledger
        instances in this process share it — but is reset to unloaded."""
        acct = self._account(root)
        with acct.lock:
            if acct.fd is not None:
                try:
                    os.close(acct.fd)
                except OSError:
                    pass
                acct.fd = None
            acct.loaded = False
            acct.files = {}
            acct.used = 0
            acct.offset = 0
            acct.lines = 0
            acct.reconcile_ts = 0.0
            acct.synced_at = 0.0
            acct.res_cache_ts = 0.0

    # -- verification --------------------------------------------------------------
    def verify(self, root: str) -> tuple[int, int]:
        """(ledger_used, fresh_walk_used) *without* reconciling."""
        with self._locked(root) as acct:
            self._sync(acct)
            used = acct.used
        walk_used = sum(scan_root(root).values())
        return used, walk_used

    def snapshot(self) -> dict:
        out = {}
        with _ACCOUNTS_LOCK:
            items = list(_ACCOUNTS.items())
        for journal_path, acct in items:
            root = os.path.dirname(os.path.dirname(journal_path))
            with acct.lock:
                if not acct.loaded:
                    continue
                out[root] = {
                    "used": acct.used,
                    "reserved": self._scan_reserved(root),
                    "files": len(acct.files),
                }
        return out
