"""Flow-level discrete-event simulator of the paper's cluster experiments.

The container has one node; the paper's results come from an 8-node cluster
with a 4-server/44-OST Lustre installation. To reproduce Figures 2a–d and 3
at paper scale we simulate the *incrementation* application (Alg. 1) as a
fluid-flow network: every I/O operation is a flow over a path of capacity-
constrained resources (node memory, node NICs, local disks, Lustre server
network, OSTs) and concurrent flows share resources by max-min fairness
(progressive filling). Placement decisions go through the same logic as the
real Sea library: fastest tier with ``free >= p*F`` reservation, spill to
local disks, then Lustre; a single flush-and-evict worker per node drains
the flush queue, exactly one per node as in the paper.

The simulator is validated against the analytic model (Eqs. 1–11): every
simulated makespan must fall within/near the model's [cached, uncached]
bounds — the same criterion the paper applies to its measurements.
"""

from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass

from .model import ClusterSpec, GiB, Workload

EPS = 1e-9


# --------------------------------------------------------------------- flows
@dataclass
class Flow:
    path: tuple[str, ...]          # resource names this flow traverses
    remaining: float               # bytes left
    owner: "object"                # Worker or NodeFlusher to notify
    rate: float = 0.0
    cap: float = 0.0               # per-flow rate cap (0 = unlimited), e.g.
                                   # a single client stream to Lustre

    def __hash__(self) -> int:
        return id(self)


def maxmin_rates(flows: list[Flow], caps: dict[str, float]) -> None:
    """Progressive-filling max-min fair allocation. Per-flow caps are
    modelled as synthetic single-user resources."""
    active = [f for f in flows if f.path]
    remaining = dict(caps)
    users: dict[str, set[Flow]] = defaultdict(set)
    tokens: dict[Flow, str] = {}
    for i, f in enumerate(active):
        f.rate = 0.0
        for r in f.path:
            users[r].add(f)
        if f.cap > 0.0:
            tok = f"__flow{i}"
            tokens[f] = tok
            remaining[tok] = f.cap
            users[tok].add(f)
    unfixed = set(active)
    while unfixed:
        # find the bottleneck resource: min fair share among resources w/ users
        best_r, best_share = None, float("inf")
        for r, us in users.items():
            live = [f for f in us if f in unfixed]
            if not live:
                continue
            share = remaining[r] / len(live)
            if share < best_share:
                best_share, best_r = share, r
        if best_r is None:
            break
        fixed = [f for f in users[best_r] if f in unfixed]
        for f in fixed:
            f.rate = best_share
            unfixed.discard(f)
            for r in f.path:
                remaining[r] = max(remaining[r] - best_share, 0.0)
        del users[best_r]


# --------------------------------------------------------------------- ops
@dataclass
class ReadOp:
    path: tuple[str, ...]
    nbytes: float
    cap: float = 0.0


@dataclass
class WriteOp:
    path: tuple[str, ...]
    nbytes: float
    cap: float = 0.0


@dataclass
class ComputeOp:
    seconds: float


# --------------------------------------------------------------------- sim
@dataclass
class SimResult:
    makespan: float
    bytes_by_tier: dict[str, float]
    flush_tail_s: float           # time between last app op and full drain
    app_done_s: float
    resolver_hits: int = 0        # resolutions served by the cached index
    resolver_misses: int = 0      # full O(tiers*roots) probe cascades
    readahead_hits: int = 0       # cold block inputs served from cache by
                                  # the predictive-staging overlap
    readahead_staged: int = 0     # background speculative staging flows
    ttfb_s: float = 0.0           # time until the FIRST worker has its first
                                  # cold input byte (whole-file: after all of
                                  # F; extent plane: after one extent)
    extents_staged: int = 0       # extent-granular staging flows modelled
    peer_hits: int = 0            # cold inputs served from a peer node's
                                  # cache (federation) instead of Lustre
    peer_pull_bytes: float = 0.0  # bytes moved over peer->node pull flows
    degraded_placements: int = 0  # writes the tier-failure model diverted
                                  # away from a down (breaker-open) tier


class _Node:
    """Mutable per-node placement state (Sea) / writeback budget (Lustre)."""

    def __init__(self, idx: int, cl: ClusterSpec):
        self.idx = idx
        self.tmpfs_used = 0.0
        self.disk_used = [0.0] * cl.g
        self.disk_rr = 0
        self.dirty_budget = 0.0  # fast page-cache write budget (Lustre base)
        self.flush_q: deque = deque()
        self.n_cached = 0        # files resident on this node's cache tiers
        self.readahead_q: deque = deque()  # speculative staging work
        self.local_inputs: set = set()  # input file ids cached on this node
                                        # (federation / shared-input model)
        self.ra_ready = 0        # staged blocks whose bytes have ARRIVED
                                 # (a worker may only consume these: the
                                 # model never serves a hit whose Lustre
                                 # flow has not physically completed)


class Simulator:
    def __init__(
        self,
        cluster: ClusterSpec,
        workload: Workload,
        system: str,                    # "lustre" | "sea" | "sea-flushall"
        *,
        compute_s_per_iter: float = 0.0,
        dirty_cap_bytes: float = 44 * GiB,
        evict_intermediates: bool = False,   # beyond-paper: reuse cache space
        flushers_per_node: int | None = None,
        ledger_placement: bool = True,       # O(1) ledger vs O(n) re-walk
        placement_probe_s: float = 0.0,      # fixed per-decision cost
        placement_scan_s_per_file: float = 0.0,  # per-cached-file walk cost
        shared_ledger: bool = False,         # cross-process ledger + 1 flusher
        ledger_lock_s: float = 0.0,          # fcntl critical-section length
        resolver_cache: bool = True,         # cached key->location index
        resolve_probe_s: float = 0.0,        # one lexists/lstat metadata RTT
        transfer_workers: int = 1,           # overlapped transfer streams per
                                             # flusher (data-plane worker pool)
        transfer_bandwidth_caps: dict[str, float] | None = None,
                                             # per-flow bytes/s cap by source
                                             # tier of a flush copy ("tmpfs",
                                             # "disk", or "*")
        readahead: bool = False,             # predictive staging: a warm
                                             # node's next cold block input
                                             # is staged Lustre->cache in the
                                             # background, so the app-side
                                             # read is a memory read
        extent_map: bool = False,            # extent-granular data plane: a
                                             # cold input's first byte waits
                                             # for ONE extent, not the file
        extent_bytes: float = 0.0,           # modelled extent size (bytes);
                                             # <=0 or >=F degenerates to the
                                             # whole-file plane
        federation: bool = False,            # cluster cache federation: a cold
                                             # input already staged on a PEER
                                             # node is pulled peer->node over
                                             # the node NICs instead of read
                                             # cold from Lustre
        shared_input_files: int = 0,         # >0: block b's input is file
                                             # b % shared_input_files (a shared
                                             # working set); 0 = every block
                                             # reads a distinct input (the
                                             # paper's incrementation workload)
        peer_stream_bw: float = 0.0,         # per-flow cap of one peer pull
                                             # stream (0 = NIC-limited only),
                                             # the "peer->*" engine cap
        tier_fail: str = "",                 # failure-domain model: this tier
                                             # ("tmpfs" or "disk<j>") is dead
                                             # — breaker open — during the
                                             # window; placement degrades to
                                             # the next tier exactly like the
                                             # real quarantine path
        tier_fail_start_s: float = 0.0,      # failure-window start (sim time)
        tier_fail_recover_s: float = 0.0,    # window end — half-open probe
                                             # re-admits the tier; 0 = the
                                             # tier never recovers
    ):
        assert system in ("lustre", "sea", "sea-flushall")
        self.cl = cluster
        self.w = workload
        self.system = system
        self.compute_s = compute_s_per_iter
        self.dirty_cap = dirty_cap_bytes
        self.evict_intermediates = evict_intermediates
        # Placement-decision cost model: with the capacity ledger the
        # eligibility check is a counter lookup (constant `probe` cost);
        # the seed's stateless design re-walked the cache root, costing
        # `scan_s_per_file * n_cached` per decision. Defaults keep the
        # cost at zero so the paper-calibrated experiments are unchanged.
        self.ledger_placement = ledger_placement
        self.placement_probe_s = placement_probe_s
        self.placement_scan_s_per_file = placement_scan_s_per_file
        # Multi-process contention model (shared_ledger): every placement
        # decision serializes through one fcntl lock per root, so with p
        # concurrent writers the expected critical-section wait is the lock
        # length plus half the queue ahead of you: lock_s * (1 + (p-1)/2).
        self.shared_ledger = shared_ledger
        self.ledger_lock_s = ledger_lock_s
        # One Sea instance per application process means one flush-and-evict
        # worker per process (paper §5.1: "if Sea is launched many times on
        # a given node, there will be many flush and evict processes") —
        # unless the shared ledger's leader election caps it at exactly one.
        if flushers_per_node is None:
            flushers_per_node = 1 if shared_ledger else cluster.p
        # Data-plane overlap model: the transfer engine drives up to
        # ``transfer_workers`` concurrent streams per flusher, so each
        # worker is one more flow contending max-min-fairly for the same
        # device/network resources — overlap wins wall-clock exactly when
        # a single stream cannot saturate the bottleneck (per-stream caps,
        # high-latency paths), mirroring the real engine's worker pool.
        self.transfer_workers = max(1, int(transfer_workers))
        self.flushers_per_node = flushers_per_node * self.transfer_workers
        # Per-stream bandwidth throttling (transfer_bandwidth_caps): a
        # flush flow from tier T is additionally capped at caps[T] (or
        # caps["*"]) bytes/s, modelling the engine's token buckets.
        self.transfer_bandwidth_caps = dict(transfer_bandwidth_caps or {})
        # Resolution-cost model: locating a file before a read probes the
        # tier roots fastest-first (`resolve_probe_s` per lexists). With
        # the resolver cache, a repeat access is one verify lstat; without
        # it, every access pays the cascade down to the resident tier.
        self.resolver_cache = resolver_cache
        self.resolve_probe_s = resolve_probe_s
        self.resolver_hits = 0
        self.resolver_misses = 0
        # Readahead overlap model: after the first block on a node the
        # predictor has the sequence, so every further block's cold input
        # arrives via a background staging flow (its Lustre read competes
        # max-min-fairly like a flush, but OFF the worker's critical
        # path) and the worker pays only a cache read + a cached
        # resolution. Mirrors the real engine: depth-1 pipelining is the
        # conservative floor of what the adaptive depth achieves.
        self.readahead = bool(readahead)
        self.readahead_hits = 0
        self.readahead_staged = 0
        # Extent-plane model: the cold read is split at extent granularity
        # — the worker blocks only for the first extent (its TTFB), then
        # the remainder streams through the same Lustre path while the
        # application consumes (total bytes moved are unchanged).
        self.extent_map = bool(extent_map)
        self.extent_bytes = float(extent_bytes)
        self.extents_staged = 0
        # Federation model: the first node to fetch a shared input becomes
        # its registry owner; any other node's later read of the same file
        # is a peer pull over (peer mem, peer NIC out, our NIC in) instead
        # of a Lustre read — cache capacity scales with the cluster.
        self.federation = bool(federation)
        self.shared_input_files = int(shared_input_files)
        self.peer_stream_bw = float(peer_stream_bw)
        self.input_owner: dict[int, int] = {}
        self.peer_hits = 0
        self.peer_pull_bytes = 0.0
        # Tier-failure model: mirrors the health tracker's quarantine — a
        # down tier is skipped by placement (writes degrade to the next
        # tier/Lustre) and every avoided selection is a degraded placement.
        self.tier_fail = tier_fail
        self.tier_fail_start_s = float(tier_fail_start_s)
        self.tier_fail_recover_s = float(tier_fail_recover_s)
        self.degraded_placements = 0
        self.ttfb_s: float | None = None
        self.now = 0.0
        self.nodes = [_Node(i, cluster) for i in range(cluster.c)]
        self.caps = self._build_resources()
        self.bytes_by_tier: dict[str, float] = defaultdict(float)

    # -- resource graph ------------------------------------------------------
    def _build_resources(self) -> dict[str, float]:
        cl = self.cl
        caps: dict[str, float] = {}
        caps["lus_net_in"] = cl.s * cl.N
        caps["lus_net_out"] = cl.s * cl.N
        caps["lus_backend_r"] = cl.L_backend_r
        caps["lus_backend_w"] = cl.L_backend_w
        # flush copies share the write backend but cap out at a lower
        # collective efficiency (no write-behind aggregation in cp-style
        # user-space copies) — calibrated on Fig. 3.
        caps["lus_flush_eff"] = cl.flush_efficiency * cl.L_backend_w
        for n in range(cl.c):
            caps[f"net_in{n}"] = cl.N
            caps[f"net_out{n}"] = cl.N
            caps[f"mem_r{n}"] = cl.C_r
            caps[f"mem_w{n}"] = cl.C_w
            for j in range(cl.g):
                # half-duplex: reads and writes share the SSD controller —
                # this is what makes flush-all expensive (paper §4.3: "the
                # majority of the overhead appears to have arisen from
                # writing to and flushing from local disk").
                caps[f"disk{n}_{j}"] = 0.5 * (cl.G_r + cl.G_w)
        return caps

    # -- paths ----------------------------------------------------------------
    def lustre_read_path(self, node: int) -> tuple[str, ...]:
        return ("lus_backend_r", "lus_net_out", f"net_in{node}")

    def lustre_write_path(self, node: int) -> tuple[str, ...]:
        return (f"net_out{node}", "lus_net_in", "lus_backend_w")

    # -- Sea placement (same policy as repro.core.placement) --------------------
    def placement_cost_s(self, nd: _Node) -> float:
        """Seconds one placement decision costs on this node: O(1) with the
        ledger, O(n_cached) with the seed's stateless re-walk, plus the
        cross-process lock-queueing penalty in shared-ledger mode."""
        cost = self.placement_probe_s
        if not self.ledger_placement:
            cost += self.placement_scan_s_per_file * nd.n_cached
        if self.shared_ledger and self.ledger_lock_s > 0.0:
            cost += self.ledger_lock_s * (1.0 + (self.cl.p - 1) / 2.0)
        return cost

    def resolution_cost_s(self, *, repeat: bool, resident: str) -> float:
        """Seconds one read-side resolution costs. A cached repeat access
        is a single verify ``lstat``; a cold access (or any access with
        the resolver disabled) probes the roots fastest-first until the
        resident tier answers — 1 probe for tmpfs, up to g+1 for a local
        disk, and the full ``1 + g + 1`` cascade for Lustre-resident
        files (every cache root says ENOENT first)."""
        if self.resolve_probe_s <= 0.0:
            return 0.0
        if self.resolver_cache and repeat:
            self.resolver_hits += 1
            return self.resolve_probe_s
        self.resolver_misses += 1
        if resident == "tmpfs":
            probes = 1
        elif resident.startswith("disk"):
            probes = 1 + self.cl.g
        else:  # lustre / pagecache-backed base tier
            probes = 1 + self.cl.g + 1
        return self.resolve_probe_s * probes

    def _tier_down(self, tier: str) -> bool:
        """Is ``tier`` inside its modelled failure window (breaker open)?"""
        if not self.tier_fail or self.tier_fail != tier:
            return False
        if self.now < self.tier_fail_start_s:
            return False
        return self.tier_fail_recover_s <= 0.0 or self.now < self.tier_fail_recover_s

    def sea_place_write(self, nd: _Node) -> tuple[str, tuple[str, ...]]:
        cl, F = self.cl, self.w.F
        reserve = cl.p * F
        if nd.tmpfs_used + F + reserve <= cl.t:
            if self._tier_down("tmpfs"):
                self.degraded_placements += 1
            else:
                nd.tmpfs_used += F
                nd.n_cached += 1
                return "tmpfs", (f"mem_w{nd.idx}",)
        for probe in range(cl.g):
            j = (nd.disk_rr + probe) % cl.g
            if nd.disk_used[j] + F + reserve <= cl.r:
                if self._tier_down(f"disk{j}"):
                    self.degraded_placements += 1
                    continue
                nd.disk_rr = (j + 1) % cl.g
                nd.disk_used[j] += F
                nd.n_cached += 1
                return f"disk{j}", (f"disk{nd.idx}_{j}",)
        return "lustre", self.lustre_write_path(nd.idx)

    # -- the incrementation application (Alg. 1) -------------------------------
    def worker_ops(self, nd: _Node, blocks: deque):
        """Generator of ops for one worker process; chained tasks: iteration
        i reads file i-1 (page-cache hit — written moments earlier on the
        same node) and writes file i."""
        w = self.w
        while True:
            try:
                bid = blocks.popleft()
            except IndexError:
                return
            # Shared-input model: block b's input file (None = distinct
            # inputs, the paper's workload). With federation, a file some
            # OTHER node already fetched resolves peer-hit: pulled over
            # the peer's NIC instead of read cold from Lustre.
            fid = (
                bid % self.shared_input_files
                if self.shared_input_files > 0 and self.system != "lustre"
                else None
            )
            local_hit = fid is not None and fid in nd.local_inputs
            peer = None
            if fid is not None and self.federation and not local_hit:
                owner = self.input_owner.get(fid)
                if owner is not None and owner != nd.idx:
                    peer = owner
            # initial read from Lustre (cold input): a Sea resolution pays
            # the full probe cascade — the file lives on the base tier.
            # With readahead, a hit is served from cache ONLY when a
            # background staging flow has already delivered the block
            # (ra_ready credit); otherwise the worker reads cold like the
            # predictor missing would in the real engine.
            if local_hit:
                # this node already holds the input: a repeat cached read
                rcost = self.resolution_cost_s(repeat=True, resident="tmpfs")
                if rcost > 0.0:
                    yield ComputeOp(rcost)
                self.bytes_by_tier["local_input_hit"] += w.F
                yield ReadOp((f"mem_r{nd.idx}",), w.F)
                if self.ttfb_s is None:
                    self.ttfb_s = self.now
            elif peer is not None:
                # peer hit: pull the replica over (peer mem read, peer NIC
                # out, our NIC in) — Lustre untouched. The pull stages a
                # local replica, so this node serves it locally next time.
                rcost = self.resolution_cost_s(repeat=False, resident="lustre")
                if rcost > 0.0:
                    yield ComputeOp(rcost)
                self.peer_hits += 1
                self.peer_pull_bytes += w.F
                self.bytes_by_tier["peer"] += w.F
                yield ReadOp(
                    (f"mem_r{peer}", f"net_out{peer}", f"net_in{nd.idx}"),
                    w.F,
                    cap=self.peer_stream_bw,
                )
                if self.ttfb_s is None:
                    self.ttfb_s = self.now
                nd.local_inputs.add(fid)
            elif self.system != "lustre" and self.readahead and nd.ra_ready > 0:
                nd.ra_ready -= 1
                rcost = self.resolution_cost_s(repeat=True, resident="tmpfs")
                if rcost > 0.0:
                    yield ComputeOp(rcost)
                self.readahead_hits += 1
                self.bytes_by_tier["readahead_hit"] += w.F
                if blocks:  # no phantom staging once the work runs out
                    nd.readahead_q.append("lustre")
                yield ReadOp((f"mem_r{nd.idx}",), w.F)
                if fid is not None:
                    nd.local_inputs.add(fid)
                    self.input_owner.setdefault(fid, nd.idx)
            else:
                if self.system != "lustre":
                    rcost = self.resolution_cost_s(
                        repeat=False, resident="lustre"
                    )
                    if rcost > 0.0:
                        yield ComputeOp(rcost)
                    if self.readahead and blocks:
                        # observed block: the predictor locks onto the
                        # sequence and stages the next one ahead (none
                        # left = nothing to speculate on)
                        nd.readahead_q.append("lustre")
                yield from self._cold_input_read(nd)
                if fid is not None:
                    # the cold fetch staged the input on this node: it is
                    # now a local hit here and a peer-pull source for the
                    # cluster (first fetcher = registry owner)
                    nd.local_inputs.add(fid)
                    self.input_owner.setdefault(fid, nd.idx)
            last_tier = None
            for i in range(1, w.n + 1):
                if self.compute_s:
                    yield ComputeOp(self.compute_s)
                if i > 1:
                    # re-read previous iteration's file: page-cache hit,
                    # located via the resolver (repeat access)
                    if self.system != "lustre":
                        rcost = self.resolution_cost_s(
                            repeat=True, resident=last_tier or "tmpfs"
                        )
                        if rcost > 0.0:
                            yield ComputeOp(rcost)
                    yield ReadOp((f"mem_r{nd.idx}",), w.F)
                if self.system == "lustre":
                    tier, path = self._lustre_app_write(nd)
                else:
                    pcost = self.placement_cost_s(nd)
                    if pcost > 0.0:
                        yield ComputeOp(pcost)
                    tier, path = self.sea_place_write(nd)
                    if self.evict_intermediates and i > 1 and last_tier == "tmpfs":
                        nd.tmpfs_used = max(nd.tmpfs_used - w.F, 0.0)
                        nd.n_cached = max(nd.n_cached - 1, 0)
                wcap = self.cl.L_stream_w if tier == "lustre" else 0.0
                self.bytes_by_tier[tier] += w.F
                yield WriteOp(path, w.F, cap=wcap)
                last_tier = tier
                final = i == w.n
                if self.system == "sea-flushall" or (self.system == "sea" and final):
                    nd.flush_q.append(tier)

    def _cold_input_read(self, nd: _Node):
        """The cold Lustre input read. Whole-file plane: one flow — the
        worker's first byte waits for ALL of F. Extent plane: the worker
        faults the first extent synchronously (TTFB = one extent over the
        same path) and the remainder streams while it computes; total
        bytes moved are identical, only the blocking prefix shrinks."""
        F = self.w.F
        path = self.lustre_read_path(nd.idx)
        cap = self.cl.L_stream_r
        if (
            self.system != "lustre"
            and self.extent_map
            and 0.0 < self.extent_bytes < F
        ):
            self.extents_staged += int(-(-F // self.extent_bytes))
            yield ReadOp(path, self.extent_bytes, cap=cap)
            if self.ttfb_s is None:
                self.ttfb_s = self.now
            yield ReadOp(path, F - self.extent_bytes, cap=cap)
        else:
            yield ReadOp(path, F, cap=cap)
            if self.ttfb_s is None:
                self.ttfb_s = self.now

    def _lustre_app_write(self, nd: _Node) -> tuple[str, tuple[str, ...]]:
        """Writeback model: the first ``dirty_cap`` bytes per node are
        absorbed by the page cache at memory speed; after that, writes are
        throttled to the sustained Lustre path (dirty_ratio throttling)."""
        if nd.dirty_budget + self.w.F <= self.dirty_cap:
            nd.dirty_budget += self.w.F
            return "pagecache", (f"mem_w{nd.idx}",)
        return "lustre", self.lustre_write_path(nd.idx)

    def flusher_ops(self, nd: _Node):
        """Single flush-and-evict worker per node (paper §5.1): reads the
        file from its cache tier and writes it to Lustre. Runs until the
        engine signals app completion and the queue is drained."""
        while True:
            if not nd.flush_q:
                yield None  # idle — engine will re-poll
                continue
            tier = nd.flush_q.popleft()
            if tier == "tmpfs":
                rpath: tuple[str, ...] = (f"mem_r{nd.idx}",)
            elif tier.startswith("disk"):
                j = int(tier[4:])
                rpath = (f"disk{nd.idx}_{j}",)
            else:  # already on Lustre
                continue
            self.bytes_by_tier["flush"] += self.w.F
            yield WriteOp(
                rpath + self.lustre_write_path(nd.idx) + ("lus_flush_eff",),
                self.w.F,
                cap=self._flush_stream_cap(tier),
            )

    def readahead_ops(self, nd: _Node):
        """Background speculative-staging agent (one per node): pulls the
        node's readahead queue and carries the Lustre→node transfer the
        worker no longer pays on its critical path."""
        while True:
            if not nd.readahead_q:
                yield None  # idle — engine will re-poll
                continue
            nd.readahead_q.popleft()
            self.readahead_staged += 1
            self.bytes_by_tier["readahead"] += self.w.F
            yield ReadOp(
                self.lustre_read_path(nd.idx), self.w.F, cap=self.cl.L_stream_r
            )
            # resumed only after the flow completed: the bytes are now on
            # the node — grant the consumption credit
            nd.ra_ready += 1

    def _flush_stream_cap(self, src_tier: str) -> float:
        """Per-flow rate cap of one flush stream: the single-client Lustre
        stream limit, tightened by any configured transfer throttle for
        the source tier ("disk3" matches the "disk" cap). Accepts BOTH
        the engine's pair grammar ("tmpfs->lustre", "tmpfs->*") and bare
        source-tier keys, so the same dict handed to SeaConfig models the
        same system here."""
        cap = self.cl.L_stream_w
        name = "disk" if src_tier.startswith("disk") else src_tier
        caps = self.transfer_bandwidth_caps
        throttle = 0.0
        for k in (f"{name}->lustre", f"{name}->*", name, "*->lustre", "*"):
            if k in caps:
                throttle = float(caps[k])
                break
        if throttle > 0.0:
            cap = min(cap, throttle) if cap > 0.0 else throttle
        return cap

    # -- engine ------------------------------------------------------------------
    def run(self) -> SimResult:
        cl = self.cl
        blocks: deque = deque(range(self.w.B))
        workers = []
        for nd in self.nodes:
            for _ in range(cl.p):
                workers.append(_Agent(self.worker_ops(nd, blocks)))
        flushers = (
            [
                _Agent(
                    self.flusher_ops(nd),
                    has_work=(lambda nd=nd: bool(nd.flush_q)),
                )
                for nd in self.nodes
                for _ in range(self.flushers_per_node)
            ]
            if self.system != "lustre"
            else []
        )
        if self.system != "lustre" and self.readahead:
            # staging runs on the transfer engine's worker pool: that
            # many concurrent speculative streams per node
            flushers += [
                _Agent(
                    self.readahead_ops(nd),
                    has_work=(lambda nd=nd: bool(nd.readahead_q)),
                )
                for nd in self.nodes
                for _ in range(self.transfer_workers)
            ]
        t = 0.0
        app_done_t: float | None = None
        while True:
            app_live = [a for a in workers if not a.done]
            if not app_live and app_done_t is None:
                app_done_t = t
            flush_live = [
                a
                for a in flushers
                if not a.done and (a.flow is not None or self._has_flush_work())
            ]
            if not app_live and not self._has_flush_work() and not any(
                a.flow for a in flushers
            ):
                break
            # collect flows / timers
            for a in app_live + flushers:
                a.ensure_started(t)
            flows = [a.flow for a in workers + flushers if a.flow is not None]
            del flush_live
            maxmin_rates(flows, self._effective_caps(flows))
            # next event: flow completion or compute wakeup or idle re-poll
            dt = float("inf")
            for a in workers + flushers:
                if a.flow is not None and a.flow.rate > EPS:
                    dt = min(dt, a.flow.remaining / a.flow.rate)
                elif a.wake_at is not None:
                    dt = min(dt, max(a.wake_at - t, 0.0))
                elif a.idle and a.has_work is not None and a.has_work():
                    dt = min(dt, 0.0)
            if dt == float("inf"):
                # only idle flushers remain and no work: done
                break
            dt = max(dt, 0.0)
            t += dt
            self.now = t  # generators resumed below read the event time
            for a in workers + flushers:
                a.advance(t, dt)
        makespan = t
        return SimResult(
            makespan=makespan,
            bytes_by_tier=dict(self.bytes_by_tier),
            flush_tail_s=makespan - (app_done_t if app_done_t is not None else makespan),
            app_done_s=app_done_t if app_done_t is not None else makespan,
            resolver_hits=self.resolver_hits,
            resolver_misses=self.resolver_misses,
            readahead_hits=self.readahead_hits,
            readahead_staged=self.readahead_staged,
            ttfb_s=self.ttfb_s if self.ttfb_s is not None else makespan,
            extents_staged=self.extents_staged,
            peer_hits=self.peer_hits,
            peer_pull_bytes=self.peer_pull_bytes,
            degraded_placements=self.degraded_placements,
        )

    def _has_flush_work(self) -> bool:
        return any(nd.flush_q or nd.readahead_q for nd in self.nodes)

    def _effective_caps(self, flows: list[Flow]) -> dict[str, float]:
        """MDS/RPC contention model (paper §4.2): when the number of
        concurrent Lustre write streams exceeds the OST count, collective
        write throughput degrades — this is what pushes measured Lustre
        above the model's upper bound in Experiment 4."""
        cl = self.cl
        k_w = sum(1 for f in flows if "lus_backend_w" in f.path)
        if k_w <= cl.d or cl.mds_beta <= 0:
            return self.caps
        caps = dict(self.caps)
        factor = 1.0 + cl.mds_beta * (k_w - cl.d) / cl.d
        caps["lus_backend_w"] = cl.L_backend_w / factor
        caps["lus_flush_eff"] = cl.flush_efficiency * caps["lus_backend_w"]
        return caps


class _Agent:
    """Drives one op-generator: holds its current flow or compute timer."""

    def __init__(self, gen, has_work=None):
        self.gen = gen
        self.flow: Flow | None = None
        self.wake_at: float | None = None
        self.idle = False
        self.done = False
        self.has_work = has_work  # idle agents re-poll only when true

    def ensure_started(self, t: float) -> None:
        if self.done or self.flow is not None or self.wake_at is not None:
            return
        self._next(t)

    def _next(self, t: float) -> None:
        try:
            op = next(self.gen)
        except StopIteration:
            self.done = True
            self.flow = None
            self.wake_at = None
            return
        if op is None:           # idle flusher poll
            self.idle = True
            self.flow = None
            self.wake_at = None
        elif isinstance(op, ComputeOp):
            self.idle = False
            self.wake_at = t + op.seconds
            self.flow = None
        else:
            self.idle = False
            self.flow = Flow(
                path=op.path,
                remaining=op.nbytes,
                owner=self,
                cap=getattr(op, "cap", 0.0),
            )
            self.wake_at = None

    def advance(self, t: float, dt: float) -> None:
        if self.done:
            return
        if self.flow is not None:
            self.flow.remaining -= self.flow.rate * dt
            if self.flow.remaining <= EPS:
                self.flow = None
                self._next(t)
        elif self.wake_at is not None:
            if t + EPS >= self.wake_at:
                self.wake_at = None
                self._next(t)
        elif self.idle and (self.has_work is None or self.has_work()):
            self._next(t)
