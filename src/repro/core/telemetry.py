"""Per-tier telemetry counters.

Lightweight, thread-safe counters so benchmarks and the framework can see
where bytes actually went (tier hit ratios, flush/evict volumes). Purely
observational — placement never consults telemetry (Sea stays stateless).

Counters are **per-process**: with ``shared_ledger`` deployments every Sea
instance exports its snapshot to ``<base_root>/.sea_ledger/telemetry/`` at
shutdown, and :func:`aggregate_snapshots` / :func:`load_aggregate` merge
them into one node-wide view (the numbers the paper reports per node).
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field


@dataclass
class TierCounters:
    bytes_written: int = 0
    bytes_read: int = 0
    files_written: int = 0
    files_read: int = 0
    read_seconds: float = 0.0
    write_seconds: float = 0.0


@dataclass
class TransferCounters:
    """One tier pair ("src->dst") of the transfer engine's data plane."""

    nbytes: int = 0
    files: int = 0
    seconds: float = 0.0
    retries: int = 0


class ThreadCounters:
    """Per-thread counter block for the open fast path: plain int/float
    increments with **no lock at all** (each block is written by exactly
    one thread; CPython attribute stores are GIL-atomic). ``snapshot``
    folds the live blocks in non-destructively — counters only grow, so
    summing base + per-thread values is always an under-by-at-most-one
    -in-flight-increment view and exact once threads quiesce. Blocks of
    dead threads are folded into the base counters and dropped, so
    thread churn cannot grow the registry without bound."""

    __slots__ = ("owner", "redirect_hits", "fastpath_opens", "io_read")

    def __init__(self):
        self.owner = threading.current_thread()
        self.redirect_hits = 0
        self.fastpath_opens = 0
        #: tier -> [bytes_read, files_read, read_seconds]
        self.io_read: dict[str, list] = {}

    def record_read(self, tier: str, nbytes: int, seconds: float) -> None:
        c = self.io_read.get(tier)
        if c is None:
            c = self.io_read[tier] = [0, 0, 0.0]
        c[0] += nbytes
        c[1] += 1
        c[2] += seconds


#: The canonical counter registry: every scalar counter ``Telemetry``
#: carries, with its meaning. This table is the single source of truth —
#: ``snapshot()`` iterates it (a counter missing here silently vanishes
#: from exports, so it must not be missable), and ``seacheck``'s
#: telemetry-drift rule cross-checks it lexically against the dataclass
#: fields and every increment site, in both directions. Add a counter by
#: adding the field AND the registry row; the lint gate fails on either
#: half alone.
COUNTERS: dict[str, str] = {
    "transfer_orphans_reaped": "dead .sea_tmp staging files swept",
    "flushed_bytes": "bytes flushed cache->base",
    "flushed_files": "files flushed cache->base",
    "flush_failures": "flushes abandoned after exhausting retries",
    "evicted_bytes": "bytes evicted from cache tiers",
    "evicted_files": "files evicted from cache tiers",
    "prefetched_bytes": "bytes staged by static prefetch lists",
    "redirect_hits": "paths under the mount that Sea translated",
    "passthrough": "paths outside the mount (left untouched)",
    "ledger_hits": "O(1) capacity queries answered by the ledger",
    "ledger_reconciles": "full-root walks (reconcile path only)",
    "resolver_hits": "resolutions served by the location index",
    "resolver_misses": "full probe cascades (cold or invalidated)",
    "resolver_negative_hits": "misses absorbed by the negative cache",
    "resolver_verify_fails": "cached paths that vanished (file moved)",
    "resolver_invalidations": "entries dropped by mutation paths",
    "dir_index_hits": "listdir unions served by the child index",
    "dir_index_misses": "listdir unions that re-walked the roots",
    "readahead_predictions": "speculative keys the predictor emitted",
    "readahead_staged_files": "predictions whose staging copy committed",
    "readahead_staged_bytes": "bytes speculatively staged base->cache",
    "readahead_hits": "predicted keys subsequently opened",
    "readahead_hit_bytes": "staged bytes that were then read hot",
    "readahead_wasted_bytes": "staged bytes expired/cancelled unread",
    "extent_hits": "reads served from a staged extent",
    "extent_hit_bytes": "bytes those reads served from cache",
    "extent_misses": "reads that found the extent unstaged",
    "extent_miss_bytes": "bytes served from the base fallback",
    "extents_staged": "extents whose staging copy committed",
    "extent_staged_bytes": "bytes staged base->cache per-extent",
    "extents_punched": "staged extents evicted by punch-hole",
    "extent_punched_bytes": "bytes those punches deallocated",
    "extent_promotions": "part files completed -> whole replicas",
    "peer_hits": "local misses served by a peer's cache",
    "peer_pull_bytes": "bytes pulled peer->cache",
    "peer_fallbacks": "peer pulls that failed and fell back to base",
    "fastpath_opens": "read opens served by the lock-free fast path",
    "fastpath_redirect_hits": "redirects taken on the fast path",
    "ckpt_save_s": "seconds the step loop was blocked in save",
    "ckpt_bytes": "checkpoint leaf payload bytes written",
    "ckpt_overlap_hits": "async saves that finished with no waiter",
    "ckpt_restore_fallbacks": "corrupt checkpoints discarded by restore",
    "device_feed_stalls": "device_iter consumers that found the feed empty",
    "root_quarantines": "cache roots newly quarantined by the circuit breaker",
    "breaker_opens": "breaker open transitions (incl. half-open re-trips)",
    "degraded_reads": "reads rerouted around a sick root (other root/peer/base)",
    "deadline_aborts": "transfers aborted by the progress-deadline watchdog",
    "hung_thread_joins": "worker threads still alive after a bounded stop() join",
}


@dataclass
class Telemetry:
    per_tier: dict[str, TierCounters] = field(
        default_factory=lambda: defaultdict(TierCounters)
    )
    transfers: dict[str, TransferCounters] = field(
        default_factory=lambda: defaultdict(TransferCounters)
    )
    transfer_orphans_reaped: int = 0  # dead .sea_tmp staging files swept
    flushed_bytes: int = 0
    flushed_files: int = 0
    flush_failures: int = 0    # flushes abandoned after exhausting retries
    evicted_bytes: int = 0
    evicted_files: int = 0
    prefetched_bytes: int = 0
    redirect_hits: int = 0     # paths under the mount that Sea translated
    passthrough: int = 0       # paths outside the mount (left untouched)
    ledger_hits: int = 0       # O(1) capacity queries answered by the ledger
    ledger_reconciles: int = 0  # full-root walks (reconcile path only)
    resolver_hits: int = 0          # resolutions served by the location index
    resolver_misses: int = 0        # full probe cascades (cold or invalidated)
    resolver_negative_hits: int = 0  # misses absorbed by the negative cache
    resolver_verify_fails: int = 0  # cached paths that vanished (file moved)
    resolver_invalidations: int = 0  # entries dropped by mutation paths
    dir_index_hits: int = 0         # listdir unions served by the child index
    dir_index_misses: int = 0       # listdir unions that re-walked the roots
    readahead_predictions: int = 0  # speculative keys the predictor emitted
    readahead_staged_files: int = 0  # predictions whose staging copy committed
    readahead_staged_bytes: int = 0  # bytes speculatively staged base->cache
    readahead_hits: int = 0         # predicted keys subsequently opened
    readahead_hit_bytes: int = 0    # staged bytes that were then read hot
    readahead_wasted_bytes: int = 0  # staged bytes expired/cancelled unread
    extent_hits: int = 0            # reads served from a staged extent
    extent_hit_bytes: int = 0       # bytes those reads served from cache
    extent_misses: int = 0          # reads that found the extent unstaged
    extent_miss_bytes: int = 0      # bytes served from the base fallback
    extents_staged: int = 0         # extents whose staging copy committed
    extent_staged_bytes: int = 0    # bytes staged base->cache per-extent
    extents_punched: int = 0        # staged extents evicted by punch-hole
    extent_punched_bytes: int = 0   # bytes those punches deallocated
    extent_promotions: int = 0      # part files completed -> whole replicas
    peer_hits: int = 0              # local misses served by a peer's cache
    peer_pull_bytes: int = 0        # bytes pulled peer->cache
    peer_fallbacks: int = 0         # peer pulls that failed (peer died or
                                    # evicted mid-pull) and fell back to base
    fastpath_opens: int = 0         # read opens served by the lock-free
                                    # fast path (base: folded dead threads)
    fastpath_redirect_hits: int = 0  # redirects taken on the fast path
                                     # (base: folded dead threads)
    ckpt_save_s: float = 0.0        # seconds the step loop was blocked in
                                    # CheckpointManager.save (async saves
                                    # count only snapshot + handoff)
    ckpt_bytes: int = 0             # checkpoint leaf payload bytes written
    ckpt_overlap_hits: int = 0      # async saves whose background write
                                    # finished with no caller blocked on the
                                    # handle (the overlap fully hid the I/O)
    ckpt_restore_fallbacks: int = 0  # checkpoints discarded by restore_latest
                                     # (corrupt/partial) before an older step
                                     # restored
    device_feed_stalls: int = 0     # device_iter consumers that found the
                                    # feed queue empty (compute outran the
                                    # host->device stage)
    root_quarantines: int = 0       # cache roots newly quarantined (closed ->
                                    # open breaker transitions)
    breaker_opens: int = 0          # every open transition, including a
                                    # half-open probe failing back to open
    degraded_reads: int = 0         # reads served from another root, a peer,
                                    # or base because the placed root is sick
    deadline_aborts: int = 0        # copies aborted because no chunk progress
                                    # happened within transfer_deadline_s
    hung_thread_joins: int = 0      # stop() joins that timed out with the
                                    # worker thread still alive
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _tls: threading.local = field(default_factory=threading.local, repr=False)
    _locals: list = field(default_factory=list, repr=False)

    def record_io(
        self, tier: str, *, read: int = 0, written: int = 0, seconds: float = 0.0
    ) -> None:
        with self._lock:
            c = self.per_tier[tier]
            if read:
                c.bytes_read += read
                c.files_read += 1
                c.read_seconds += seconds
            if written:
                c.bytes_written += written
                c.files_written += 1
                c.write_seconds += seconds

    def record_transfer(
        self, pair: str, *, nbytes: int, seconds: float = 0.0, retries: int = 0
    ) -> None:
        """One committed engine transfer over a ``"src->dst"`` tier pair —
        ``nbytes / seconds`` is that pair's observed bytes/sec."""
        with self._lock:
            c = self.transfers[pair]
            c.nbytes += nbytes
            c.files += 1
            c.seconds += seconds
            c.retries += retries

    def record_orphan_reaped(self) -> None:
        with self._lock:
            self.transfer_orphans_reaped += 1

    def transfer_rate_bps(self, pair: str) -> float:
        """Observed mean bytes/sec of one tier pair (0 when unmeasured)."""
        with self._lock:
            c = self.transfers.get(pair)
            if c is None or c.seconds <= 0:
                return 0.0
            return c.nbytes / c.seconds

    def record_flush(self, nbytes: int) -> None:
        with self._lock:
            self.flushed_bytes += nbytes
            self.flushed_files += 1

    def record_flush_failure(self) -> None:
        with self._lock:
            self.flush_failures += 1

    def record_evict(self, nbytes: int) -> None:
        with self._lock:
            self.evicted_bytes += nbytes
            self.evicted_files += 1

    def record_prefetch(self, nbytes: int) -> None:
        with self._lock:
            self.prefetched_bytes += nbytes

    def record_redirect(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self.redirect_hits += 1
            else:
                self.passthrough += 1

    def record_ledger_hit(self) -> None:
        with self._lock:
            self.ledger_hits += 1

    def record_ledger_reconcile(self) -> None:
        with self._lock:
            self.ledger_reconciles += 1

    def record_resolve(
        self, *, hit: bool, negative: bool = False, verify_failed: bool = False
    ) -> None:
        with self._lock:
            if hit:
                self.resolver_hits += 1
                if negative:
                    self.resolver_negative_hits += 1
            else:
                self.resolver_misses += 1
                if verify_failed:
                    self.resolver_verify_fails += 1

    def record_resolver_invalidate(self) -> None:
        with self._lock:
            self.resolver_invalidations += 1

    def record_dir_resolve(self, *, hit: bool) -> None:
        with self._lock:
            if hit:
                self.dir_index_hits += 1
            else:
                self.dir_index_misses += 1

    # -- readahead (predictive prefetch) ------------------------------------
    def record_readahead_prediction(self) -> None:
        with self._lock:
            self.readahead_predictions += 1

    def record_readahead_staged(self, nbytes: int) -> None:
        with self._lock:
            self.readahead_staged_files += 1
            self.readahead_staged_bytes += nbytes

    def record_readahead_hit(self, nbytes: int, *, count: bool = True) -> None:
        """``count=False`` back-fills bytes for a hit already counted
        (the staging copy committed after the predicted open)."""
        with self._lock:
            if count:
                self.readahead_hits += 1
            self.readahead_hit_bytes += nbytes

    def record_readahead_waste(self, nbytes: int) -> None:
        with self._lock:
            self.readahead_wasted_bytes += nbytes

    # -- extent plane (block-granular staging) -------------------------------
    def record_extent_read(self, *, hit: bool, nbytes: int = 0) -> None:
        with self._lock:
            if hit:
                self.extent_hits += 1
                self.extent_hit_bytes += nbytes
            else:
                self.extent_misses += 1
                self.extent_miss_bytes += nbytes

    def record_extent_staged(self, nbytes: int) -> None:
        with self._lock:
            self.extents_staged += 1
            self.extent_staged_bytes += nbytes

    def record_extent_punched(self, nbytes: int) -> None:
        with self._lock:
            self.extents_punched += 1
            self.extent_punched_bytes += nbytes

    def record_extent_promoted(self) -> None:
        with self._lock:
            self.extent_promotions += 1

    # -- federation (peer-aware miss resolution) -----------------------------
    def record_peer_hit(self, nbytes: int) -> None:
        with self._lock:
            self.peer_hits += 1
            self.peer_pull_bytes += nbytes

    def record_peer_fallback(self) -> None:
        with self._lock:
            self.peer_fallbacks += 1

    # -- training I/O (checkpoint writer + device feed) ----------------------
    def record_ckpt_save(self, seconds: float, nbytes: int = 0) -> None:
        with self._lock:
            self.ckpt_save_s += seconds
            self.ckpt_bytes += nbytes

    def record_ckpt_overlap_hit(self) -> None:
        with self._lock:
            self.ckpt_overlap_hits += 1

    def record_ckpt_restore_fallback(self) -> None:
        with self._lock:
            self.ckpt_restore_fallbacks += 1

    def record_device_feed_stall(self) -> None:
        with self._lock:
            self.device_feed_stalls += 1

    def record_root_quarantine(self) -> None:
        with self._lock:
            self.root_quarantines += 1

    def record_breaker_open(self) -> None:
        with self._lock:
            self.breaker_opens += 1

    def record_degraded_read(self) -> None:
        with self._lock:
            self.degraded_reads += 1

    def record_deadline_abort(self) -> None:
        with self._lock:
            self.deadline_aborts += 1

    def record_hung_thread_join(self) -> None:
        with self._lock:
            self.hung_thread_joins += 1

    # -- thread-batched fast-path counters ----------------------------------
    def local(self) -> ThreadCounters:
        """This thread's lock-free counter block (created and registered
        on first use). The open fast path writes here — one attribute
        store per event instead of a mutex round-trip."""
        lc = getattr(self._tls, "counters", None)
        if lc is None:
            lc = self._tls.counters = ThreadCounters()
            with self._lock:
                self._fold_dead_locked()
                self._locals.append(lc)
        return lc

    # seacheck: holds-lock
    def _fold_dead_locked(self) -> None:
        """Fold counter blocks of dead threads into the base counters and
        drop them (caller holds ``self._lock``). Safe: a dead thread can
        no longer write its block."""
        if all(lc.owner.is_alive() for lc in self._locals):
            return
        live = []
        for lc in self._locals:
            if lc.owner.is_alive():
                live.append(lc)
                continue
            self.redirect_hits += lc.redirect_hits
            self.fastpath_redirect_hits += lc.redirect_hits
            self.fastpath_opens += lc.fastpath_opens
            for tier, (nbytes, files, seconds) in lc.io_read.items():
                c = self.per_tier[tier]
                c.bytes_read += nbytes
                c.files_read += files
                c.read_seconds += seconds
        self._locals = live

    def snapshot(self) -> dict:
        with self._lock:
            self._fold_dead_locked()
            snap = {
                "tiers": {
                    k: vars(v).copy() for k, v in sorted(self.per_tier.items())
                },
                "transfers": {
                    k: vars(v).copy() for k, v in sorted(self.transfers.items())
                },
            }
            for name in COUNTERS:
                snap[name] = getattr(self, name)
            locals_ = list(self._locals)
        # fold the LIVE per-thread fast-path blocks in (non-destructive
        # sums: the blocks only grow and are never reset, so no event is
        # ever double-counted or lost once its thread quiesces; dead
        # threads' blocks were folded into the base counters above)
        live_redirects = 0
        for lc in locals_:
            snap["fastpath_opens"] += lc.fastpath_opens
            snap["fastpath_redirect_hits"] += lc.redirect_hits
            live_redirects += lc.redirect_hits
            for tier in tuple(lc.io_read):
                nbytes, files, seconds = lc.io_read[tier]
                c = snap["tiers"].setdefault(
                    tier,
                    {
                        "bytes_written": 0,
                        "bytes_read": 0,
                        "files_written": 0,
                        "files_read": 0,
                        "read_seconds": 0.0,
                        "write_seconds": 0.0,
                    },
                )
                c["bytes_read"] += nbytes
                c["files_read"] += files
                c["read_seconds"] += seconds
        snap["redirect_hits"] += live_redirects
        return snap

    def export(self, path: str) -> str:
        """Write this process's snapshot (plus pid/timestamp) as JSON —
        atomically, so a concurrent aggregator never reads a torn file."""
        snap = self.snapshot()
        snap["pid"] = os.getpid()
        snap["exported_at"] = time.time()
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(snap, f)
        os.replace(tmp, path)
        return path


def aggregate_snapshots(snapshots: list[dict]) -> dict:
    """Merge per-process snapshots into one aggregate view: numeric
    counters sum (per tier and global); pids are collected for attribution."""
    agg: dict = {"tiers": {}, "transfers": {}, "pids": []}
    for snap in snapshots:
        if "pid" in snap:
            agg["pids"].append(snap["pid"])
        for section in ("tiers", "transfers"):
            for name, counters in snap.get(section, {}).items():
                out = agg[section].setdefault(name, defaultdict(float))
                for k, v in counters.items():
                    out[k] += v
        for k, v in snap.items():
            if k in ("tiers", "transfers", "pid", "exported_at"):
                continue
            if isinstance(v, (int, float)):
                agg[k] = agg.get(k, 0) + v
    agg["tiers"] = {t: dict(c) for t, c in agg["tiers"].items()}
    agg["transfers"] = {t: dict(c) for t, c in agg["transfers"].items()}
    agg["pids"].sort()
    return agg


def load_aggregate(stats_dir: str) -> dict:
    """Aggregate every exported per-process snapshot under ``stats_dir``
    (the ``.sea_ledger/telemetry/`` directory of a shared hierarchy)."""
    snaps = []
    try:
        names = sorted(os.listdir(stats_dir))
    except FileNotFoundError:
        names = []
    for fn in names:
        if not fn.endswith(".json"):
            continue
        try:
            with open(os.path.join(stats_dir, fn)) as f:
                snaps.append(json.load(f))
        except (OSError, ValueError):
            continue
    return aggregate_snapshots(snaps)


class Stopwatch:
    """Context timer used around raw I/O calls."""

    __slots__ = ("t0", "elapsed")

    def __enter__(self) -> "Stopwatch":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self.t0
