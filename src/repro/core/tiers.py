"""Storage-tier abstraction for the Sea data-placement hierarchy.

A *tier* is one level of the user-declared storage hierarchy (paper §3.1:
"Sea requires the user to specify at least two storage devices, a fast
temporary device used as cache and a slower long-term storage device").
Levels are ordered fastest-first; the last tier is the *base* (long-term,
persistent) tier — the Lustre/PFS analogue. A level may contain several
*roots* (e.g. 6 local SSDs): Sea selects among same-level roots by random
shuffle, mirroring the paper's metadata-server-free design.
"""

from __future__ import annotations

import os
import shutil
from dataclasses import dataclass, field

from .ledger import (
    LEDGER_DIRNAME,
    TMP_SUFFIX,
    CapacityLedger,
    Reservation,
    file_disk_usage,
)
from .shared_ledger import SharedCapacityLedger


@dataclass
class TierSpec:
    """Static description of one storage level.

    Bandwidths are used by the performance model / simulator and by
    benchmarks; placement itself only needs capacities.
    """

    name: str
    roots: tuple[str, ...]
    read_bw: float = 0.0          # bytes/s, 0 = unknown
    write_bw: float = 0.0         # bytes/s, 0 = unknown
    capacity: int | None = None   # per-root byte cap; None = ask the OS
    persistent: bool = False      # True only for the base (PFS) tier

    def __post_init__(self) -> None:
        if isinstance(self.roots, str):
            self.roots = (self.roots,)
        self.roots = tuple(os.path.abspath(r) for r in self.roots)
        if not self.roots:
            raise ValueError(f"tier {self.name!r} needs at least one root")


class Tier:
    """A live tier: spec + capacity accounting over its roots.

    With a :class:`~repro.core.ledger.CapacityLedger` attached (the
    default through :class:`Hierarchy`), ``used_bytes``/``free_bytes``
    are O(1) counter lookups; the full ``os.walk`` survives only as the
    ledger's reconcile path. ``ledger=None`` restores the seed's
    stateless per-call rescan (used by benchmarks as the baseline).
    """

    def __init__(
        self,
        spec: TierSpec,
        level: int,
        ledger: CapacityLedger | SharedCapacityLedger | None = None,
    ):
        self.spec = spec
        self.level = level
        self.ledger = ledger
        for root in spec.roots:
            os.makedirs(root, exist_ok=True)

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def roots(self) -> tuple[str, ...]:
        return self.spec.roots

    @property
    def persistent(self) -> bool:
        return self.spec.persistent

    # -- capacity ----------------------------------------------------------
    def scan_used_bytes(self, root: str) -> int:
        """Bytes used under one root by a full re-scan (the seed's per-call
        behaviour; now the reconcile/baseline path only). In-flight
        ``.sea_tmp`` staging files are not data: counting one that a
        failed transfer later unlinks would overstate ``used`` with bytes
        nothing ever removes. Sizes are sparse-aware (``file_disk_usage``)
        so a partial extent replica counts its staged blocks, not the
        holes."""
        total = 0
        for dirpath, dirnames, filenames in os.walk(root):
            if LEDGER_DIRNAME in dirnames:
                dirnames.remove(LEDGER_DIRNAME)
            for fn in filenames:
                if fn.endswith(TMP_SUFFIX):
                    continue
                try:
                    total += file_disk_usage(os.path.join(dirpath, fn))
                except OSError:
                    pass
        return total

    def used_bytes(self, root: str) -> int:
        """Bytes used under one root — O(1) via the ledger when attached."""
        if self.ledger is not None:
            return self.ledger.used_bytes(root)
        return self.scan_used_bytes(root)

    def reserved_bytes(self, root: str) -> int:
        """In-flight write budget currently held against one root."""
        if self.ledger is not None:
            return self.ledger.reserved_bytes(root)
        return 0

    def free_bytes(self, root: str) -> int:
        """Free bytes on one root, honouring the configured cap if any and
        discounting in-flight write reservations.

        The paper: "Sea queries all the available file systems directly to
        determine the amount of available space." — the ledger caches that
        query and reconciles against the file system periodically.
        """
        reserved = self.reserved_bytes(root)
        if self.spec.capacity is not None:
            return max(self.spec.capacity - self.used_bytes(root) - reserved, 0)
        try:
            st = os.statvfs(root)
            return max(st.f_bavail * st.f_frsize - reserved, 0)
        except OSError:
            return 0

    def total_free_bytes(self) -> int:
        return sum(self.free_bytes(r) for r in self.roots)

    def admissible(self, root: str, *, required: int, nbytes: int) -> bool:
        """Would a new ``nbytes`` write be admitted on this root?  Mirrors
        :meth:`CapacityLedger.try_reserve`: existing reservations count
        toward the ``required`` worst-case headroom rather than on top of
        it, so one in-flight writer does not disqualify a root that still
        provably fits another."""
        if self.spec.capacity is None:
            return self.free_bytes(root) >= required
        reserved = self.reserved_bytes(root)
        return self.spec.capacity - self.used_bytes(root) >= max(
            required, reserved + nbytes
        )

    # -- ledger notifications (no-ops when running stateless) ---------------
    def note_written(self, root: str, key: str, nbytes: int) -> None:
        if self.ledger is not None:
            self.ledger.note_written(root, key, nbytes)

    def note_removed(self, root: str, key: str) -> None:
        if self.ledger is not None:
            self.ledger.note_removed(root, key)

    def reserve_write(self, root: str, nbytes: int) -> Reservation | None:
        if self.ledger is not None:
            return self.ledger.reserve(root, nbytes)
        return None

    def commit_write(
        self, res: Reservation | None, root: str, key: str, nbytes: int
    ) -> None:
        if self.ledger is None:
            return
        if res is not None:
            self.ledger.commit(res, key, nbytes)
        else:
            self.ledger.note_written(root, key, nbytes)

    def release_write(self, res: Reservation | None) -> None:
        if self.ledger is not None and res is not None:
            self.ledger.release(res)

    def reconcile(self) -> None:
        """On-demand reconciliation of every root of this tier."""
        if self.ledger is not None:
            for root in self.roots:
                self.ledger.reconcile(root)

    def root_of(self, path: str) -> str | None:
        """The root of this tier that ``path`` lives under, if any."""
        ap = os.path.abspath(path)
        for root in self.roots:
            if ap == root or ap.startswith(root + os.sep):
                return root
        return None

    def locate(self, relpath: str) -> str | None:
        """Return the real path of ``relpath`` if present on this tier."""
        for root in self.roots:
            p = os.path.join(root, relpath)
            if os.path.lexists(p):
                return p
        return None

    def wipe(self) -> None:
        for root in self.roots:
            if os.path.isdir(root):
                shutil.rmtree(root, ignore_errors=True)
            os.makedirs(root, exist_ok=True)
            if self.ledger is not None:
                self.ledger.forget(root)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Tier(level={self.level}, name={self.name!r}, roots={self.roots})"


@dataclass
class Hierarchy:
    """Ordered collection of tiers, fastest (level 0) first. All tiers
    share one :class:`CapacityLedger` (sharded internally by root)."""

    tiers: list[Tier] = field(default_factory=list)
    ledger: CapacityLedger | SharedCapacityLedger | None = None

    @classmethod
    def from_specs(
        cls,
        specs: list[TierSpec],
        *,
        ledger: CapacityLedger | SharedCapacityLedger | None = None,
        use_ledger: bool = True,
        shared: bool = False,
        reconcile_interval_s: float = 5.0,
    ) -> "Hierarchy":
        if len(specs) < 2:
            raise ValueError(
                "Sea requires at least two storage devices: a fast cache "
                "tier and a slower long-term tier (paper §3.1)"
            )
        if not specs[-1].persistent:
            specs[-1].persistent = True  # last tier is the base by definition
        if ledger is None and use_ledger:
            # shared: file-backed, fcntl-guarded accounting every process
            # mounting this hierarchy sees; default: in-process counters
            cls_ledger = SharedCapacityLedger if shared else CapacityLedger
            ledger = cls_ledger(reconcile_interval_s=reconcile_interval_s)
        return cls([Tier(s, i, ledger) for i, s in enumerate(specs)], ledger)

    def owner_of(self, path: str) -> tuple[Tier, str] | None:
        """The (tier, root) a real path lives under, if any."""
        for tier in self.tiers:
            root = tier.root_of(path)
            if root is not None:
                return tier, root
        return None

    def reconcile(self) -> None:
        """On-demand reconciliation of every root of every tier."""
        for tier in self.tiers:
            tier.reconcile()

    @property
    def base(self) -> Tier:
        """The long-term (persistent) tier — Lustre/PFS analogue."""
        return self.tiers[-1]

    @property
    def cache_tiers(self) -> list[Tier]:
        """All ephemeral tiers, fastest first."""
        return self.tiers[:-1]

    def __iter__(self):
        return iter(self.tiers)

    def __len__(self) -> int:
        return len(self.tiers)

    @property
    def total_roots(self) -> int:
        """Number of probe targets one full resolution cascade touches."""
        return sum(len(t.roots) for t in self.tiers)

    def locate(self, relpath: str) -> tuple[Tier, str] | None:
        """Find a file across the hierarchy, fastest tier first.

        This is the stateless resolution at the heart of Sea: no metadata
        server — a file's location IS its state on the file systems.
        (:class:`~repro.core.resolver.Resolver` caches this cascade; this
        method remains the source-of-truth fallback.)
        """
        for tier in self.tiers:
            real = tier.locate(relpath)
            if real is not None:
                return tier, real
        return None

    def locate_above(self, relpath: str, level: int) -> tuple[Tier, str] | None:
        """Find a replica on a tier *faster* than ``level`` — the
        write-side verify: an overwrite of a cached hit must never miss a
        faster copy (probes zero roots when ``level`` is already 0)."""
        for tier in self.tiers:
            if tier.level >= level:
                break
            real = tier.locate(relpath)
            if real is not None:
                return tier, real
        return None

    def locate_all(self, relpath: str) -> list[tuple[Tier, str]]:
        """Every replica of ``relpath`` across every root of every tier
        (``locate`` stops at the first hit per tier; removal must not)."""
        out: list[tuple[Tier, str]] = []
        for tier in self.tiers:
            for root in tier.roots:
                p = os.path.join(root, relpath)
                if os.path.lexists(p):
                    out.append((tier, p))
        return out
