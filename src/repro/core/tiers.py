"""Storage-tier abstraction for the Sea data-placement hierarchy.

A *tier* is one level of the user-declared storage hierarchy (paper §3.1:
"Sea requires the user to specify at least two storage devices, a fast
temporary device used as cache and a slower long-term storage device").
Levels are ordered fastest-first; the last tier is the *base* (long-term,
persistent) tier — the Lustre/PFS analogue. A level may contain several
*roots* (e.g. 6 local SSDs): Sea selects among same-level roots by random
shuffle, mirroring the paper's metadata-server-free design.
"""

from __future__ import annotations

import os
import shutil
from dataclasses import dataclass, field


@dataclass
class TierSpec:
    """Static description of one storage level.

    Bandwidths are used by the performance model / simulator and by
    benchmarks; placement itself only needs capacities.
    """

    name: str
    roots: tuple[str, ...]
    read_bw: float = 0.0          # bytes/s, 0 = unknown
    write_bw: float = 0.0         # bytes/s, 0 = unknown
    capacity: int | None = None   # per-root byte cap; None = ask the OS
    persistent: bool = False      # True only for the base (PFS) tier

    def __post_init__(self) -> None:
        if isinstance(self.roots, str):
            self.roots = (self.roots,)
        self.roots = tuple(os.path.abspath(r) for r in self.roots)
        if not self.roots:
            raise ValueError(f"tier {self.name!r} needs at least one root")


class Tier:
    """A live tier: spec + capacity probing over its roots."""

    def __init__(self, spec: TierSpec, level: int):
        self.spec = spec
        self.level = level
        for root in spec.roots:
            os.makedirs(root, exist_ok=True)

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def roots(self) -> tuple[str, ...]:
        return self.spec.roots

    @property
    def persistent(self) -> bool:
        return self.spec.persistent

    # -- capacity ----------------------------------------------------------
    def used_bytes(self, root: str) -> int:
        """Bytes used under one root (stateless re-scan, as in the paper:
        the file system itself is the source of truth)."""
        total = 0
        for dirpath, _dirnames, filenames in os.walk(root):
            for fn in filenames:
                try:
                    total += os.path.getsize(os.path.join(dirpath, fn))
                except OSError:
                    pass
        return total

    def free_bytes(self, root: str) -> int:
        """Free bytes on one root, honouring the configured cap if any.

        The paper: "Sea queries all the available file systems directly to
        determine the amount of available space."
        """
        if self.spec.capacity is not None:
            return max(self.spec.capacity - self.used_bytes(root), 0)
        try:
            st = os.statvfs(root)
            return st.f_bavail * st.f_frsize
        except OSError:
            return 0

    def total_free_bytes(self) -> int:
        return sum(self.free_bytes(r) for r in self.roots)

    def locate(self, relpath: str) -> str | None:
        """Return the real path of ``relpath`` if present on this tier."""
        for root in self.roots:
            p = os.path.join(root, relpath)
            if os.path.lexists(p):
                return p
        return None

    def wipe(self) -> None:
        for root in self.roots:
            if os.path.isdir(root):
                shutil.rmtree(root, ignore_errors=True)
            os.makedirs(root, exist_ok=True)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Tier(level={self.level}, name={self.name!r}, roots={self.roots})"


@dataclass
class Hierarchy:
    """Ordered collection of tiers, fastest (level 0) first."""

    tiers: list[Tier] = field(default_factory=list)

    @classmethod
    def from_specs(cls, specs: list[TierSpec]) -> "Hierarchy":
        if len(specs) < 2:
            raise ValueError(
                "Sea requires at least two storage devices: a fast cache "
                "tier and a slower long-term tier (paper §3.1)"
            )
        if not specs[-1].persistent:
            specs[-1].persistent = True  # last tier is the base by definition
        return cls([Tier(s, i) for i, s in enumerate(specs)])

    @property
    def base(self) -> Tier:
        """The long-term (persistent) tier — Lustre/PFS analogue."""
        return self.tiers[-1]

    @property
    def cache_tiers(self) -> list[Tier]:
        """All ephemeral tiers, fastest first."""
        return self.tiers[:-1]

    def __iter__(self):
        return iter(self.tiers)

    def __len__(self) -> int:
        return len(self.tiers)

    def locate(self, relpath: str) -> tuple[Tier, str] | None:
        """Find a file across the hierarchy, fastest tier first.

        This is the stateless resolution at the heart of Sea: no metadata
        server — a file's location IS its state on the file systems.
        """
        for tier in self.tiers:
            real = tier.locate(relpath)
            if real is not None:
                return tier, real
        return None
