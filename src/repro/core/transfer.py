"""TransferEngine — the data plane: every byte moved between tiers.

PRs 1–3 made Sea's *metadata* hot paths O(1); the actual bytes, however,
still moved through five independent synchronous ``shutil.copyfile`` call
sites (cross-mount rename, persist, flush, prefetch, pipeline staging)
with divergent atomicity, locking, and capacity-accounting semantics.
This module unifies them behind one engine, following the chunked,
overlapped-transfer designs of the HSM follow-up work (Hayot-Sasson &
Glatard 2024) and the openPMD/ADIOS2 streaming pipelines (Poeschel et
al. 2021):

* **Chunked copies** via ``os.copy_file_range`` (zero userspace copies,
  reflink/server-side offload where the filesystem supports it), falling
  back per-transfer to ``os.sendfile`` and finally to buffered
  read/write — the same chunk loop serves throttling, cancellation, and
  fault injection.
* **Admission before bytes move**: the capacity ledger's ``try_reserve``
  runs *before* the first chunk; the reservation is committed with the
  actual on-disk size after the rename, and released on any failure — a
  transfer can never over-commit a capped root or leak budget.
* **Crash-safe commit**: chunks land in a
  ``<dst>.<host>.<pid>.<seq>.sea_tmp`` staging file and the destination
  appears atomically via ``os.replace`` after a size verify, so a
  concurrent reader (or a crash at any chunk boundary) never observes a
  partial file. Orphaned staging files from dead processes are swept by
  :meth:`maybe_reap_orphan` (pid liveness on the owning host, age grace
  everywhere else).
* **Bounded parallelism with backpressure**: a lazy pool of
  ``transfer_workers`` threads executes submitted jobs; the submission
  queue is bounded, so producers block instead of buffering unbounded
  work (the prefetcher's overlap win lives here).
* **Per-tier-pair bandwidth throttling**: token buckets keyed
  ``"src->dst"`` (``SeaConfig.transfer_bandwidth_caps``) pace the chunk
  loop so background flushes can be capped below application I/O.
* **Retry with backoff** on transient ``OSError``; cooperative
  **cancellation** between chunks.

``SeaConfig(transfer_engine=False)`` keeps the atomic-commit and
accounting semantics but moves bytes with one whole-file
``shutil.copyfile`` — the seed's behaviour, kept for benchmarking.
"""

from __future__ import annotations

import errno
import itertools
import os
import queue
import shutil
import socket
import threading
import time

from . import faults
from .config import SeaConfig
from .faults import FALLBACK_ERRNOS, TRANSIENT, classify
from .ledger import TMP_SUFFIX as _TMP_SUFFIX
from .telemetry import Telemetry
from .tiers import Tier

#: errno classification lives in repro.core.faults (one shared table for
#: the engine's retry loop, the flusher's backoff, and the breaker trips);
#: the historical module-private names stay as aliases.
_FALLBACK_ERRNOS = FALLBACK_ERRNOS
_PERMANENT_ERRNOS = faults.PERMANENT_ERRNOS

_HAS_COPY_FILE_RANGE = hasattr(os, "copy_file_range")
_HAS_SENDFILE = hasattr(os, "sendfile")

#: unique staging-file sequence within this process
_TMP_SEQ = itertools.count()

#: host tag embedded in staging-file names — pid liveness is only
#: meaningful on the node that created the file (tiers may be shared
#: parallel file systems); dots are stripped so the name stays parseable
_HOST = (socket.gethostname() or "localhost").replace(".", "-") or "localhost"

#: age past which a staging file not provably owned by a live local
#: process is declared dead. In-flight transfers keep their tmp's mtime
#: fresh (every chunk is a write), so age is a safe cross-node signal.
ORPHAN_GRACE_S = 300.0

#: source-side tier label of a federation peer pull — the bandwidth-cap
#: pair becomes "peer-><cache tier>" (wildcards "peer->*" / "*" apply),
#: so cluster pulls are throttled independently of local tier moves
PEER_TIER = "peer"


class TransferError(OSError):
    """A transfer failed after exhausting its retries."""


class TransferAdmissionError(TransferError):
    """The destination root refused the ledger reservation (no room)."""


class TransferCancelled(TransferError):
    """The transfer's cancel event fired between chunks."""


class TransferDeadlineError(TransferError):
    """The chunk loop made no progress for ``transfer_deadline_s``: the
    watchdog aborted the copy, the reservation was released, and the
    destination root's breaker was tripped."""


class _WatchEntry:
    """One in-flight copy under the progress-deadline watchdog."""

    __slots__ = ("progress", "deadline_s", "cancel", "tripped")

    def __init__(self, cancel: threading.Event, deadline_s: float):
        self.progress = time.monotonic()  # last chunk completion
        self.deadline_s = deadline_s
        self.cancel = cancel
        self.tripped = False


class TransferResult:
    """Outcome of one committed transfer."""

    __slots__ = ("nbytes", "seconds", "attempts", "impl")

    def __init__(self, nbytes: int, seconds: float, attempts: int, impl: str):
        self.nbytes = nbytes
        self.seconds = seconds
        self.attempts = attempts
        self.impl = impl

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"TransferResult(nbytes={self.nbytes}, seconds={self.seconds:.4f}, "
            f"attempts={self.attempts}, impl={self.impl!r})"
        )


class _TokenBucket:
    """Bytes/sec pacing for one tier pair. ``consume`` debits the bucket
    and returns how long the caller must sleep to honour the cap — the
    sleep happens outside the lock so concurrent transfers sharing a pair
    serialize only the arithmetic, not the wait."""

    def __init__(self, rate_bps: float):
        self.rate = float(rate_bps)
        self._lock = threading.Lock()
        self._available = self.rate * 0.05  # small burst allowance
        self._ts = time.monotonic()

    def consume(self, nbytes: int) -> float:
        with self._lock:
            now = time.monotonic()
            self._available = min(
                self._available + (now - self._ts) * self.rate, self.rate * 0.25
            )
            self._ts = now
            self._available -= nbytes
            if self._available >= 0:
                return 0.0
            return -self._available / self.rate


class _Future:
    """Minimal completion handle for a submitted transfer job."""

    __slots__ = ("_done", "_result", "_exc", "cancel_event")

    def __init__(self):
        self._done = threading.Event()
        self._result = None
        self._exc: BaseException | None = None
        self.cancel_event = threading.Event()

    def cancel(self) -> None:
        """Request cooperative cancellation (checked between chunks)."""
        self.cancel_event.set()

    def _finish(self, result=None, exc: BaseException | None = None) -> None:
        self._result = result
        self._exc = exc
        self._done.set()

    def result(self, timeout: float | None = None):
        if not self._done.wait(timeout):
            raise TimeoutError("transfer job still running")
        if self._exc is not None:
            raise self._exc
        return self._result

    def done(self) -> bool:
        return self._done.is_set()


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except (PermissionError, OSError):
        return True
    return True


def tmp_owner(path: str) -> tuple[str, int] | None:
    """Parse the owning (host, pid) out of a
    ``<dst>.<host>.<pid>.<seq>.sea_tmp`` staging-file name. Returns None
    for names the engine did not produce (reaping then falls back to the
    age grace — a numeric suffix in user data must never be mistaken for
    a dead pid)."""
    if not path.endswith(_TMP_SUFFIX):
        return None
    parts = path[: -len(_TMP_SUFFIX)].rsplit(".", 3)
    if len(parts) == 4 and parts[-1].isdigit() and parts[-2].isdigit():
        host = parts[-3]
        if host and "/" not in host:
            return host, int(parts[-2])
    return None


class TransferEngine:
    """One engine per :class:`~repro.core.seafs.SeaFS` instance. The
    engine owns byte movement only; callers keep resolver/telemetry
    semantics (key locks, ``note_location``, flush/prefetch counters)."""

    def __init__(
        self,
        config: SeaConfig,
        telemetry: Telemetry | None = None,
        policy=None,
    ):
        self.enabled = bool(getattr(config, "transfer_engine", True))
        self.chunk_bytes = int(getattr(config, "transfer_chunk_bytes", 32 << 20))
        self.n_workers = max(1, int(getattr(config, "transfer_workers", 4)))
        self.retries = max(0, int(getattr(config, "transfer_retries", 2)))
        self.backoff_s = float(getattr(config, "transfer_backoff_s", 0.02))
        self.deadline_s = float(getattr(config, "transfer_deadline_s", 0.0))
        self.telemetry = telemetry or Telemetry()
        self.policy = policy  # bound by SeaFS after PlacementPolicy exists
        self.health = None  # HealthTracker, bound by SeaFS (optional)
        self._caps_spec = dict(getattr(config, "transfer_bandwidth_caps", {}) or {})
        self._buckets: dict[str, _TokenBucket] = {}
        self._bucket_lock = threading.Lock()
        #: staging paths of in-flight transfers in THIS process — the
        #: orphan reaper must never kill a live transfer's tmp file
        self._active_tmps: set[str] = set()
        self._active_lock = threading.Lock()
        #: fault-injection / instrumentation hook, called after every
        #: committed chunk as ``hook(copied_bytes, total_bytes, dst)``;
        #: an exception it raises fails the transfer like an I/O error
        self.chunk_hook = None
        #: lazy bounded worker pool
        self._q: "queue.Queue" = queue.Queue(maxsize=self.n_workers * 2)
        self._threads: list[threading.Thread] = []
        self._pool_lock = threading.Lock()
        #: progress-deadline watchdog (armed only when transfer_deadline_s>0)
        self._watch: set[_WatchEntry] = set()
        self._watch_lock = threading.Lock()
        self._watch_thread: threading.Thread | None = None

    # -- worker pool ---------------------------------------------------------
    def _ensure_pool(self) -> None:
        with self._pool_lock:
            self._threads = [t for t in self._threads if t.is_alive()]
            while len(self._threads) < self.n_workers:
                t = threading.Thread(
                    target=self._worker,
                    name=f"sea-transfer-{len(self._threads)}",
                    daemon=True,
                )
                t.start()
                self._threads.append(t)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, args, kwargs, fut = item
            try:
                fut._finish(result=fn(*args, **kwargs))
            except BaseException as e:  # delivered through Future.result
                fut._finish(exc=e)

    def submit(self, fn, /, *args, **kwargs) -> _Future:
        """Run ``fn(*args, cancel=..., **kwargs)`` on the bounded pool.
        The queue is bounded at ``2 x workers``: a producer that outruns
        the device blocks here instead of buffering unbounded work
        (backpressure). ``fn`` receives the future's cancel event as a
        ``cancel`` keyword when it accepts one (``copy`` does)."""
        self._ensure_pool()
        fut = _Future()
        self._q.put((fn, args, kwargs, fut))
        return fut

    def try_submit(self, fn, /, *args, **kwargs) -> _Future | None:
        """Non-blocking :meth:`submit`: returns None when the bounded
        queue is full instead of blocking the caller. For producers that
        must never stall behind other producers sharing the pool (the
        readahead predictor's digestion thread drops the speculative job
        instead)."""
        self._ensure_pool()
        fut = _Future()
        try:
            self._q.put_nowait((fn, args, kwargs, fut))
        except queue.Full:
            return None
        return fut

    def submit_copy(self, src: str, dst: str, /, **kwargs) -> _Future:
        """``submit`` specialised to :meth:`copy`, wiring the future's
        cancel event into the chunk loop."""
        self._ensure_pool()
        fut = _Future()
        kwargs.setdefault("cancel", fut.cancel_event)
        self._q.put((self.copy, (src, dst), kwargs, fut))
        return fut

    def map(self, fn, items) -> list:
        """Run ``fn(item)`` for every item on the pool and collect results
        in order; exceptions propagate after all jobs settle."""
        futs = [self.submit(fn, item) for item in items]
        out, first_exc = [], None
        for f in futs:
            try:
                out.append(f.result())
            except BaseException as e:  # noqa: BLE001 — re-raised below
                if first_exc is None:
                    first_exc = e
                out.append(None)
        if first_exc is not None:
            raise first_exc
        return out

    def close(self) -> None:
        """Stop the worker pool (restarts lazily on the next submit)."""
        with self._pool_lock:
            threads, self._threads = self._threads, []
        for _ in threads:
            self._q.put(None)
        for t in threads:
            t.join(timeout=10)

    # -- progress-deadline watchdog ------------------------------------------
    def _deadline_guard(self, cancel, on_chunk):
        """Arm the watchdog for one copy when ``transfer_deadline_s`` is set.

        Returns ``(cancel, on_chunk, entry)``: the (possibly new) cancel
        event the watchdog will set on a stall, an ``on_chunk`` wrapper that
        stamps per-chunk progress, and the watch entry to unregister (None
        when deadlines are disabled).  The abort is cooperative — the chunk
        loop (and cancel-aware injected hangs) observe the event between
        chunks; a thread wedged *inside* a blocking syscall cannot be
        interrupted from Python and is documented as out of contract.
        Note the deadline measures *stall*, not total duration: a heavily
        token-bucket-throttled transfer must configure a deadline above its
        worst-case per-chunk wait.
        """
        if self.deadline_s <= 0:
            return cancel, on_chunk, None
        if cancel is None:
            cancel = threading.Event()
        entry = _WatchEntry(cancel, self.deadline_s)

        def stamped(copied, total, path, _e=entry, _inner=on_chunk):
            _e.progress = time.monotonic()
            if _inner is not None:
                _inner(copied, total, path)

        with self._watch_lock:
            self._watch.add(entry)
            t = self._watch_thread
            if t is None or not t.is_alive():
                t = threading.Thread(
                    target=self._watchdog, name="sea-transfer-watchdog", daemon=True
                )
                self._watch_thread = t
                t.start()
        return cancel, stamped, entry

    def _watch_unregister(self, entry: _WatchEntry) -> None:
        with self._watch_lock:
            self._watch.discard(entry)

    def _watchdog(self) -> None:
        while True:
            with self._watch_lock:
                if not self._watch:
                    # nothing left to watch: exit instead of ticking for
                    # the life of the process. Clearing _watch_thread
                    # under the lock lets _deadline_guard respawn the
                    # thread race-free on the next armed copy.
                    self._watch_thread = None
                    return
                entries = list(self._watch)
            now = time.monotonic()
            tick = 0.25
            for e in entries:
                if e.tripped:
                    continue
                stalled = now - e.progress
                if stalled >= e.deadline_s:
                    e.tripped = True
                    e.cancel.set()
                else:
                    tick = min(tick, max(0.005, (e.deadline_s - stalled) / 4))
            time.sleep(tick)

    def _deadline_abort(self, entry, src, dst, root, cause) -> "TransferDeadlineError":
        """Account a watchdog trip: telemetry + breaker, build the error."""
        self.telemetry.record_deadline_abort()
        if root is not None and self.health is not None:
            self.health.trip(root, "deadline")
        err = TransferDeadlineError(
            errno.ETIMEDOUT,
            f"transfer {src} -> {dst} made no progress for {entry.deadline_s}s",
        )
        err.__cause__ = cause
        return err

    # -- throttling ----------------------------------------------------------
    def _pair_cap(self, pair: str) -> float:
        src, _, dst = pair.partition("->")
        for k in (pair, f"{src}->*", f"*->{dst}", "*"):
            if k in self._caps_spec:
                return float(self._caps_spec[k])
        return 0.0

    def _bucket(self, pair: str) -> _TokenBucket | None:
        rate = self._pair_cap(pair)
        if rate <= 0:
            return None
        with self._bucket_lock:
            b = self._buckets.get(pair)
            if b is None:
                b = self._buckets[pair] = _TokenBucket(rate)
            return b

    # -- the transfer primitive ----------------------------------------------
    @staticmethod
    def _tier_name(tier) -> str:
        if tier is None:
            return "ext"
        return tier.name if isinstance(tier, Tier) else str(tier)

    def copy(
        self,
        src: str,
        dst: str,
        *,
        src_tier: Tier | str | None = None,
        dst_tier: Tier | str | None = None,
        dst_root: str | None = None,
        key: str | None = None,
        admit: str | None = None,
        reservation=None,
        preserve_stat: bool = True,
        cancel: threading.Event | None = None,
        on_chunk=None,
    ) -> TransferResult:
        """Move ``src`` to ``dst`` atomically, with accounting.

        ``admit`` selects the ledger admission run *before* any byte moves
        (only meaningful when ``dst_tier`` is a :class:`Tier` with
        ``dst_root``):

        * ``"require"`` — ``try_reserve`` the source's actual size; raises
          :class:`TransferAdmissionError` when the root has no room
          (prefetch/staging callers skip the stage).
        * ``"reserve"`` — unconditional budget hold (flush/persist to the
          base tier: there is nowhere slower to go).
        * ``None`` — no engine-side admission; pass ``reservation`` when
          the caller already holds one (it is committed with the actual
          size on success and released on failure either way).

        On success the reservation (engine- or caller-held) is committed
        via ``Tier.commit_write`` — which also folds the actual size into
        the capacity ledger — and the staging file has been renamed over
        ``dst``. On any failure the staging file is unlinked and the
        reservation released; ``dst`` is untouched.
        """
        t0 = time.perf_counter()
        pair = f"{self._tier_name(src_tier)}->{self._tier_name(dst_tier)}"
        accounted = isinstance(dst_tier, Tier) and dst_root is not None
        res = reservation
        if cancel is not None and cancel.is_set():
            # a stale speculative transfer must not even take admission
            # or touch the source — but a caller-held reservation still
            # must not leak
            if res is not None and isinstance(dst_tier, Tier):
                dst_tier.release_write(res)
            raise TransferCancelled(f"transfer {src} -> {dst} cancelled")
        try:
            # the source must be readable before any admission or staging
            # — and its error propagates untranslated (callers rely on
            # POSIX semantics, e.g. FileNotFoundError from a cross-mount
            # rename). A caller-held reservation must not leak even here.
            src_size = os.stat(src).st_size
        except OSError:
            if res is not None and isinstance(dst_tier, Tier):
                dst_tier.release_write(res)
            raise
        if res is None and accounted and admit is not None:
            res = self._admit(dst_tier, dst_root, src_size, mode=admit)

        # per-root health: only cache destinations are tracked (base has no
        # "elsewhere" to degrade to, so its breaker would only hurt)
        health_root = (
            dst_root
            if self.health is not None and accounted and not dst_tier.spec.persistent
            else None
        )
        cancel, on_chunk, watch = self._deadline_guard(cancel, on_chunk)
        t1 = time.monotonic()
        try:
            nbytes, attempts, impl = self._copy_with_retries(
                src, dst, pair, preserve_stat, cancel, on_chunk
            )
        except BaseException as e:
            if res is not None and isinstance(dst_tier, Tier):
                dst_tier.release_write(res)
            if watch is not None and watch.tripped:
                raise self._deadline_abort(watch, src, dst, health_root, e) from e
            if (
                health_root is not None
                and isinstance(e, OSError)
                and not isinstance(e, (TransferCancelled, TransferAdmissionError))
                and e.errno != errno.ENOENT  # src vanished, not a sick root
            ):
                self.health.record_failure(health_root, e)
            raise
        finally:
            if watch is not None:
                self._watch_unregister(watch)
        if health_root is not None:
            self.health.record_success(health_root, time.monotonic() - t1)
        if accounted:
            if key is None:
                key = os.path.relpath(dst, dst_root)
            dst_tier.commit_write(res, dst_root, key, nbytes)
        elif res is not None and isinstance(dst_tier, Tier):
            # caller-held reservation with no root to commit against:
            # return the budget rather than leak it
            dst_tier.release_write(res)
        seconds = time.perf_counter() - t0
        self.telemetry.record_transfer(
            pair, nbytes=nbytes, seconds=seconds, retries=attempts - 1
        )
        return TransferResult(nbytes, seconds, attempts, impl)

    def peer_pull(
        self,
        src: str,
        dst: str,
        *,
        dst_tier: Tier,
        dst_root: str,
        key: str,
        cancel: threading.Event | None = None,
    ) -> TransferResult:
        """Pull a peer node's cache replica into a local cache tier —
        :meth:`copy` specialised to the federation path.

        The source tier is the symbolic :data:`PEER_TIER` (the replica
        lives in *another node's* hierarchy, which this engine has no
        Tier object for), so throttling uses the ``"peer-><dst>"``
        bandwidth-cap pair — cluster pulls get their own budget.
        Admission is ``"require"``: a full cache root skips the pull
        rather than evicting for it (the base fallback still serves).
        All of :meth:`copy`'s failure guarantees apply — a peer that
        dies or evicts mid-pull leaves no partial file, no leaked
        reservation, and ``dst`` untouched; the caller falls back to
        the base tier and expunges the registry entry.

        A configured ``transfer_deadline_s`` applies here too: a peer whose
        export hangs mid-pull trips the watchdog, the pull cancels, and the
        caller's OSError handler falls back to base."""
        faults.fire("federation.pull", path=src)
        return self.copy(
            src,
            dst,
            src_tier=PEER_TIER,
            dst_tier=dst_tier,
            dst_root=dst_root,
            key=key,
            admit="require",
            preserve_stat=True,
            cancel=cancel,
        )

    def copy_range(
        self,
        src: str,
        dst: str,
        offset: int,
        length: int,
        *,
        src_tier: Tier | str | None = None,
        dst_tier: Tier | str | None = None,
        dst_root: str | None = None,
        cancel: threading.Event | None = None,
        on_chunk=None,
    ) -> TransferResult:
        """Stream ``length`` bytes of ``src`` starting at ``offset`` into
        the same range of ``dst`` — the extent-staging primitive.

        ``dst_root`` (the cache root holding the extent part file) feeds
        the same per-root health/breaker accounting as :meth:`copy`: a
        deadline abort or I/O failure on an extent stage trips/records
        against the destination root exactly like a whole-file copy.

        Unlike :meth:`copy` there is no staging tmp and no rename:
        ``dst`` is a preallocated *sparse* destination (an extent plane
        part file) and the bytes are written in place at ``offset``.
        Atomicity is the caller's validity journal — it is updated only
        after this method returns, so a crash at any chunk boundary
        leaves the extent unmarked, never torn-but-valid. Ledger
        admission likewise stays with the caller (per-extent
        reservations, committed against the part file's disk usage).

        The chunk loop shares everything else with :meth:`copy`:
        ``copy_file_range`` with explicit offsets (buffered pread/pwrite
        fallback), the per-tier-pair token-bucket throttle,
        retry-with-backoff (re-copying a range is idempotent),
        cooperative ``cancel`` between chunks, and the
        ``chunk_hook``/``on_chunk`` fault-injection points."""
        t0 = time.perf_counter()
        pair = f"{self._tier_name(src_tier)}->{self._tier_name(dst_tier)}"
        if cancel is not None and cancel.is_set():
            raise TransferCancelled(f"range transfer {src} -> {dst} cancelled")
        # per-root health: same contract as copy() — only cache
        # destinations are tracked
        health_root = (
            dst_root
            if self.health is not None
            and dst_root is not None
            and isinstance(dst_tier, Tier)
            and not dst_tier.spec.persistent
            else None
        )
        cancel, on_chunk, watch = self._deadline_guard(cancel, on_chunk)
        delay = self.backoff_s
        last_exc: BaseException | None = None
        t1 = time.monotonic()
        try:
            for attempt in range(1, self.retries + 2):
                try:
                    copied, impl = self._copy_range_once(
                        src, dst, offset, length, pair, cancel, on_chunk
                    )
                except TransferCancelled as e:
                    if watch is not None and watch.tripped:
                        raise self._deadline_abort(
                            watch, src, dst, health_root, e
                        ) from e
                    raise
                except Exception as e:
                    last_exc = e
                    # transient errors retry; permanent and capacity
                    # (ENOSPC) classes fail fast — see repro.core.faults
                    if classify(e) is not TRANSIENT or attempt > self.retries:
                        break
                    if cancel is not None and cancel.is_set():
                        if watch is not None and watch.tripped:
                            raise self._deadline_abort(
                                watch, src, dst, health_root, e
                            ) from e
                        raise TransferCancelled(
                            f"range transfer to {dst} cancelled"
                        ) from e
                    time.sleep(delay)
                    delay *= 2
                else:
                    seconds = time.perf_counter() - t0
                    if health_root is not None:
                        self.health.record_success(
                            health_root, time.monotonic() - t1
                        )
                    self.telemetry.record_transfer(
                        pair, nbytes=copied, seconds=seconds, retries=attempt - 1
                    )
                    return TransferResult(copied, seconds, attempt, impl)
        finally:
            if watch is not None:
                self._watch_unregister(watch)
        if (
            health_root is not None
            and isinstance(last_exc, OSError)
            and last_exc.errno != errno.ENOENT  # src vanished, not a sick root
        ):
            self.health.record_failure(health_root, last_exc)
        if isinstance(last_exc, OSError):
            raise last_exc
        raise TransferError(
            f"range transfer {src}[{offset}:{offset + length}] -> {dst} "
            f"failed after {self.retries + 1} attempts"
        ) from last_exc

    def _copy_range_once(
        self, src, dst, offset, length, pair, cancel, on_chunk
    ) -> tuple[int, str]:
        bucket = self._bucket(pair)
        copied = 0
        impl = "copy_file_range" if _HAS_COPY_FILE_RANGE else "preadwrite"
        with open(src, "rb") as fi, open(dst, "r+b") as fo:
            ifd, ofd = fi.fileno(), fo.fileno()
            while copied < length:
                if cancel is not None and cancel.is_set():
                    raise TransferCancelled(f"range transfer of {src} cancelled")
                want = min(self.chunk_bytes, length - copied)
                pos = offset + copied
                if impl == "copy_file_range":
                    try:
                        n = os.copy_file_range(
                            ifd, ofd, want, offset_src=pos, offset_dst=pos
                        )
                    except OSError as e:
                        if e.errno in _FALLBACK_ERRNOS:
                            impl = "preadwrite"
                            continue
                        raise
                else:
                    buf = os.pread(ifd, want, pos)
                    n = len(buf)
                    if n:
                        os.pwrite(ofd, buf, pos)
                if n == 0:
                    break  # source shorter than the recorded extent map
                copied += n
                if on_chunk is not None:
                    on_chunk(copied, length, dst)
                if self.chunk_hook is not None:
                    self.chunk_hook(copied, length, dst)
                faults.fire("transfer.range_chunk", path=dst, cancel=cancel)
                if bucket is not None:
                    self._throttle_wait(bucket.consume(n), ofd)
        if copied != length:
            # the source changed size under the extent map: the caller's
            # map is stale and must be rebuilt, not marked valid
            raise TransferError(
                f"range verify failed for {src}[{offset}:{offset + length}]: "
                f"copied {copied}"
            )
        return copied, impl

    def _admit(self, tier: Tier, root: str, nbytes: int, *, mode: str):
        if mode == "reserve" or tier.ledger is None:
            return tier.reserve_write(root, nbytes)
        if tier.spec.capacity is None:
            return tier.reserve_write(root, nbytes)
        required = (
            self.policy.required_bytes if self.policy is not None else nbytes
        )
        res = tier.ledger.try_reserve(
            root, nbytes, capacity=tier.spec.capacity, required=required
        )
        if res is None:
            raise TransferAdmissionError(
                f"no room for {nbytes} bytes on {tier.name}:{root}"
            )
        return res

    def _copy_with_retries(
        self, src, dst, pair, preserve_stat, cancel, on_chunk
    ) -> tuple[int, int, str]:
        delay = self.backoff_s
        last_exc: BaseException | None = None
        for attempt in range(1, self.retries + 2):
            tmp = f"{dst}.{_HOST}.{os.getpid()}.{next(_TMP_SEQ)}{_TMP_SUFFIX}"
            with self._active_lock:
                self._active_tmps.add(tmp)
            try:
                nbytes, impl = self._copy_once(
                    src, tmp, pair, cancel, on_chunk
                )
                if preserve_stat:
                    try:
                        shutil.copystat(src, tmp)
                    except OSError:
                        pass  # stat parity is best-effort (e.g. tmpfs xattrs)
                faults.fire("transfer.commit", path=dst)
                os.replace(tmp, dst)  # atomic commit
                return nbytes, attempt, impl
            except TransferCancelled:
                self._discard_tmp(tmp)
                raise
            except Exception as e:
                self._discard_tmp(tmp)
                last_exc = e
                # transient errors retry; permanent and capacity (ENOSPC)
                # classes fail fast — see repro.core.faults for the table
                if classify(e) is not TRANSIENT or attempt > self.retries:
                    break
                if cancel is not None and cancel.is_set():
                    raise TransferCancelled(f"transfer to {dst} cancelled") from e
                time.sleep(delay)
                delay *= 2
            finally:
                with self._active_lock:
                    self._active_tmps.discard(tmp)
        if isinstance(last_exc, OSError):
            # preserve the POSIX error class/errno the seed's bare copy
            # surfaced (callers match `except PermissionError`, check
            # e.errno, etc.); TransferError wraps only non-OS failures
            raise last_exc
        raise TransferError(
            f"transfer {src} -> {dst} failed after {self.retries + 1} attempts"
        ) from last_exc

    def _discard_tmp(self, tmp: str) -> None:
        try:
            os.unlink(tmp)
        except OSError:
            pass

    def _copy_once(self, src, tmp, pair, cancel, on_chunk) -> tuple[int, str]:
        """One staging attempt: chunk loop into ``tmp`` + size verify."""
        if not self.enabled:
            # seed behaviour for benchmarking: one whole-file copy (the
            # atomic rename + accounting above still apply)
            shutil.copyfile(src, tmp)
            return os.path.getsize(tmp), "shutil"
        bucket = self._bucket(pair)
        copied = 0
        impl = (
            "copy_file_range"
            if _HAS_COPY_FILE_RANGE
            else ("sendfile" if _HAS_SENDFILE else "readwrite")
        )
        with open(src, "rb") as fi, open(tmp, "wb") as fo:
            ifd, ofd = fi.fileno(), fo.fileno()
            total = os.fstat(ifd).st_size
            while True:
                if cancel is not None and cancel.is_set():
                    raise TransferCancelled(f"transfer of {src} cancelled")
                if impl == "copy_file_range":
                    try:
                        n = os.copy_file_range(ifd, ofd, self.chunk_bytes)
                    except OSError as e:
                        if e.errno in _FALLBACK_ERRNOS:
                            impl = "sendfile" if _HAS_SENDFILE else "readwrite"
                            continue
                        raise
                elif impl == "sendfile":
                    try:
                        n = os.sendfile(ofd, ifd, None, self.chunk_bytes)
                    except OSError as e:
                        if e.errno in _FALLBACK_ERRNOS:
                            impl = "readwrite"
                            continue
                        raise
                else:
                    buf = fi.read(self.chunk_bytes)
                    n = len(buf)
                    if n:
                        fo.write(buf)
                if n == 0:
                    break
                copied += n
                if on_chunk is not None:
                    on_chunk(copied, total, tmp)
                if self.chunk_hook is not None:
                    self.chunk_hook(copied, total, tmp)
                faults.fire("transfer.chunk", path=tmp, cancel=cancel)
                if bucket is not None:
                    self._throttle_wait(bucket.consume(n), ofd)
        # size-verified completion: the committed file must hold exactly
        # what the source holds NOW (a mid-copy rewrite forces a retry)
        final = os.path.getsize(src)
        if copied != final:
            raise TransferError(
                f"size verify failed for {src}: copied {copied}, source {final}"
            )
        return copied, impl

    @staticmethod
    def _throttle_wait(wait: float, fd: int) -> None:
        """Sleep out a token-bucket debt in bounded slices, refreshing
        the staging file's mtime between slices — a heavily throttled
        transfer (one chunk's debt can exceed the orphan grace) must
        never look age-dead to another node's reaper."""
        slice_s = ORPHAN_GRACE_S / 4
        while wait > 0:
            time.sleep(min(wait, slice_s))
            wait -= slice_s
            if wait > 0:
                try:
                    os.utime(fd)
                except OSError:
                    pass

    # -- orphan staging files --------------------------------------------------
    def maybe_reap_orphan(self, path: str) -> bool:
        """Delete a ``*.sea_tmp`` staging file iff it is provably dead:
        created on THIS host by a pid that no longer exists, or untouched
        for :data:`ORPHAN_GRACE_S` (an in-flight transfer keeps its tmp's
        mtime fresh with every chunk, so age is safe even for files owned
        by another node of a shared tier). Anything else is left alone —
        the LRU and scan walks must never delete a half-written staging
        file out from under a racing ``os.replace``."""
        if not path.endswith(_TMP_SUFFIX):
            return False
        with self._active_lock:
            if path in self._active_tmps:
                return False
        owner = tmp_owner(path)
        local_dead = (
            owner is not None
            and owner[0] == _HOST
            and not _pid_alive(owner[1])
        )
        if not local_dead:
            # foreign host, unparseable name, or a live local pid (which
            # may be a RECYCLED pid squatting on a crashed writer's name):
            # fall back to the age grace. Safe for genuinely in-flight
            # transfers — every chunk write and every throttle slice
            # (_throttle_wait) keeps the tmp's mtime fresh.
            try:
                age = time.time() - os.path.getmtime(path)
            except OSError:
                return False
            if age < ORPHAN_GRACE_S:
                return False
        try:
            os.unlink(path)
        except OSError:
            return False
        self.telemetry.record_orphan_reaped()
        return True

    def sweep_orphans(self, root: str) -> int:
        """Walk one root and reap every provably-dead staging file."""
        n = 0
        for dirpath, _dirs, files in os.walk(root):
            for fn in files:
                if fn.endswith(_TMP_SUFFIX) and self.maybe_reap_orphan(
                    os.path.join(dirpath, fn)
                ):
                    n += 1
        return n
