"""Input pipeline on top of Sea: sharded token datasets with tiered
prefetch, consumed-shard eviction, and straggler-tolerant work stealing.

Dataset layout (all paths under the Sea mountpoint, physically on the
persistent tier until prefetched):

    dataset/<name>/meta.json
    dataset/<name>/shard_00000.npy     int32 [tokens_per_shard]

The pipeline stages upcoming shards into the fast tier (Sea prefetch),
yields fixed-shape [B, S] batches double-buffered on the host, and drops
cache copies once consumed (the in-memory-computing pattern: inputs are
re-readable from the persistent tier, so cache space is better spent on
the shards ahead).

Work stealing: shards live in a global deque; each worker claims the next
shard when idle. A straggler's unprocessed claims return to the queue
when the StragglerDetector flags it (launcher side), so slow nodes cost
their own throughput only.
"""

from __future__ import annotations

import json
import os
import queue
import threading
from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.core import Sea


# ------------------------------------------------------------------ build
def write_dataset(
    sea: Sea,
    name: str,
    *,
    n_shards: int,
    tokens_per_shard: int,
    vocab_size: int,
    seed: int = 0,
) -> str:
    """Synthetic corpus: Zipfian tokens with local correlations (enough
    structure for a CE-loss to visibly decrease)."""
    rng = np.random.default_rng(seed)
    root = os.path.join(sea.fs.mount, "dataset", name)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    for i in range(n_shards):
        toks = rng.choice(vocab_size, size=tokens_per_shard, p=probs).astype(
            np.int32
        )
        # inject learnable bigram structure: every odd position repeats the
        # previous token with p=0.5
        repeat = rng.random(tokens_per_shard) < 0.5
        toks[1::2] = np.where(repeat[1::2], toks[0::2], toks[1::2])
        shard_path = os.path.join(root, f"shard_{i:05d}.npy")
        with sea.fs.open(shard_path, "wb") as f:
            np.save(f, toks, allow_pickle=False)
        sea.fs.persist(shard_path)   # inputs must survive cache eviction
    with sea.fs.open(os.path.join(root, "meta.json"), "w") as f:
        json.dump(
            {
                "n_shards": n_shards,
                "tokens_per_shard": tokens_per_shard,
                "vocab_size": vocab_size,
            },
            f,
        )
    sea.fs.persist(os.path.join(root, "meta.json"))
    return root


# ------------------------------------------------------------------ pipeline
@dataclass
class PipelineStats:
    shards_consumed: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    evictions: int = 0


class DataPipeline:
    """Iterator of {tokens, labels} numpy batches with Sea-tiered staging."""

    def __init__(
        self,
        sea: Sea,
        name: str,
        *,
        batch_size: int,
        seq_len: int,
        prefetch_shards: int = 2,
        evict_consumed: bool = True,
        start_shard: int = 0,
        worker_id: int = 0,
        n_workers: int = 1,
    ):
        self.sea = sea
        self.fs = sea.fs
        self.root = os.path.join(sea.fs.mount, "dataset", name)
        with self.fs.open(os.path.join(self.root, "meta.json")) as f:
            self.meta = json.load(f)
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.evict_consumed = evict_consumed
        self.stats = PipelineStats()
        # work-stealing queue of shard indices (strided start for locality)
        ids = list(range(start_shard, self.meta["n_shards"]))
        self._queue: "queue.Queue[int]" = queue.Queue()
        for sid in ids[worker_id::n_workers]:
            self._queue.put(sid)
        self._staged: "queue.Queue[tuple[int, np.ndarray]]" = queue.Queue(
            maxsize=prefetch_shards
        )
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._stage_loop, name="sea-data-prefetch", daemon=True
        )
        self._thread.start()

    # -- staging thread: PFS -> cache tier -> host memory --------------------
    def _shard_path(self, sid: int) -> str:
        return os.path.join(self.root, f"shard_{sid:05d}.npy")

    def _stage_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sid = self._queue.get_nowait()
            except queue.Empty:
                self._staged.put((-1, None))
                return
            try:
                self._stage_one(sid)
            except Exception as e:  # surface failures to the consumer
                self._staged.put((-2, e))
                return

    def _stage_one(self, sid: int) -> None:
        path = self._shard_path(sid)
        key = self.fs.key_of(path)
        where = self.fs.where(path)
        if where is not None and where != self.fs.hierarchy.base.name:
            self.stats.cache_hits += 1
        elif not getattr(self.fs.config, "readahead", False):
            self.stats.cache_misses += 1
            # stage through the shared engine-backed primitive (same code
            # path as Flusher.prefetch): key-locked against racing
            # _evict/flusher moves, ledger admission before bytes move,
            # staging tmp cleaned up on failure. Best-effort — on any
            # transfer error the shard is read from its persistent copy.
            self.fs.stage_to_cache(key)
        else:
            # with predictive readahead enabled the bespoke staging is
            # redundant: the predictor observes the sequential shard
            # opens below and stages upcoming shards through the same
            # engine — with adaptive depth, cancellation, and waste
            # accounting this loop never had
            self.stats.cache_misses += 1
        with self.fs.open(path, "rb") as f:
            arr = np.load(f, allow_pickle=False)
        self._staged.put((sid, arr))

    def _evict(self, sid: int) -> None:
        """Drop the cache copy of a consumed shard (persistent copy stays)."""
        key = self.fs.key_of(self._shard_path(sid))
        with self.fs.key_lock(key):
            if self.fs.hierarchy.base.locate(key) is None:
                return  # never orphan the only copy
            evicted = False
            for tier in self.fs.hierarchy.cache_tiers:
                real = tier.locate(key)
                if real is not None:
                    try:
                        os.remove(real)
                        root = tier.root_of(real)
                        if root is not None:
                            tier.note_removed(root, key)
                        self.stats.evictions += 1
                        self.fs.telemetry.record_evict(0)
                        evicted = True
                    except OSError:
                        pass
            if evicted:
                self.fs.resolver.invalidate(key)

    # -- iteration --------------------------------------------------------------
    def __iter__(self):
        """Fixed-shape batches assembled from a list of staged chunks
        with an offset cursor — O(batch) per batch. (The previous
        implementation re-concatenated the whole remaining buffer on
        every shard arrival: O(total²) bytes copied over an epoch.)"""
        need = self.batch_size * (self.seq_len + 1)
        chunks: deque = deque()  # staged shard arrays, consumed in order
        offset = 0  # consumed prefix of chunks[0]
        have = 0  # unconsumed tokens across all chunks
        while True:
            while have < need:
                if self._stop.is_set():
                    # closed: the staging thread is (being) joined and
                    # may never post another item — a blocking get here
                    # would hang forever
                    return
                sid, arr = self._staged.get()
                if sid == -2:
                    raise RuntimeError("data staging failed") from arr
                if arr is None:
                    return  # staging exhausted; tail < one batch is dropped
                if arr.size:
                    chunks.append(arr)
                    have += arr.size
                self.stats.shards_consumed += 1
                if self.evict_consumed:
                    self._evict(sid)
            parts = []
            got = 0
            while got < need:
                head = chunks[0]
                take = min(head.size - offset, need - got)
                parts.append(head[offset : offset + take])
                got += take
                offset += take
                if offset == head.size:
                    chunks.popleft()
                    offset = 0
            have -= need
            chunk = np.concatenate(parts) if len(parts) > 1 else parts[0]
            chunk = chunk.reshape(self.batch_size, self.seq_len + 1)
            yield {
                "tokens": chunk[:, :-1].copy(),
                "labels": chunk[:, 1:].copy(),
            }

    # -- device feed ------------------------------------------------------------
    def device_iter(self, *, depth: int | None = None, put_fn=None):
        """Batches already on device: a feeder thread runs ``put_fn``
        (default ``jax.device_put`` per array) on batch N+1..N+depth
        while the consumer computes on batch N, so the host->device copy
        — the last hop of the base->cache->host->device pipeline — is
        double-buffered behind compute exactly like the staging thread
        double-buffers the base->cache->host hops. ``depth`` defaults to
        the ``device_prefetch`` config knob. A consumer that finds the
        buffer empty records a ``device_feed_stalls`` telemetry tick."""
        if depth is None:
            depth = max(1, getattr(self.fs.config, "device_prefetch", 2))
        if put_fn is None:
            import jax

            def put_fn(batch):
                return {k: jax.device_put(v) for k, v in batch.items()}

        fed: "queue.Queue" = queue.Queue(maxsize=depth)
        done = threading.Event()

        def _feed() -> None:
            try:
                for batch in self:
                    item = (0, put_fn(batch))
                    while True:
                        if done.is_set():
                            return  # consumer gone: nobody reads a sentinel
                        if self._stop.is_set():
                            self._put_sentinel(fed, (-1, None), done)
                            return
                        try:
                            fed.put(item, timeout=0.05)
                            break
                        except queue.Full:
                            continue
                self._put_sentinel(fed, (-1, None), done)
            except BaseException as e:
                self._put_sentinel(fed, (-2, e), done)

        t = threading.Thread(
            target=_feed, name="sea-device-feed", daemon=True
        )
        t.start()
        try:
            while True:
                try:
                    tag, item = fed.get_nowait()
                except queue.Empty:
                    if done.is_set():
                        return
                    self.fs.telemetry.record_device_feed_stall()
                    tag, item = fed.get()
                if tag == -2:
                    raise RuntimeError("device feed failed") from item
                if tag == -1:
                    return
                yield item
        finally:
            # stop + JOIN the feeder (it may be blocked in put): mirror
            # of close() for the device stage
            done.set()
            while t.is_alive():
                try:
                    while True:
                        fed.get_nowait()
                except queue.Empty:
                    pass
                t.join(timeout=0.05)

    @staticmethod
    def _put_sentinel(q: "queue.Queue", item, done: threading.Event) -> None:
        while not done.is_set():
            try:
                q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    # -- lifecycle --------------------------------------------------------------
    def __enter__(self) -> "DataPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Stop and JOIN the staging thread (it may be blocked putting
        into the bounded staged queue: drain until it exits, so no
        daemon thread keeps reading shards after close returns)."""
        self._stop.set()
        while self._thread.is_alive():
            try:
                while True:
                    self._staged.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)
        # a consumer that raced the drain may already sit in a blocking
        # get(): hand it the end-of-data sentinel its __iter__ expects
        try:
            self._staged.put_nowait((-1, None))
        except queue.Full:
            pass
