"""Gradient compression with error feedback.

At 1000+-node scale, cross-pod (DCI) all-reduce bandwidth is the scarce
resource; compressing gradients before the reduce trades a little
precision for a 2x (bf16) or 4x (int8) cut in collective bytes. The
int8 path uses per-tensor symmetric scaling with an error-feedback
residual carried in the train state so quantization noise does not bias
long runs (Karimireddy et al., error feedback fixes SignSGD).

Under pjit, compressing the *gradient values* before they enter the
all-reduce is expressed by quantize -> dequantize around the point where
XLA inserts the reduction; XLA reduces the low-precision representation
when the pattern is recognized, and the roofline's collective term drops
accordingly (measured in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_decompress(grads, kind: str):
    """Round-trip compression applied to the gradient pytree."""
    if kind == "none":
        return grads
    if kind == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads
        )
    if kind == "int8":
        def q(g):
            gf = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            qg = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            return (qg.astype(jnp.float32) * scale).astype(g.dtype)

        return jax.tree.map(q, grads)
    raise ValueError(kind)


def compress_with_error_feedback(grads, residual, kind: str):
    """(grads, residual) -> (compressed grads, new residual)."""
    if kind == "none":
        return grads, residual

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if kind == "bf16":
            cg = gf.astype(jnp.bfloat16).astype(jnp.float32)
        elif kind == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            cg = (
                jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.float32)
                * scale
            )
        else:
            raise ValueError(kind)
        return cg.astype(g.dtype), gf - cg

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
    )


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
