"""Fault tolerance: heartbeats, straggler detection, restart policy.

On a real multi-pod fleet the JAX runtime surfaces worker failure as a
distributed-initialization error and the launcher restarts the job from
the last checkpoint (ephemeral workers, exactly the batch-scheduler
assumption of the paper's HPC setting — Sea's burst-buffer checkpoints
make restart cheap). This module implements the *launcher-side* machinery
so it can be exercised on one host:

    HeartbeatMonitor    per-worker liveness file (mtime-based), through
                        SeaFS so heartbeats live on the fast tier
    StragglerDetector   per-step duration tracking; flags workers slower
                        than median * threshold; the data pipeline then
                        re-assigns their pending shards (work stealing)
    RestartPolicy       bounded exponential backoff with a restart budget

Integration test: tests/test_fault_tolerance.py kills a simulated worker
mid-run and asserts training resumes from the latest checkpoint with
identical loss trajectory modulo the lost steps.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field


class HeartbeatMonitor:
    def __init__(self, root: str, worker_id: int, timeout_s: float = 60.0,
                 fs=None):
        self.root = root
        self.worker_id = worker_id
        self.timeout_s = timeout_s
        self.fs = fs
        self._open = fs.open if fs is not None else open
        self._exists = (
            fs.exists if fs is not None else os.path.exists
        )
        self._stat = fs.stat if fs is not None else os.stat
        if fs is None:
            os.makedirs(root, exist_ok=True)

    def _path(self, wid: int) -> str:
        return os.path.join(self.root, f"heartbeat_{wid}")

    def beat(self, step: int) -> None:
        with self._open(self._path(self.worker_id), "w") as f:
            f.write(f"{step} {time.time()}\n")

    def live_workers(self, expected: list[int]) -> dict[int, bool]:
        now = time.time()
        out = {}
        for wid in expected:
            p = self._path(wid)
            try:
                st = self._stat(p)
                out[wid] = (now - st.st_mtime) < self.timeout_s
            except (FileNotFoundError, OSError):
                out[wid] = False
        return out

    def dead_workers(self, expected: list[int]) -> list[int]:
        return [w for w, ok in self.live_workers(expected).items() if not ok]


@dataclass
class StragglerDetector:
    """Flags workers whose recent step times exceed median * threshold."""

    threshold: float = 1.8
    window: int = 16
    _times: dict[int, list[float]] = field(default_factory=dict)

    def record(self, worker_id: int, step_seconds: float) -> None:
        h = self._times.setdefault(worker_id, [])
        h.append(step_seconds)
        if len(h) > self.window:
            del h[0]

    def medians(self) -> dict[int, float]:
        out = {}
        for wid, h in self._times.items():
            s = sorted(h)
            out[wid] = s[len(s) // 2]
        return out

    def stragglers(self) -> list[int]:
        med = self.medians()
        if len(med) < 2:
            return []
        global_med = sorted(med.values())[len(med) // 2]
        return [
            w for w, m in med.items() if m > self.threshold * global_med
        ]


@dataclass
class RestartPolicy:
    max_restarts: int = 8
    backoff_base_s: float = 1.0
    backoff_cap_s: float = 300.0
    restarts: int = 0

    def next_delay(self) -> float | None:
        """None = restart budget exhausted, fail the job."""
        if self.restarts >= self.max_restarts:
            return None
        d = min(self.backoff_base_s * (2 ** self.restarts), self.backoff_cap_s)
        self.restarts += 1
        return d

    def reset(self) -> None:
        """Call after a healthy stretch (e.g. N successful checkpoints)."""
        self.restarts = 0
