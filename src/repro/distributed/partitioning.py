"""Partitioning: logical spec trees -> physical NamedShardings.

Parameters carry logical PartitionSpec tuples from their init functions
(FSDP over data, TP over model, EP over experts). This module resolves
them against a mesh + rule binding, and provides the activation/cache/
batch shardings for every shape-cell kind.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.sharding import (
    DP,
    EP,
    SP,
    TP,
    default_rules,
    resolve_pspec,
)


def is_spec_leaf(v) -> bool:
    return isinstance(v, tuple) and all(
        a is None or isinstance(a, (str, tuple)) for a in v
    )


def tree_to_shardings(mesh: Mesh, rules: dict, spec_tree) -> Any:
    """Logical spec tree -> NamedSharding tree (same structure)."""
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, resolve_pspec(sp, rules)),
        spec_tree,
        is_leaf=is_spec_leaf,
    )


# ------------------------------------------------------------------ batches
def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Logical specs for one training/prefill batch dict."""
    dp = DP if cell.global_batch > 1 else None
    specs: dict[str, tuple] = {"tokens": (dp, None), "labels": (dp, None)}
    if cfg.frontend_tokens:
        specs["embeds"] = (dp, None, None)
    if cfg.encdec is not None:
        specs["frames"] = (dp, None, None)
    return specs


def decode_arg_specs(cfg: ModelConfig, cell: ShapeCell) -> tuple:
    """(token, caches, lengths) logical specs for a decode cell.

    Batched decode shards the cache over batch (DP); the long-context
    cell (batch=1) shards the KV-cache *sequence* dim over the data axis
    instead (sequence parallelism) — recurrent states shard over TP only.
    """
    long_ctx = cell.global_batch == 1
    dp = None if long_ctx else DP
    seq_ax = SP if long_ctx else None

    def entry_specs(entry: str) -> dict:
        mixer, _ffn = entry.split(":")
        c: dict[str, tuple] = {}
        if mixer in ("attn", "local", "attnx"):
            # [B, S, Hk, Dh] (ring buffers for local are small: replicate S)
            s_ax = seq_ax if mixer != "local" else None
            c["k"] = (dp, s_ax, None, None)
            c["v"] = (dp, s_ax, None, None)
            if mixer == "attnx":
                c["xk"] = (dp, None, None, None)
                c["xv"] = (dp, None, None, None)
        elif mixer == "mamba":
            c["conv"] = (dp, None, TP)
            c["h"] = (dp, TP, None)
        elif mixer == "rwkv":
            c["x_tm"] = (dp, None)
            c["S"] = (dp, TP, None, None)
        if entry.endswith(":rwkv"):
            c["x_cm"] = (dp, None)
        return c

    caches: dict[str, Any] = {}
    if cfg.n_periods > 0:
        caches["stack"] = {
            f"pat{pos}": jax.tree.map(
                lambda sp: (None, *sp), entry_specs(e), is_leaf=is_spec_leaf
            )
            for pos, e in enumerate(cfg.pattern)
        }
    for i, e in enumerate(cfg.remainder):
        caches[f"rem{i}"] = entry_specs(e)
    token = (dp, None)
    lengths = (dp,)
    return token, caches, lengths


def prefill_out_specs(cfg: ModelConfig, cell: ShapeCell):
    """(logits, caches, lengths) output specs for prefill cells."""
    token, caches, lengths = decode_arg_specs(cfg, cell)
    logits = (DP if cell.global_batch > 1 else None, None)
    return logits, caches, lengths


__all__ = [
    "tree_to_shardings",
    "batch_specs",
    "decode_arg_specs",
    "prefill_out_specs",
    "default_rules",
    "is_spec_leaf",
]
