"""GPipe-style pipeline parallelism over a mesh axis (shard_map +
collective_permute).

Multi-pod topology makes the 'pod' axis the natural pipeline dimension:
inter-pod (DCI) bandwidth is far below in-pod ICI, and pipelining moves
only layer activations across pods once per microbatch instead of
all-reducing every gradient. Stages hold contiguous period-groups of the
layer stack; the schedule is the classic GPipe fill-drain loop expressed
as a ``lax.scan`` over (microbatches + stages - 1) ticks with a
``collective_permute`` shifting activations to the next stage each tick.

This module is self-contained and validated on a host-device mesh in
``tests/test_pipeline.py``; production launchers opt in with
``--pipeline pod``. (The dry-run default keeps pod as a pure DP axis —
see DESIGN.md §5.)
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable,        # (stage_params, x [Bm, ...]) -> y
    params_stacked,            # pytree stacked over stages on axis 0
    x_microbatches: jax.Array, # [n_micro, Bm, ...] (already on stage 0)
    mesh: Mesh,
    axis: str = "pod",
):
    """Run the pipeline forward. Returns final-stage outputs
    [n_micro, Bm, ...]. Correctness oracle: applying the stages
    sequentially on one device."""
    n_stages = mesh.shape[axis]
    n_micro = x_microbatches.shape[0]
    ticks = n_micro + n_stages - 1

    def per_stage(params, xs):
        # P(axis) leaves a local leading stage dim of 1: drop it
        params = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index(axis)

        def tick(carry, t):
            outputs, inflight = carry
            # which microbatch enters stage 0 at tick t
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            stage0_in = jax.lax.dynamic_index_in_dim(
                xs, mb_idx, axis=0, keepdims=False
            )
            x_in = jnp.where(stage_id == 0, stage0_in, inflight)
            active = (t - stage_id >= 0) & (t - stage_id < n_micro)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # shift to the next stage (ring; last stage's output falls off)
            shifted = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            # last stage writes its finished microbatch
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_done = (stage_id == n_stages - 1) & (t >= n_stages - 1)
            outputs = jax.lax.cond(
                is_done,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, done_idx, axis=0
                ),
                lambda o: o,
                outputs,
            )
            return (outputs, shifted), None

        outputs0 = jnp.zeros_like(xs)
        inflight0 = jnp.zeros_like(xs[0])
        (outputs, _), _ = jax.lax.scan(
            tick, (outputs0, inflight0), jnp.arange(ticks)
        )
        # broadcast final outputs from the last stage to all stages
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis,
        )
        return outputs

    in_specs = (P(axis), P())        # params sharded by stage; x replicated
    out_specs = P()
    fn = _shard_map(per_stage, mesh, in_specs, out_specs)
    return fn(params_stacked, x_microbatches)


def _shard_map(f, mesh, in_specs, out_specs):
    # jax >= 0.5 exposes jax.shard_map (replication check kwarg: check_vma);
    # older releases only have jax.experimental.shard_map (check_rep).
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def split_microbatches(x: jax.Array, n_micro: int) -> jax.Array:
    B = x.shape[0]
    assert B % n_micro == 0
    return x.reshape(n_micro, B // n_micro, *x.shape[1:])
