"""Logical-axis sharding context.

Model code annotates activations with *logical* axes ("dp", "tp", "sp");
the launcher binds them to physical mesh axes (("pod","data"), "model",
"data") once, so the same model code runs on the single-pod (data, model)
mesh, the multi-pod (pod, data, model) mesh, or a single CPU device
(no-op). Parameters carry logical PartitionSpecs built with ``lspec``;
``resolve_pspec`` translates them to physical PartitionSpecs.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

#: logical axis names used throughout the model code
DP = "dp"        # data parallel (batch) — maps to ("pod","data") or ("data",)
TP = "tp"        # tensor parallel — maps to "model"
FSDP = "fsdp"    # parameter sharding — maps to "data" (and "pod" if desired)
SP = "sp"        # sequence parallel (long-context) — maps to "data"
EP = "ep"        # expert parallel — maps to "model"

_state = threading.local()


def _ctx():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextlib.contextmanager
def logical_axis_rules(mesh: jax.sharding.Mesh | None, rules: dict[str, tuple[str, ...] | str | None]):
    """Bind logical axes to physical mesh axes for the duration of a trace.

    rules maps logical name -> physical axis (str), tuple of axes, or None
    (replicate). Unknown logical names replicate.
    """
    _ctx().append((mesh, dict(rules)))
    try:
        yield
    finally:
        _ctx().pop()


def current_rules():
    stack = _ctx()
    return stack[-1] if stack else (None, {})


def default_rules(multi_pod: bool) -> dict:
    if multi_pod:
        return {DP: ("pod", "data"), TP: "model", FSDP: "data", SP: "data", EP: "model"}
    return {DP: ("data",), TP: "model", FSDP: "data", SP: "data", EP: "model"}


def resolve_pspec(logical: tuple, rules: dict) -> P:
    """Translate a tuple of logical axis names (or None / tuples) into a
    physical PartitionSpec under the given rules."""
    phys = []
    for ax in logical:
        if ax is None:
            phys.append(None)
        elif isinstance(ax, (tuple, list)):
            merged: list[str] = []
            for a in ax:
                m = rules.get(a)
                if m is None:
                    continue
                merged.extend(m if isinstance(m, (tuple, list)) else (m,))
            phys.append(tuple(merged) if merged else None)
        else:
            m = rules.get(ax)
            if m is None:
                phys.append(None)
            elif isinstance(m, (tuple, list)):
                phys.append(tuple(m))
            else:
                phys.append(m)
    # PartitionSpec forbids duplicate mesh axes; drop later repeats
    seen: set[str] = set()
    out = []
    for entry in phys:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):
            keep = tuple(a for a in entry if a not in seen)
            seen.update(keep)
            out.append(keep if keep else None)
        else:
            if entry in seen:
                out.append(None)
            else:
                seen.add(entry)
                out.append(entry)
    return P(*out)


def shard_hint(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint against the current logical-axis binding;
    identity when no mesh is bound (CPU tests)."""
    mesh, rules = current_rules()
    if mesh is None:
        return x
    spec = resolve_pspec(tuple(logical_axes), rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: jax.sharding.Mesh, rules: dict, logical: tuple) -> NamedSharding:
    return NamedSharding(mesh, resolve_pspec(logical, rules))
