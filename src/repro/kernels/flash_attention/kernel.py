"""Flash attention (GQA, causal, sliding-window) as a Pallas TPU kernel.

Grid: (batch, q_heads, q_blocks, kv_blocks) — the kv dimension is
sequential ("arbitrary"); the online-softmax statistics (m, l) and the
output accumulator live in VMEM scratch and persist across kv steps.
BlockSpecs tile Q/K/V so one program touches

    q:   [block_q,  head_dim]     (VMEM)
    k,v: [block_k,  head_dim]     (VMEM)

with the GQA head mapping folded into the K/V index_map (q head h reads
kv head h // group_size). Scores and softmax statistics are fp32; the
P·V product feeds the MXU in the input dtype with fp32 accumulation.

VMEM budget at the default 512x512 tiles, head_dim 128, bf16:
q/k/v 128 KiB each + acc 256 KiB + p 1 MiB  « 16 MiB/core.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,        # inputs
    o_ref,                      # output
    m_ref, l_ref, acc_ref,      # scratch
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # [bq, dh]
    k = k_ref[0, 0]                                   # [bk, dh]
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * sm_scale                                      # [bq, bk]

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos < seq_k) & (qpos < seq_q)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                            # [bq, bk] fp32
    corr = jnp.exp(m_prev - m_new)                    # [bq, 1]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,          # [B, H, Sq, Dh]
    k: jax.Array,          # [B, Hk, Sk, Dh]
    v: jax.Array,          # [B, Hk, Sk, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, Dh = q.shape
    _, Hk, Sk, _ = k.shape
    G = H // Hk
    sm_scale = 1.0 / math.sqrt(Dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        seq_q=Sq,
        seq_k=Sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, Dh), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, Dh), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, Dh), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, Dh), q.dtype),
        scratch_shapes=[
            vmem_scratch((block_q, 1)),
            vmem_scratch((block_q, 1)),
            vmem_scratch((block_q, Dh)),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]


def vmem_scratch(shape, dtype=jnp.float32):
    """VMEM scratch allocation (also honoured by interpret mode)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
