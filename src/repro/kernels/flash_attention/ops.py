"""jit'd public wrapper for the flash-attention kernel.

Model code keeps [B, S, H, Dh] layout; the kernel wants [B, H, S, Dh].
``interpret`` defaults to True off-TPU so the same call sites validate on
CPU and run the Mosaic kernel on TPU.
"""

from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,          # [B, Sq, H, Dh]  (model layout)
    k: jax.Array,          # [B, Sk, Hk, Dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    out = flash_attention_kernel(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
