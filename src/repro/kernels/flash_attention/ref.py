"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,          # [B, H, Sq, Dh]
    k: jax.Array,          # [B, Hk, Sk, Dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    B, H, Sq, Dh = q.shape
    _, Hk, Sk, _ = k.shape
    G = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Hk, G, Sq, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / math.sqrt(Dh)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, H, Sq, Dh).astype(q.dtype)
