"""Fused RMSNorm(+scale) Pallas kernel: one HBM round-trip per row block,
fp32 statistics, output in the input dtype."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # [blk, D]
    scale = s_ref[...].astype(jnp.float32)        # [1, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale).astype(o_ref.dtype)


def rmsnorm_kernel(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
                   block_rows: int = 256, interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    D = x.shape[-1]
    xr = x.reshape(-1, D)
    R = xr.shape[0]
    blk = min(block_rows, R)
    pad = (-R) % blk
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // blk,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale.reshape(1, D))
    return out[:R].reshape(orig_shape)
