"""jit'd wrapper for the fused RMSNorm kernel."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import rmsnorm_kernel


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rmsnorm_kernel(
        x, scale, eps=eps, block_rows=block_rows, interpret=interpret
    )
