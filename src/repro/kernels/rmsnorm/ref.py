"""Oracle: the model's own rms_norm."""

from repro.models.layers import rms_norm as _rms_norm


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    return _rms_norm(x, scale, eps)
