"""Mamba selective-scan as a Pallas TPU kernel.

Grid: (batch, channel_blocks, time_chunks) — time is sequential with the
SSM state h ∈ R^{dblk×N} carried in VMEM scratch; batch and channel
blocks are parallel. Within a chunk the recurrence

    h_t = e^{Δ_t A} h_{t-1} + (Δ_t x_t) B_t ;   y_t = h_t · C_t + D x_t

runs as a ``fori_loop`` over L steps of [dblk, N] vector work (VPU); the
O(T) dependency chain costs only T/L sequential *grid* steps of HBM
traffic. The [L, dblk, N] decay tensor stays in VMEM (4 MiB at the
default L=64, dblk=256, N=16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref,
                *, L: int, dblk: int, N: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dt = dt_ref[0].astype(jnp.float32)            # [L, dblk]
    x = x_ref[0].astype(jnp.float32)              # [L, dblk]
    Bm = b_ref[0].astype(jnp.float32)             # [L, N]
    Cm = c_ref[0].astype(jnp.float32)             # [L, N]
    A = a_ref[...].astype(jnp.float32)            # [dblk, N]
    D = d_ref[...].astype(jnp.float32)            # [1, dblk]

    da = jnp.exp(dt[:, :, None] * A[None])        # [L, dblk, N]
    dbx = (dt * x)[:, :, None] * Bm[:, None, :]   # [L, dblk, N]

    def step(t, carry):
        h, y = carry
        h = da[t] * h + dbx[t]                    # [dblk, N]
        yt = jnp.sum(h * Cm[t][None, :], axis=-1)  # [dblk]
        y = jax.lax.dynamic_update_index_in_dim(y, yt, t, axis=0)
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros((L, dblk), jnp.float32)
    h_fin, y = jax.lax.fori_loop(0, L, step, (h0, y0))
    h_ref[...] = h_fin
    y_ref[0] = (y + x * D).astype(y_ref.dtype)


def ssm_scan_kernel(
    dt: jax.Array,       # [B, T, d_in]
    x: jax.Array,        # [B, T, d_in]  (post-conv activations)
    Bm: jax.Array,       # [B, T, N]
    Cm: jax.Array,       # [B, T, N]
    A: jax.Array,        # [d_in, N]   (negative)
    D: jax.Array,        # [d_in]
    *,
    chunk: int = 64,
    dblk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, T, d_in = dt.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    dblk = min(dblk, d_in)
    assert T % L == 0 and d_in % dblk == 0
    nc, nd = T // L, d_in // dblk
    grid = (B, nd, nc)
    kern = functools.partial(_ssm_kernel, L=L, dblk=dblk, N=N)
    chan_spec = pl.BlockSpec((1, L, dblk), lambda b, d, c: (b, c, d))
    state_spec = pl.BlockSpec((1, L, N), lambda b, d, c: (b, c, 0))
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            chan_spec,
            chan_spec,
            state_spec,
            state_spec,
            pl.BlockSpec((dblk, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, dblk), lambda b, d, c: (0, d)),
        ],
        out_specs=chan_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, d_in), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dblk, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bm, Cm, A, D.reshape(1, d_in))
