"""jit'd wrapper for the selective-scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import ssm_scan_kernel


@partial(jax.jit, static_argnames=("chunk", "dblk", "interpret"))
def ssm_scan(dt, x, Bm, Cm, A, D, *, chunk: int = 64, dblk: int = 256,
             interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssm_scan_kernel(
        dt, x, Bm, Cm, A, D, chunk=chunk, dblk=dblk, interpret=interpret
    )
