"""Naive sequential oracle for the selective scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(dt, x, Bm, Cm, A, D):
    """dt,x: [B,T,d_in]; Bm,Cm: [B,T,N]; A: [d_in,N]; D: [d_in]."""
    B, T, d_in = dt.shape
    dtf, xf = dt.astype(jnp.float32), x.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af, Df = A.astype(jnp.float32), D.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * Af)           # [B, d_in, N]
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1)    # [B, d_in]
        return h, y

    h0 = jnp.zeros((B, d_in, Af.shape[-1]), jnp.float32)
    xs = (
        dtf.transpose(1, 0, 2), xf.transpose(1, 0, 2),
        Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + xf * Df
    return y
