"""RWKV-6 chunked WKV as a Pallas TPU kernel.

Grid: (batch, heads, chunks) — the chunk dimension is sequential; the
per-head state S ∈ R^{N×N} persists in VMEM scratch across chunk steps.
Each program loads one [L, N] chunk of r/k/v/log-decay, computes

    inter-chunk: (r ⊙ e^{Λ_prev}) @ S                 (MXU)
    intra-chunk: Σ_n r_t k_s e^{Λ_{t-1}−Λ_s} (s<t)    (VPU, bounded exps)
    diagonal:    (r·(u ⊙ k)) v
    state:       S ← e^{Λ_L} ⊙ S + (k e^{Λ_L−Λ})ᵀ V   (MXU)

All decay exponentials are of non-positive arguments (Λ is a cumsum of
log-decays ≤ 0), so fp32 is safe with no clamping. VMEM at L=64, N=64:
the [L, L, N] intra tensor is 1 MiB; everything else is KiB-scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, S_ref, *, L: int, N: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        S_ref[...] = jnp.zeros_like(S_ref)

    r = r_ref[0, 0].astype(jnp.float32)          # [L, N]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)          # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)             # [N]
    S = S_ref[...]                               # [N, N]

    lam = jnp.cumsum(w, axis=0)                  # Λ_t inclusive
    lam_prev = lam - w                           # Λ_{t-1}
    lam_end = lam[-1:, :]                        # Λ_L

    r_in = r * jnp.exp(lam_prev)
    o = jax.lax.dot_general(
        r_in, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # [L, N]

    dl = lam_prev[:, None, :] - lam[None, :, :]  # [L, L, N], <= 0 for s < t
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    att = jnp.sum(
        jnp.where(tri[:, :, None], jnp.exp(dl), 0.0)
        * r[:, None, :]
        * k[None, :, :],
        axis=-1,
    )                                            # [L, L]
    o = o + jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    o = o + diag * v

    k_out = k * jnp.exp(lam_end - lam)
    S_ref[...] = jnp.exp(lam_end)[0][:, None] * S + jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = o.astype(o_ref.dtype)


def wkv6_kernel(
    r: jax.Array,        # [B, H, T, N]
    k: jax.Array,
    v: jax.Array,
    w_log: jax.Array,    # [B, H, T, N], log decay <= 0
    u: jax.Array,        # [H, N]
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    B, H, T, N = r.shape
    L = min(chunk, T)
    assert T % L == 0, f"T={T} % chunk={L}"
    nc = T // L
    grid = (B, H, nc)
    kern = functools.partial(_wkv6_kernel, L=L, N=N)
    spec = pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0))
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            spec, spec, spec, spec,
            pl.BlockSpec((1, N), lambda b, h, c: (h, 0)),
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u)
