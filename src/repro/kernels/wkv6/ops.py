"""jit'd wrapper for the WKV6 kernel (model layout [B, T, H, N])."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import wkv6_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w_log, u, *, chunk: int = 64, interpret: bool | None = None):
    """r,k,v,w_log: [B, T, H, N]; u: [H, N] -> [B, T, H, N] fp32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = wkv6_kernel(
        r.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        w_log.transpose(0, 2, 1, 3),
        u,
        chunk=chunk,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
