"""Naive sequential oracle for WKV6 (the textbook recurrence)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w_log, u):
    """r,k,v,w_log: [B, H, T, N]; u: [H, N]. fp32 output.

        S_t = diag(e^{w_t}) S_{t-1} + k_t ⊗ v_t
        o_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)
    """
    B, H, T, N = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w_log))
    uf = u.astype(jnp.float32)

    def step(S, inputs):
        rt, kt, vt, wt = inputs                      # [B, H, N]
        kv = kt[..., :, None] * vt[..., None, :]     # [B, H, N, N]
        o = jnp.einsum("bhn,bhnm->bhm", rt, S + uf[None, :, :, None] * kv)
        S_new = jnp.exp(wt)[..., :, None] * S + kv
        return S_new, o

    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    xs = tuple(a.transpose(2, 0, 1, 3) for a in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, S0, xs)
    return outs.transpose(1, 2, 0, 3)                # [B, H, T, N]
