import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs — no allocation — and record
memory/cost/collective analysis for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out benchmarks/artifacts/dryrun

The two lines above MUST stay the first statements in this module: jax
locks the device count at first init, and the production meshes need 512
host placeholder devices.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (  # noqa: E402
    SHAPES,
    ModelConfig,
    ShapeCell,
    cell_supported,
    get_config,
    list_archs,
)
from repro.distributed import partitioning as part  # noqa: E402
from repro.distributed.sharding import default_rules, logical_axis_rules  # noqa: E402
from repro.launch import specs as lspecs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import model_flops, parse_collectives, roofline_terms  # noqa: E402
from repro.models.transformer import LM  # noqa: E402
from repro.training.optimizer import AdamWConfig, OptimizerConfig, Schedule  # noqa: E402
from repro.training.train_step import TrainConfig, make_train_step  # noqa: E402


def analysis_twin(cfg: ModelConfig, cell: ShapeCell) -> ModelConfig:
    """Cost-accounting twin: unrolled layer stack + single-tile attention
    so XLA cost_analysis and the HLO collective parse count every layer
    and the full attention quadratic (scan bodies are otherwise counted
    once — verified 8x undercount on a synthetic probe; see EXPERIMENTS.md
    §Roofline methodology). memory_analysis still comes from the scanned
    production lowering."""
    kw: dict = {"unroll_stack": True}
    if cfg.attention is not None:
        import dataclasses

        S = cell.seq_len
        if cfg.encdec is not None:
            S = max(S // cfg.encdec.decoder_seq_divisor, 8)
        kw["attention"] = dataclasses.replace(
            cfg.attention,
            q_chunk=max(S, 1),
            kv_chunk=max(cell.seq_len, 1),
        )
    return cfg.replace(**kw)


def _train_cfg(cfg: ModelConfig) -> TrainConfig:
    return TrainConfig(
        optimizer=OptimizerConfig(
            kind="adamw",
            adamw=AdamWConfig(
                state_dtype=cfg.opt_state_dtype, schedule=Schedule()
            ),
        ),
    )


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    return {k: getattr(ma, k, None) for k in keys}


def apply_variant(cfg: ModelConfig, variant: str) -> ModelConfig:
    """§Perf hillclimb variants (see EXPERIMENTS.md):
    zero1            params replicated over data, optimizer state sharded
                     (ZeRO-1) — kills FSDP weight gathers for <=13B models
    replicated-acts  decode: replicate activations over data, keep the KV
                     cache batch-sharded — kills per-token weight gathers
    bf16-scan        Mamba chunk temporaries in bf16 (halves scan HBM)
    sschunk<L>       Mamba scan chunk length
    """
    if variant.startswith("sschunk") and cfg.ssm is not None:
        import dataclasses

        return cfg.replace(
            ssm=dataclasses.replace(cfg.ssm, chunk=int(variant[7:]))
        )
    if variant == "bf16-scan" and cfg.ssm is not None:
        import dataclasses

        return cfg.replace(
            ssm=dataclasses.replace(cfg.ssm, scan_dtype="bfloat16")
        )
    if "nokvhint" in variant and cfg.attention is not None:
        import dataclasses

        cfg = cfg.replace(
            attention=dataclasses.replace(cfg.attention, kv_replicate_hint=False)
        )
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             train_override: TrainConfig | None = None,
             cfg_override: ModelConfig | None = None,
             variant: str = "") -> dict:
    """Lower + compile one cell; returns the JSON-able record."""
    cfg = cfg_override or get_config(arch)
    if variant:
        cfg = apply_variant(cfg, variant)
    cell = SHAPES[shape_name]
    ok, why = cell_supported(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod)
    if "zero1" in variant:
        # ZeRO-1: params replicated over the data axis; grads all-reduce;
        # optimizer state (and its update) stays data-sharded
        param_rules = dict(rules, fsdp=None)
    else:
        param_rules = rules
    param_specs = lspecs.param_spec_tree(cfg)
    t0 = time.time()

    if cell.kind == "train":
        tcfg = train_override or _train_cfg(cfg)
        init_state, train_step, state_specs = make_train_step(cfg, tcfg)
        abstract_state = lspecs.abstract_train_state(cfg, init_state)
        state_spec_tree = state_specs(param_specs)
        state_sh = part.tree_to_shardings(mesh, rules, state_spec_tree)
        if "zero1" in variant:
            state_sh = {
                "params": part.tree_to_shardings(
                    mesh, param_rules, state_spec_tree["params"]
                ),
                "opt": part.tree_to_shardings(mesh, rules, state_spec_tree["opt"]),
                "step": part.tree_to_shardings(mesh, rules, state_spec_tree["step"]),
            }
        batch_abs = lspecs.train_batch_specs(cfg, cell)
        batch_sh = part.tree_to_shardings(
            mesh, rules, part.batch_specs(cfg, cell)
        )
        # metrics are replicated scalars
        _, metrics_abs = jax.eval_shape(train_step, abstract_state, batch_abs)
        metrics_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), metrics_abs
        )
        with logical_axis_rules(mesh, rules):
            lowered = jax.jit(
                train_step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metrics_sh),
                donate_argnums=0,
            ).lower(abstract_state, batch_abs)
    elif cell.kind == "prefill":
        tokens_abs, kwargs_abs = lspecs.prefill_arg_shapes(cfg, cell)
        cache_len = lspecs.decoder_len(cfg, cell)

        def prefill_fn(params, tokens, kwargs):
            return LM.prefill(
                params, cfg, tokens, cache_len,
                embeds=kwargs.get("embeds"), encoder_frames=kwargs.get("frames"),
            )

        params_abs = jax.eval_shape(
            lambda k: LM.init(k, cfg)[0], lspecs.sds((2,), "uint32")
        )
        params_sh = part.tree_to_shardings(mesh, rules, param_specs)
        dp_spec = P(rules["dp"]) if cell.global_batch > 1 else P()
        tok_sh = NamedSharding(mesh, P(*dp_spec, None))
        kw_sh = {
            k: NamedSharding(mesh, P(*dp_spec, None, None))
            for k in kwargs_abs
        }
        out_shape = jax.eval_shape(prefill_fn, params_abs, tokens_abs, kwargs_abs)
        _, cache_specs, _ = part.prefill_out_specs(cfg, cell)
        cache_sh = part.tree_to_shardings(mesh, rules, cache_specs)
        logits_sh = NamedSharding(mesh, P(*dp_spec, None))
        len_sh = NamedSharding(mesh, P(*dp_spec))
        del out_shape
        with logical_axis_rules(mesh, rules):
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(params_sh, tok_sh, kw_sh),
                out_shardings=(logits_sh, cache_sh, len_sh),
            ).lower(params_abs, tokens_abs, kwargs_abs)
    else:  # decode
        token_abs, caches_abs, len_abs = lspecs.decode_arg_shapes(cfg, cell)
        tok_spec, cache_specs, len_spec = part.decode_arg_specs(cfg, cell)
        if variant == "cache-seqshard":
            # KV cache sequence dim over 'model': attention computes
            # per-shard partial softmax; no whole-cache gather
            def reshard(sp):
                if len(sp) >= 4 and sp[-3] is None:   # [.., B, S, Hk, Dh]
                    return (*sp[:-3], "tp", *sp[-2:])
                return sp

            cache_specs = jax.tree.map(
                reshard, cache_specs, is_leaf=part.is_spec_leaf
            )
        if variant == "replicated-acts":
            # activations/token replicated; cache keeps batch over 'data'
            tok_spec = (None, None)
            len_spec = (None,)
            cache_specs = jax.tree.map(
                lambda sp: tuple("fsdp" if a == "dp" else a for a in sp),
                cache_specs,
                is_leaf=part.is_spec_leaf,
            )
            rules = dict(rules, dp=None)  # model-internal hints replicate too
        params_abs = jax.eval_shape(
            lambda k: LM.init(k, cfg)[0], lspecs.sds((2,), "uint32")
        )
        params_sh = part.tree_to_shardings(mesh, rules, param_specs)
        tok_sh = part.tree_to_shardings(mesh, rules, tok_spec)
        cache_sh = part.tree_to_shardings(mesh, rules, cache_specs)
        len_sh = part.tree_to_shardings(mesh, rules, len_spec)
        logits_sh = NamedSharding(
            mesh,
            P(rules["dp"] if cell.global_batch > 1 and variant != "replicated-acts"
              else None, None),
        )

        def decode_fn(params, token, caches, lengths):
            return LM.decode_step(params, cfg, token, caches, lengths)

        with logical_axis_rules(mesh, rules):
            lowered = jax.jit(
                decode_fn,
                in_shardings=(params_sh, tok_sh, cache_sh, len_sh),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=2,
            ).lower(params_abs, token_abs, caches_abs, len_abs)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = _cost_dict(compiled)
    mem = _mem_dict(compiled)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    n_chips = 512 if multi_pod else 256
    terms = roofline_terms(flops, byts, coll["total_bytes"])
    mflops = model_flops(cfg, cell)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed",
                                          "optimal_seconds") if k in cost},
        "collectives": coll,
        "roofline": terms.to_dict(),
        "model_flops_global": mflops,
        "model_flops_per_chip": mflops / n_chips,
        "useful_flops_ratio": (mflops / n_chips) / flops if flops else None,
        "hlo_bytes": len(hlo),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--analysis", action="store_true",
                    help="unrolled cost-accounting lowering (see §Roofline)")
    ap.add_argument("--variant", default="", help="hillclimb variant id")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for multi_pod in meshes:
        mesh_name = "multi" if multi_pod else "single"
        for arch in archs:
            for shape in shapes:
                prefix = "analysis__" if args.analysis else ""
                if args.variant:
                    prefix += f"variant-{args.variant}__"
                fname = os.path.join(
                    args.out, f"{prefix}{mesh_name}__{arch}__{shape}.json"
                )
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip-existing] {fname}", flush=True)
                    continue
                t0 = time.time()
                try:
                    cfg_over = None
                    if args.analysis:
                        from repro.configs.base import get_config as _gc

                        c = _gc(arch)
                        cfg_over = analysis_twin(c, SHAPES[shape])
                    rec = run_cell(arch, shape, multi_pod, cfg_override=cfg_over,
                                   variant=args.variant)
                    if args.analysis:
                        rec["analysis_mode"] = True
                    if args.variant:
                        rec["variant"] = args.variant
                except Exception as e:  # record the failure — it is a bug
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "error", "error": repr(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                dt = time.time() - t0
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']} comp={r['compute_s']:.3g}s "
                             f"mem={r['memory_s']:.3g}s coll={r['collective_s']:.3g}s")
                elif st == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{mesh_name}] {arch} x {shape}: {st} ({dt:.0f}s){extra}",
                      flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
