"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state. The dry-run forces 512 host platform
devices before any jax import; real deployments get the same topology from
the TPU runtime.

    single-pod: (16, 16)        axes ("data", "model")    — 256 chips
    multi-pod:  (2, 16, 16)     axes ("pod", "data", "model") — 512 chips
"""

from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_local_mesh(axes=("data", "model")):
    """1x1 mesh over the single local device (tests/examples)."""
    import jax

    dev = np.asarray(jax.devices()[:1]).reshape((1,) * len(axes))
    return jax.sharding.Mesh(dev, axes)
