"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e-like constants:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs      (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_chip / HBM_bw          (819 GB/s)
    collective = collective_bytes_per_chip / link_bw  (50 GB/s/link ICI)

``cost_analysis()`` on the SPMD executable reports per-partition (per-
chip) numbers. Collective bytes are parsed from the post-optimization
HLO: per op we charge the RESULT bytes times an op-specific factor
(all-reduce 2x for its reduce-scatter+all-gather ring phases; others 1x)
— a documented, consistent approximation used for both the baseline and
the hillclimbed variants, so deltas are meaningful.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*([^=]+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

#: bytes-moved multiplier per collective kind
_OP_WEIGHT = {
    "all-reduce": 2.0,          # ring: reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-kind {count, bytes(weighted result bytes)} from HLO text."""
    out: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op, _start = m.group(1), m.group(2), m.group(3)
        b = shape_bytes(type_str) * _OP_WEIGHT[op]
        rec = out.setdefault(op, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += b
    out["total_bytes"] = sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict)
    )
    return out


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        """Perfect-overlap bound: the max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-resource roofline this step achieves
        under perfect overlap (1.0 = the dominant term is the only cost)."""
        s = self.compute_s + self.memory_s + self.collective_s
        return self.step_time_lower_bound_s / s if s else 0.0

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "step_lower_bound_s": self.step_time_lower_bound_s,
        }


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_chip / PEAK_FLOPS,
        memory_s=bytes_per_chip / HBM_BW,
        collective_s=coll_bytes_per_chip / ICI_BW,
        flops_per_chip=flops_per_chip,
        bytes_per_chip=bytes_per_chip,
        coll_bytes_per_chip=coll_bytes_per_chip,
    )


def model_flops(cfg, cell) -> float:
    """Analytic 'useful' FLOPs for the step: 6·N·T (train, fwd+bwd) or
    2·N_active·T (inference fwd), T = tokens processed globally."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    tokens = cell.global_batch  # one new token per sequence
    return 2.0 * n * tokens
