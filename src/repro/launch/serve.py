"""Batched serving driver: prefill + decode with KV/state caches.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch rwkv6-7b --reduce --batch 4 --prompt-len 32 --new-tokens 16

Serves batched requests against a (reduced or small) model, reporting
per-phase latency and tokens/s. The decode step lowered here is the same
function the dry-run compiles for the decode_*/long_* cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.transformer import LM
from repro.training.serve_step import make_serve_fns


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        from repro.configs.archs import reduced

        cfg = reduced(cfg)
    log = (lambda *a: None) if args.quiet else (lambda *a: print(*a, flush=True))

    params, _ = LM.init(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, args.prompt_len
    cache_len = S + args.new_tokens
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    kwargs = {}
    if cfg.frontend_tokens:
        kwargs["embeds"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.encdec is not None:
        kwargs["frames"] = jnp.zeros((B, S * 4, cfg.d_model), jnp.bfloat16)

    prefill_fn, decode_fn = make_serve_fns(cfg, cache_len)
    prefill_fn = jax.jit(prefill_fn)
    decode_fn = jax.jit(decode_fn)

    t0 = time.time()
    logits, caches, lengths = prefill_fn(
        params, prompt, kwargs.get("embeds"), kwargs.get("frames")
    )
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    log(f"[prefill] {B}x{S} tokens in {t_prefill:.2f}s "
        f"({B * S / t_prefill:,.0f} tok/s)")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, caches = decode_fn(params, tok, caches, lengths)
        lengths = lengths + 1
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    n_gen = B * args.new_tokens
    log(f"[decode] {args.new_tokens} steps x {B} seqs in {t_decode:.2f}s "
        f"({n_gen / max(t_decode, 1e-9):,.0f} tok/s)")
    seqs = jnp.concatenate(outs, axis=1)
    log(f"[out] tokens[0,:8] = {seqs[0, :8].tolist()}")
    return {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens": seqs,
    }


if __name__ == "__main__":
    main()
