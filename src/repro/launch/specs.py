"""Abstract input construction for every (arch x shape) cell.

``input_specs(cfg, cell)`` returns ShapeDtypeStruct stand-ins for every
model input of that cell's step function — weak-type-correct, shardable,
and allocation-free. Frontend-stub archs ([vlm]/[audio]) receive
precomputed patch/frame embeddings as inputs, per the assignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.transformer import LM


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Batch dict for train/prefill cells. Sequence budget ``cell.seq_len``
    is the TOTAL stream length: [vlm] spends ``frontend_tokens`` of it on
    patch embeddings; [audio] spends it on encoder frames with
    seq/divisor decoder tokens."""
    B, S = cell.global_batch, cell.seq_len
    batch: dict = {}
    n_text = S
    if cfg.frontend_tokens:
        n_text = S - cfg.frontend_tokens
        batch["embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), "bfloat16")
    if cfg.encdec is not None:
        batch["frames"] = sds((B, S, cfg.d_model), "bfloat16")
        n_text = max(S // cfg.encdec.decoder_seq_divisor, 8)
    batch["tokens"] = sds((B, n_text), "int32")
    batch["labels"] = sds((B, n_text), "int32")
    return batch


def decoder_len(cfg: ModelConfig, cell: ShapeCell) -> int:
    if cfg.encdec is not None:
        return max(cell.seq_len // cfg.encdec.decoder_seq_divisor, 8)
    return cell.seq_len


def decode_arg_shapes(cfg: ModelConfig, cell: ShapeCell):
    """(token, caches, lengths) ShapeDtypeStructs for decode cells.

    The cache holds ``seq_len`` tokens (decode_* cells are 'one new token
    against a seq_len cache')."""
    B = cell.global_batch
    cache_len = cell.seq_len
    caches = jax.eval_shape(
        lambda: LM.init_caches(cfg, B, cache_len, jnp.bfloat16)
    )
    token = sds((B, 1), "int32")
    lengths = sds((B,), "int32")
    return token, caches, lengths


def prefill_arg_shapes(cfg: ModelConfig, cell: ShapeCell):
    """(tokens [, embeds, frames]) for prefill cells; cache_len = seq_len."""
    B, S = cell.global_batch, cell.seq_len
    kwargs: dict = {}
    n_text = S
    if cfg.frontend_tokens:
        n_text = S - cfg.frontend_tokens
        kwargs["embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), "bfloat16")
    if cfg.encdec is not None:
        kwargs["frames"] = sds((B, S, cfg.d_model), "bfloat16")
        n_text = decoder_len(cfg, cell)
    tokens = sds((B, n_text), "int32")
    return tokens, kwargs


def abstract_train_state(cfg: ModelConfig, init_state_fn):
    """eval_shape the full train state (params + optimizer) — no allocation."""
    key = sds((2,), "uint32")
    return jax.eval_shape(init_state_fn, key)


def spec_twin(cfg: ModelConfig) -> ModelConfig:
    """A structurally-identical but tiny config used ONLY to materialize the
    logical PartitionSpec tree (spec trees depend on structure, not sizes)."""
    from repro.configs.archs import reduced

    return reduced(cfg).replace(n_layers=cfg.n_layers)


def param_spec_tree(cfg: ModelConfig):
    twin = spec_twin(cfg)
    _, specs = LM.init(jax.random.PRNGKey(0), twin)
    return specs
