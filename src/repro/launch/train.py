"""End-to-end training driver: Sea-staged data -> pjit train loop ->
burst-buffer checkpoints -> crash-safe resume.

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-3-2b --reduce --steps 200 --batch 8 --seq 256 \
        --workdir /tmp/sea_run --ckpt-every 25

The same driver powers the fault-tolerance integration test
(--simulate-failure N aborts the process mid-run; a relaunch with the
same workdir resumes from the latest complete checkpoint).
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, checkpoint_sea_config
from repro.configs.base import get_config
from repro.core import Sea
from repro.data.pipeline import DataPipeline, write_dataset
from repro.distributed.fault import HeartbeatMonitor
from repro.training.optimizer import AdamWConfig, OptimizerConfig, Schedule
from repro.training.train_step import TrainConfig, make_train_step


def small_lm(n_params_m: int = 20, vocab: int = 8192):
    """A ~n_params_m-million-parameter dense LM for CPU-scale end-to-end
    runs (d_model chosen so 12·L·d² + 2·V·d ≈ target)."""
    from repro.configs.base import AttentionConfig, ModelConfig

    n_layers = 8
    target = n_params_m * 1e6
    # params ≈ n_layers * 12 d^2 + 2 V d  (SwiGLU w/ d_ff=2.67d ≈ 8d^2 + attn 4d^2)
    a, b, c = n_layers * 12, 2 * vocab, -target
    d = int((-b + math.sqrt(b * b - 4 * a * c)) / (2 * a) // 64 * 64) or 64
    return ModelConfig(
        name=f"small-{n_params_m}m",
        family="dense",
        n_layers=n_layers,
        d_model=d,
        d_ff=int(d * 8 / 3 // 64 * 64) or 128,
        vocab_size=vocab,
        pattern=("attn:mlp",),
        attention=AttentionConfig(
            num_heads=max(d // 64, 1), num_kv_heads=max(d // 128, 1),
            head_dim=64, q_chunk=128, kv_chunk=128,
        ),
        remat="none",
    )


def build_model_config(args):
    if args.arch == "small":
        return small_lm(args.params_m)
    cfg = get_config(args.arch)
    if args.reduce:
        from repro.configs.archs import reduced

        cfg = reduced(cfg)
    return cfg


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small",
                    help="'small' or any assigned arch id (with --reduce)")
    ap.add_argument("--params-m", type=int, default=20)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default="/tmp/sea_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none")
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="abort() at this step (fault-tolerance testing)")
    ap.add_argument("--n-shards", type=int, default=8)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cfg = build_model_config(args)
    os.makedirs(args.workdir, exist_ok=True)
    sea = Sea(checkpoint_sea_config(
        args.workdir, max_file_size=1 << 24, n_procs=2
    )).start()
    log = (lambda *a: None) if args.quiet else (lambda *a: print(*a, flush=True))

    # ---- dataset (build once; later runs reuse the persistent copy) --------
    ds_meta = os.path.join(sea.fs.mount, "dataset", "corpus", "meta.json")
    if not sea.fs.exists(ds_meta):
        log(f"[data] writing {args.n_shards} shards through Sea")
        write_dataset(
            sea, "corpus",
            n_shards=args.n_shards,
            tokens_per_shard=args.batch * (args.seq + 1) * 16,
            vocab_size=cfg.vocab_size,
        )

    # ---- train step ----------------------------------------------------------
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(
            kind="adamw",
            adamw=AdamWConfig(
                state_dtype=cfg.opt_state_dtype,
                schedule=Schedule(base_lr=args.lr, warmup_steps=10,
                                  decay_steps=max(args.steps, 20)),
            ),
        ),
        microbatches=args.microbatches,
        compression=args.compression,
        seq_chunk_loss=min(args.seq, 512),
    )
    init_state, train_step, _ = make_train_step(cfg, tcfg)
    train_step = jax.jit(train_step, donate_argnums=0)

    ckpt = CheckpointManager(sea, keep_n=3)
    hb = HeartbeatMonitor(os.path.join(sea.fs.mount, "heartbeats"), 0, fs=sea.fs)

    template = jax.eval_shape(init_state, jax.ShapeDtypeStruct((2,), jnp.uint32))
    start_step, state = ckpt.restore_latest(template)
    if state is None:
        state = init_state(jax.random.PRNGKey(0))
        start_step = 0
        log(f"[init] fresh start: {cfg.name}, "
            f"{sum(x.size for x in jax.tree.leaves(state['params'])):,} params")
    else:
        log(f"[init] resumed from checkpoint step {start_step}")

    async_ckpt = bool(getattr(sea.fs.config, "checkpoint_async", True))
    pipe = DataPipeline(
        sea, "corpus", batch_size=args.batch, seq_len=args.seq,
        start_shard=0,
    )
    it = pipe.device_iter()   # batches arrive already device_put
    losses = []
    t_start = time.time()
    try:
        for step in range(start_step, args.steps):
            try:
                batch = next(it)
            except StopIteration:
                pipe.close()
                pipe = DataPipeline(sea, "corpus", batch_size=args.batch,
                                    seq_len=args.seq)
                it = pipe.device_iter()
                batch = next(it)
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            hb.beat(step)
            if not args.quiet and (step % 10 == 0 or step == args.steps - 1):
                toks = args.batch * args.seq / (time.time() - t0)
                log(f"[step {step:5d}] loss={loss:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.2f} tok/s={toks:,.0f}")
            if args.simulate_failure and step + 1 == args.simulate_failure:
                log(f"[fault] simulating crash at step {step + 1}")
                os._exit(17)   # hard abort: no drain, no atexit
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                # async: the step loop pays only the device->host snapshot;
                # leaf writes overlap the next ckpt_every steps of compute
                out = ckpt.save(step + 1, state, async_=async_ckpt)
                d = out.directory if async_ckpt else out
                log(f"[ckpt] step {step + 1} -> {d} "
                    f"({'async' if async_ckpt else 'blocking'})")
    finally:
        # error path included: never leave the staging / device-feed
        # threads reading shards after the loop is gone
        pipe.close()
    ckpt.wait()      # last async save must commit before the final drain
    sea.shutdown()   # final flush: checkpoints materialize on the PFS tier
    wall = time.time() - t_start
    result = {
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        "steps": len(losses),
        "wall_s": wall,
        "telemetry": sea.fs.telemetry.snapshot(),
    }
    log(f"[done] {len(losses)} steps in {wall:.0f}s; "
        f"loss {result['first_loss']:.3f} -> {result['final_loss']:.3f}")
    return result


if __name__ == "__main__":
    main()
