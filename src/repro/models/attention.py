"""GQA attention: flash-style chunked online-softmax in pure XLA, sliding
windows, KV caches (full + ring-buffer for local layers), decode paths.

The chunked path is the XLA twin of the Pallas flash kernel
(``repro.kernels.flash_attention``) and doubles as its oracle at small
sizes. Scores/softmax statistics accumulate in fp32; the P·V matmul runs
in the compute dtype for the MXU.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.distributed.sharding import DP, FSDP, TP, shard_hint
from repro.models.layers import (
    Layout,
    apply_rope,
    dense_init,
    norm_init,
    qk_head_norm,
)

NEG_INF = -1e30


# --------------------------------------------------------------- core math
def _chunk_attend(q, k, v, qpos, kpos, *, causal, window, softcap, compute_dtype):
    """One (q-chunk, kv-chunk) tile: returns fp32 (scores_exp, m, l, pv).

    q: [B, Hk, G, Lq, Dh]   k/v: [B, Hk, Lk, Dh]
    qpos: [Lq], kpos: [Lk]  absolute positions for masking.
    """
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", q, k, preferred_element_type=jnp.float32
    )
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.ones((q.shape[3], k.shape[2]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


def chunked_attention(
    q: jax.Array,            # [B, Sq, H, Dh]
    k: jax.Array,            # [B, Sk, Hk, Dh]
    v: jax.Array,            # [B, Sk, Hk, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    softcap: float | None = None,
    q_offset: int = 0,       # absolute position of q[0] (prefill continuation)
) -> jax.Array:
    """Flash-style attention with O(S·chunk) live memory."""
    B, Sq, H, Dh = q.shape
    _, Sk, Hk, _ = k.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(Dh)
    cdt = q.dtype

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    pad_q = nq * q_chunk - Sq
    pad_k = nk * kv_chunk - Sk

    qr = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kr = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vr = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    # [B, Hk, G, S, Dh] / [B, Hk, S, Dh]
    qr = (qr.reshape(B, nq * q_chunk, Hk, G, Dh) * scale).transpose(0, 2, 3, 1, 4)
    kr = kr.transpose(0, 2, 1, 3)
    vr = vr.transpose(0, 2, 1, 3)

    kpos_all = jnp.arange(nk * kv_chunk)
    kvalid = kpos_all < Sk

    def q_body(_, qi):
        qblk = jax.lax.dynamic_slice_in_dim(qr, qi * q_chunk, q_chunk, axis=3)
        qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_body(carry, ki):
            m, l, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(kr, ki * kv_chunk, kv_chunk, axis=2)
            vblk = jax.lax.dynamic_slice_in_dim(vr, ki * kv_chunk, kv_chunk, axis=2)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = _chunk_attend(
                qblk, kblk, vblk, qpos, kpos,
                causal=causal, window=window, softcap=softcap, compute_dtype=cdt,
            )
            s = jnp.where(
                jax.lax.dynamic_slice_in_dim(kvalid, ki * kv_chunk, kv_chunk)[
                    None, None, None, None, :
                ],
                s,
                NEG_INF,
            )
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhgqk,bhkd->bhgqd",
                p.astype(cdt),
                vblk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hk, G, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(cdt)

    _, blocks = jax.lax.scan(q_body, None, jnp.arange(nq))
    # blocks: [nq, B, Hk, G, q_chunk, Dh] -> [B, S, H, Dh]
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, Dh)
    return out[:, :Sq]


def decode_attention(
    q: jax.Array,            # [B, 1, H, Dh]
    k_cache: jax.Array,      # [B, S, Hk, Dh]
    v_cache: jax.Array,
    length: jax.Array | int, # valid cache length (inclusive of current token)
    *,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Single-token attention against a cache — one matmul pass, fp32
    softmax. Memory-bound by the cache read (the roofline term that
    dominates decode shapes)."""
    B, _, H, Dh = q.shape
    _, S, Hk, _ = k_cache.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Hk, G, Dh) * scale
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(S)
    valid = pos[None, :] < (
        length if isinstance(length, jax.Array) else jnp.full((B,), length)
    )[:, None]
    if window is not None:
        cur = (
            length if isinstance(length, jax.Array) else jnp.full((B,), length)
        )[:, None]
        valid &= pos[None, :] > cur - 1 - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# --------------------------------------------------------------- module
@dataclass
class KVCache:
    """Cache spec helper: full caches for global layers, ring buffers of
    ``window`` slots for sliding-window layers (what makes gemma3-style
    5:1 interleaves cheap at 500k)."""

    k: jax.Array
    v: jax.Array


def attn_init(key, cfg: AttentionConfig, d_model: int, layout: Layout, eps: float):
    H, Hk, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], d_model, H * Dh, FSDP, TP, layout)
    p["wk"], s["wk"] = dense_init(ks[1], d_model, Hk * Dh, FSDP, TP, layout)
    p["wv"], s["wv"] = dense_init(ks[2], d_model, Hk * Dh, FSDP, TP, layout)
    p["wo"], s["wo"] = dense_init(ks[3], H * Dh, d_model, TP, FSDP, layout)
    if cfg.qk_norm:
        p["q_norm"], s["q_norm"] = norm_init(Dh, layout)
        p["k_norm"], s["k_norm"] = norm_init(Dh, layout)
    return p, s


def _project_qkv(p, cfg: AttentionConfig, x, positions, theta, eps):
    B, S, D = x.shape
    H, Hk, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    k = (x @ p["wk"]).reshape(B, S, Hk, Dh)
    v = (x @ p["wv"]).reshape(B, S, Hk, Dh)
    if cfg.qk_norm:
        q = qk_head_norm(q, p["q_norm"], eps)
        k = qk_head_norm(k, p["k_norm"], eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def attn_apply(
    p,
    cfg: AttentionConfig,
    x: jax.Array,                  # [B, S, D]
    *,
    local: bool,
    eps: float,
    positions: jax.Array | None = None,
) -> jax.Array:
    """Training/prefill self-attention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    theta = cfg.rope_theta_local if local else cfg.rope_theta
    q, k, v = _project_qkv(p, cfg, x, positions, theta, eps)
    q = shard_hint(q, DP, None, TP, None)
    if cfg.kv_replicate_hint:
        k = shard_hint(k, DP, None, None, None)
        v = shard_hint(v, DP, None, None, None)
    window = cfg.sliding_window if local else None
    out = chunked_attention(
        q, k, v,
        causal=cfg.causal,
        window=window,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
        softcap=cfg.logit_softcap,
    )
    return out.reshape(B, S, -1) @ p["wo"]


def attn_decode(
    p,
    cfg: AttentionConfig,
    x: jax.Array,                  # [B, 1, D]
    cache_k: jax.Array,            # [B, S_cache, Hk, Dh]  (ring if local)
    cache_v: jax.Array,
    length: jax.Array,             # [B] current position (tokens so far)
    *,
    local: bool,
    eps: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step: insert the new k/v, attend over the cache.

    Local layers use a ring buffer: slot = length % cache_len. Returns
    (out [B,1,D], new_k, new_v).
    """
    B = x.shape[0]
    theta = cfg.rope_theta_local if local else cfg.rope_theta
    q, k, v = _project_qkv(p, cfg, x, length[:, None], theta, eps)
    S_cache = cache_k.shape[1]
    if local:
        slot = length % S_cache                       # ring buffer
    else:
        slot = jnp.minimum(length, S_cache - 1)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    if local:
        # ring buffer: every live slot is within the window by construction
        mask_len = jnp.minimum(length + 1, S_cache)
        out = decode_attention(q, cache_k, cache_v, mask_len, window=None,
                               softcap=cfg.logit_softcap)
    else:
        out = decode_attention(q, cache_k, cache_v, length + 1, window=None,
                               softcap=cfg.logit_softcap)
    return out.reshape(B, 1, -1) @ p["wo"], cache_k, cache_v


def attn_cache_shape(cfg: AttentionConfig, batch: int, seq_len: int, local: bool,
                     dtype) -> tuple[tuple, tuple]:
    S = min(cfg.sliding_window, seq_len) if (local and cfg.sliding_window) else seq_len
    shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
    return shape, dtype
