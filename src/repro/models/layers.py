"""Core layer primitives: norms, rotary embeddings, GLU MLPs, embeddings.

Every init function returns ``(params, specs)`` where ``specs`` mirrors the
param tree with *logical* PartitionSpec tuples (see
``repro.distributed.sharding``): "fsdp" shards over the data axis (ZeRO-3),
"tp" over the model axis (Megatron TP), "ep" over experts.

Numerics policy: params/activations bf16; RMSNorm statistics, softmax,
router logits and final logits in fp32.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import EP, FSDP, TP  # noqa: F401  (re-export)

Dtype = jnp.dtype


def to_dtype(name: str) -> Dtype:
    return jnp.dtype(name)


@dataclasses.dataclass(frozen=True)
class Layout:
    """Dtype bundle threaded through model construction."""

    param_dtype: Dtype
    compute_dtype: Dtype

    @classmethod
    def from_config(cls, cfg) -> "Layout":
        return cls(to_dtype(cfg.param_dtype), to_dtype(cfg.compute_dtype))


# ------------------------------------------------------------------ inits
def dense_init(key, in_dim: int, out_dim: int, in_axis, out_axis, layout: Layout,
               scale: float | None = None):
    """Dense kernel [in, out] with truncated-normal fan-in init."""
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * std
    return w.astype(layout.param_dtype), (in_axis, out_axis)


def embed_init(key, vocab: int, dim: int, layout: Layout):
    # unit-RMS after the sqrt(d_model) embed scaling in the model
    w = jax.random.normal(key, (vocab, dim)) * (1.0 / math.sqrt(dim))
    return w.astype(layout.param_dtype), (TP, FSDP)


def norm_init(dim: int, layout: Layout):
    # norm scales stay fp32 — they are tiny and numerically sensitive
    return jnp.ones((dim,), jnp.float32), (None,)


# ------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def qk_head_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm over the head dim (qwen3/gemma3-style qk-norm)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------ rotary
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] (int). Pairwise rotation on
    the last dim, fp32 trig."""
    dt = x.dtype
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(ang)[..., :, None, :]                 # [..., S, 1, Dh/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(dt)


# ------------------------------------------------------------------ acts
def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ------------------------------------------------------------------ MLP
def mlp_init(key, d_model: int, d_ff: int, layout: Layout):
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["wi"], s["wi"] = dense_init(k1, d_model, d_ff, FSDP, TP, layout)
    p["wg"], s["wg"] = dense_init(k2, d_model, d_ff, FSDP, TP, layout)
    p["wo"], s["wo"] = dense_init(k3, d_ff, d_model, TP, FSDP, layout)
    return p, s


def mlp_apply(p, x: jax.Array, act_name: str) -> jax.Array:
    """SwiGLU/GeGLU MLP."""
    act = activation(act_name)
    h = act(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


# ------------------------------------------------------------------ embed/logits
def unembed_logits(x: jax.Array, table: jax.Array) -> jax.Array:
    """fp32 logits; `table` may be the (tied) embedding [V, D] or an
    untied head stored as [D, V]."""
    if table.shape[0] == x.shape[-1]:
        return jnp.einsum("...d,dv->...v", x, table, preferred_element_type=jnp.float32)
    return jnp.einsum("...d,vd->...v", x, table, preferred_element_type=jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """Mean CE over unmasked tokens, fp32. Returns (loss, n_tokens)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll), nll.size
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, denom
