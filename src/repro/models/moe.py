"""Mixture-of-Experts FFN: top-k routing + grouped capacity dispatch.

Dispatch strategy (TPU/SPMD-adapted GShard): tokens are routed *within
their batch row* (group = batch element, which is data-parallel-sharded),
so slot assignment (a cumulative sum) never crosses shards. Each group
scatters its tokens into a per-expert capacity buffer [B, E, C, D]; the
expert einsum contracts it against the expert stacks (E shards over the
model axis → XLA emits the canonical MoE all-to-all), and outputs gather
back into token order locally. The [T, E, C] one-hot einsum of the
original GShard formulation — O(T·E·C) memory, prohibitive at our token
counts — is avoided entirely.

Router logits are fp32; a Switch-style load-balance auxiliary loss is
returned. Padding experts (qwen2's 60 -> 64 for EP divisibility) carry
zero traffic via -inf router logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.sharding import DP, EP, FSDP, shard_hint
from repro.models.layers import Layout, activation, dense_init, mlp_apply, mlp_init


def moe_init(key, cfg: MoEConfig, d_model: int, layout: Layout):
    E = cfg.num_experts + cfg.padded_experts
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(ks[0], d_model, E, FSDP, None, layout)

    def expert_stack(k, shape, in_dim):
        w = (
            jax.random.truncated_normal(k, -2.0, 2.0, shape)
            * (1.0 / jnp.sqrt(in_dim))
        ).astype(layout.param_dtype)
        return w

    F = cfg.d_ff_expert
    p["w_in"] = expert_stack(ks[1], (E, d_model, F), d_model)
    s["w_in"] = (EP, FSDP, None)
    p["w_gate"] = expert_stack(ks[2], (E, d_model, F), d_model)
    s["w_gate"] = (EP, FSDP, None)
    p["w_out"] = expert_stack(ks[3], (E, F, d_model), F)
    s["w_out"] = (EP, None, FSDP)
    if cfg.d_ff_shared:
        p["shared"], s["shared"] = mlp_init(ks[4], d_model, cfg.d_ff_shared, layout)
        p["shared_gate"], s["shared_gate"] = dense_init(
            ks[5], d_model, 1, FSDP, None, layout
        )
    return p, s


def capacity_per_group(cfg: MoEConfig, group_tokens: int) -> int:
    """Per-group expert capacity, MXU-aligned, never above group_tokens*k."""
    raw = int(group_tokens * cfg.top_k * cfg.capacity_factor) // max(
        cfg.num_experts, 1
    )
    cap = max(8, -(-max(raw, 1) // 8) * 8)
    return min(cap, group_tokens * cfg.top_k)


def moe_apply(p, cfg: MoEConfig, x: jax.Array, act_name: str):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    E = cfg.num_experts + cfg.padded_experts
    k = cfg.top_k
    C = capacity_per_group(cfg, S)

    # ---- router (fp32) -----------------------------------------------------
    logits = jnp.einsum(
        "bsd,de->bse", x, p["router"], preferred_element_type=jnp.float32
    )
    if cfg.padded_experts:
        pad_mask = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- Switch-style load-balance auxiliary loss ---------------------------
    me = jnp.mean(probs, axis=(0, 1))                          # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )
    aux = jnp.sum(me * ce) * (cfg.num_experts / max(k, 1))

    # ---- group-local slot assignment (cumsum along S only) -------------------
    flat_eid = expert_ids.reshape(B, S * k)                    # [B, Sk]
    flat_gate = gate_vals.reshape(B, S * k)
    onehot = jax.nn.one_hot(flat_eid, E, dtype=jnp.int32)      # [B, Sk, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot                  # exclusive
    slot = jnp.take_along_axis(pos, flat_eid[..., None], axis=2)[..., 0]
    keep = slot < C
    safe_slot = jnp.where(keep, slot, C - 1)
    tok_idx = jnp.repeat(jnp.arange(S), k)[None, :].repeat(B, axis=0)

    # ---- dispatch into [B, E, C, D] -------------------------------------------
    contrib = jnp.where(keep[..., None], jnp.take_along_axis(
        x, tok_idx[..., None], axis=1
    ), 0).astype(x.dtype)                                      # [B, Sk, D]
    buf = jnp.zeros((B, E, C, D), x.dtype)
    bidx = jnp.arange(B)[:, None].repeat(S * k, axis=1)
    buf = buf.at[bidx, flat_eid, safe_slot].add(contrib, mode="drop")
    buf = shard_hint(buf, DP, EP, None, None)

    # ---- expert computation (batched einsum over E; EP all-to-all) ------------
    act = activation(act_name)
    h = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * jnp.einsum(
        "becd,edf->becf", buf, p["w_in"]
    )
    eo = jnp.einsum("becf,efd->becd", h, p["w_out"])
    eo = shard_hint(eo, DP, EP, None, None)

    # ---- combine: gather each assignment's expert output ----------------------
    gathered = eo[bidx, flat_eid, safe_slot]                   # [B, Sk, D]
    gathered = jnp.where(keep[..., None], gathered, 0)
    weighted = gathered.astype(jnp.float32) * flat_gate[..., None]
    out = jnp.zeros((B, S, D), jnp.float32)
    out = out.at[bidx, tok_idx].add(weighted)

    # ---- shared experts (qwen2-style, sigmoid-gated) ---------------------------
    if cfg.d_ff_shared:
        gate = jax.nn.sigmoid(
            jnp.einsum(
                "bsd,dz->bsz", x, p["shared_gate"],
                preferred_element_type=jnp.float32,
            )
        )
        shared = mlp_apply(p["shared"], x, act_name).astype(jnp.float32)
        out = out + gate * shared

    out = out.astype(x.dtype)
    return shard_hint(out, DP, None, None), aux
