"""RWKV-6 "Finch" block: data-dependent-decay linear attention (WKV6) with
token-shift dd-lerp, plus the RWKV channel-mix FFN.

TPU adaptation (DESIGN.md §2): the reference CUDA kernel walks the
recurrence elementwise; here the sequence is processed in chunks of
``L`` steps so the intra-chunk work becomes matmuls (MXU) while the state
is carried across chunks by a ``lax.scan``. All decay exponentials are
exponentials of *non-positive* log-decay differences (Λ is monotonically
decreasing), so the chunked form is numerically safe in fp32 without the
clamping tricks CUDA implementations need.

    state S ∈ R^{N×N} per head;  per step t:
        S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
        o_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig
from repro.distributed.sharding import DP, FSDP, TP, shard_hint
from repro.models.layers import Layout, dense_init


# ------------------------------------------------------------------ chunked WKV
def wkv6_chunked(r, k, v, w_log, u, *, chunk: int, return_state: bool = False):
    """r,k,v: [B, T, H, N]; w_log: [B, T, H, N] (log decay, <= 0);
    u: [H, N]. Returns o: [B, T, H, N] (fp32), and the final state when
    ``return_state``."""
    B, T, H, N = r.shape
    L = min(chunk, T)
    assert T % L == 0, f"T={T} must be divisible by chunk={L}"
    nc = T // L

    rf = r.astype(jnp.float32).reshape(B, nc, L, H, N).transpose(1, 0, 3, 2, 4)
    kf = k.astype(jnp.float32).reshape(B, nc, L, H, N).transpose(1, 0, 3, 2, 4)
    vf = v.astype(jnp.float32).reshape(B, nc, L, H, N).transpose(1, 0, 3, 2, 4)
    wf = w_log.astype(jnp.float32).reshape(B, nc, L, H, N).transpose(1, 0, 3, 2, 4)
    # shapes now [nc, B, H, L, N]
    uf = u.astype(jnp.float32)

    def chunk_body(S, inputs):
        rc, kc, vc, wc = inputs                    # [B, H, L, N]
        lam = jnp.cumsum(wc, axis=2)               # Λ_t (inclusive), <= 0
        lam_prev = lam - wc                        # Λ_{t-1} (exclusive)
        lam_end = lam[:, :, -1:, :]                # Λ_L
        # inter-chunk: o_t += (r_t ⊙ e^{Λ_{t-1}}) @ S
        r_in = rc * jnp.exp(lam_prev)
        o = jnp.einsum("bhln,bhnm->bhlm", r_in, S)
        # intra-chunk (s < t):  A_ts = Σ_n r_tn k_sn e^{Λ_{t-1,n} − Λ_{s,n}}
        dl = lam_prev[:, :, :, None, :] - lam[:, :, None, :, :]   # [B,H,L,L,N]
        causal = jnp.tril(jnp.ones((L, L), bool), k=-1)[None, None, :, :, None]
        att = jnp.sum(
            jnp.where(causal, jnp.exp(dl), 0.0)
            * rc[:, :, :, None, :]
            * kc[:, :, None, :, :],
            axis=-1,
        )                                           # [B, H, L, L]
        o = o + jnp.einsum("bhts,bhsn->bhtn", att, vc)
        # diagonal bonus: r_t · (u ⊙ k_t) v_t
        diag = jnp.sum(rc * uf[None, :, None, :] * kc, axis=-1, keepdims=True)
        o = o + diag * vc
        # state update: S' = e^{Λ_L} ⊙_rows S + Σ_s (k_s e^{Λ_L − Λ_s}) ⊗ v_s
        k_out = kc * jnp.exp(lam_end - lam)
        S_new = jnp.exp(lam_end)[:, :, 0, :, None] * S + jnp.einsum(
            "bhln,bhlm->bhnm", k_out, vc
        )
        return S_new, o

    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    S_fin, outs = jax.lax.scan(chunk_body, S0, (rf, kf, vf, wf))
    # outs: [nc, B, H, L, N] -> [B, T, H, N]
    o = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, N)
    return (o, S_fin) if return_state else o


def wkv6_step(S, r, k, v, w_log, u):
    """One decode step. S: [B,H,N,N]; r,k,v,w_log: [B,H,N]."""
    Sf = S.astype(jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]             # [B,H,N,N]
    o = jnp.einsum(
        "bhn,bhnm->bhm", rf, Sf + u.astype(jnp.float32)[None, :, :, None] * kv
    )
    S_new = jnp.exp(w_log.astype(jnp.float32))[..., :, None] * Sf + kv
    return S_new, o


# ------------------------------------------------------------------ module
def rwkv_block_init(key, cfg: RWKVConfig, d_model: int, layout: Layout):
    D = d_model
    H = D // cfg.head_size
    ks = jax.random.split(key, 10)
    p, s = {}, {}
    # token-shift dd-lerp
    p["maa_x"] = jnp.zeros((D,), layout.param_dtype); s["maa_x"] = (None,)
    p["maa_5"] = jnp.zeros((5, D), layout.param_dtype); s["maa_5"] = (None, None)
    p["maa_w1"], s["maa_w1"] = dense_init(
        ks[0], D, 5 * cfg.token_shift_lora, FSDP, None, layout
    )
    p["maa_w2"] = (
        jax.random.normal(ks[1], (5, cfg.token_shift_lora, D)) * 0.01
    ).astype(layout.param_dtype)
    s["maa_w2"] = (None, None, TP)
    # decay
    p["decay_base"] = jnp.full((D,), -6.0, jnp.float32); s["decay_base"] = (None,)
    p["decay_w1"], s["decay_w1"] = dense_init(ks[2], D, cfg.decay_lora, FSDP, None, layout)
    p["decay_w2"], s["decay_w2"] = dense_init(ks[3], cfg.decay_lora, D, None, TP, layout)
    # bonus
    p["u"] = jnp.zeros((H, cfg.head_size), jnp.float32); s["u"] = (TP, None)
    # projections
    p["wr"], s["wr"] = dense_init(ks[4], D, D, FSDP, TP, layout)
    p["wk"], s["wk"] = dense_init(ks[5], D, D, FSDP, TP, layout)
    p["wv"], s["wv"] = dense_init(ks[6], D, D, FSDP, TP, layout)
    p["wg"], s["wg"] = dense_init(ks[7], D, D, FSDP, TP, layout)
    p["wo"], s["wo"] = dense_init(ks[8], D, D, TP, FSDP, layout)
    # per-head group norm
    p["ln_x_scale"] = jnp.ones((D,), jnp.float32); s["ln_x_scale"] = (None,)
    p["ln_x_bias"] = jnp.zeros((D,), jnp.float32); s["ln_x_bias"] = (None,)
    return p, s


def _token_shift(x, x_prev):
    """Shift sequence right by one; position 0 receives x_prev (decode carry
    or zeros)."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def _ddlerp(p, x, shifted):
    """RWKV6 data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    dx = shifted - x
    xx = x + dx * p["maa_x"]
    # low-rank adjustments, one per mixed stream (w,k,v,r,g)
    a = jnp.tanh(xx @ p["maa_w1"])
    a = a.reshape(*a.shape[:-1], 5, -1)
    parts = []
    for i in range(5):
        ai = a[..., i, :]
        adj_i = ai @ p["maa_w2"][i]
        parts.append(x + dx * (p["maa_5"][i] + adj_i))
    return parts  # [xw, xk, xv, xr, xg]


def _project(p, cfg: RWKVConfig, x, shifted, head_size):
    B, T, D = x.shape
    H = D // head_size
    xw, xk, xv, xr, xg = _ddlerp(p, x, shifted)
    # decay (fp32, <= 0 after -exp)
    w_raw = p["decay_base"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    ).astype(jnp.float32)
    w_log = -jnp.exp(w_raw)                                   # log decay <= 0
    r = (xr @ p["wr"]).reshape(B, T, H, head_size)
    k = (xk @ p["wk"]).reshape(B, T, H, head_size)
    v = (xv @ p["wv"]).reshape(B, T, H, head_size)
    g = jax.nn.silu(xg @ p["wg"])
    return r, k, v, w_log.reshape(B, T, H, head_size), g


def _group_norm(p, o, eps=64e-5):
    """Per-head LayerNorm (RWKV's GroupNorm(H))."""
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    yn = (o - mean) * jax.lax.rsqrt(var + eps)
    B, T, H, N = o.shape
    y = yn.reshape(B, T, H * N)
    return y * p["ln_x_scale"] + p["ln_x_bias"]


def rwkv_block_apply(p, cfg: RWKVConfig, x: jax.Array) -> jax.Array:
    """Training/prefill time-mix. x: [B, T, D]."""
    B, T, D = x.shape
    shifted = _token_shift(x, jnp.zeros((B, D), x.dtype))
    r, k, v, w_log, g = _project(p, cfg, x, shifted, cfg.head_size)
    o = wkv6_chunked(r, k, v, w_log, p["u"], chunk=cfg.chunk)
    y = _group_norm(p, o).astype(x.dtype)
    y = shard_hint(y * g, DP, None, TP)
    return y @ p["wo"]


def rwkv_block_prefill(p, cfg: RWKVConfig, x: jax.Array):
    """Like apply, but also returns (x_last, S_final) for decode."""
    B, T, D = x.shape
    shifted = _token_shift(x, jnp.zeros((B, D), x.dtype))
    r, k, v, w_log, g = _project(p, cfg, x, shifted, cfg.head_size)
    o, S_fin = wkv6_chunked(r, k, v, w_log, p["u"], chunk=cfg.chunk,
                            return_state=True)
    y = _group_norm(p, o).astype(x.dtype)
    y = shard_hint(y * g, DP, None, TP)
    return y @ p["wo"], (x[:, -1, :], S_fin)


def rwkv_block_decode(p, cfg: RWKVConfig, x, state):
    """x: [B, 1, D]; state = (x_prev [B,D], S [B,H,N,N])."""
    B, _, D = x.shape
    x_prev, S = state
    shifted = x_prev[:, None, :]
    r, k, v, w_log, g = _project(p, cfg, x, shifted, cfg.head_size)
    S_new, o = wkv6_step(
        S, r[:, 0], k[:, 0], v[:, 0], w_log[:, 0], p["u"]
    )
    y = _group_norm(p, o[:, None, :, :]).astype(x.dtype)
    y = y * g
    return y @ p["wo"], (x[:, 0, :], S_new)


# ------------------------------------------------------------------ channel mix
def rwkv_ffn_init(key, d_model: int, d_ff: int, layout: Layout):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["maa_k"] = jnp.zeros((d_model,), layout.param_dtype); s["maa_k"] = (None,)
    p["maa_r"] = jnp.zeros((d_model,), layout.param_dtype); s["maa_r"] = (None,)
    p["wk"], s["wk"] = dense_init(ks[0], d_model, d_ff, FSDP, TP, layout)
    p["wv"], s["wv"] = dense_init(ks[1], d_ff, d_model, TP, FSDP, layout)
    p["wr"], s["wr"] = dense_init(ks[2], d_model, d_model, FSDP, None, layout)
    return p, s


def rwkv_ffn_apply(p, x: jax.Array, x_prev: jax.Array | None = None):
    B = x.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((B, x.shape[-1]), x.dtype)
    shifted = _token_shift(x, x_prev)
    dx = shifted - x
    xk = x + dx * p["maa_k"]
    xr = x + dx * p["maa_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])


def rwkv_ffn_decode(p, x, x_prev):
    out = rwkv_ffn_apply(p, x, x_prev)
    return out, x[:, 0, :]
