"""Mamba-1 selective SSM mixer (the Jamba 'mamba' sublayer).

TPU adaptation: the CUDA selective-scan walks time sequentially per
channel; here time is processed in chunks under ``lax.scan`` with a
parallel ``associative_scan`` inside each chunk, so the O(T) dependency
becomes O(T/L) sequential steps of MXU/VPU-friendly batched work. The
[T, d_inner, N] state expansion only ever materializes one chunk at a
time (d_inner shards over the model axis).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.distributed.sharding import DP, FSDP, TP, shard_hint
from repro.models.layers import Layout, dense_init, rms_norm


def ssm_init(key, cfg: SSMConfig, d_model: int, layout: Layout):
    d_in = cfg.expand * d_model
    dtr = cfg.dt_rank or math.ceil(d_model / 16)
    N = cfg.d_state
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["in_proj"], s["in_proj"] = dense_init(ks[0], d_model, 2 * d_in, FSDP, TP, layout)
    p["conv_w"] = (
        jax.random.normal(ks[1], (cfg.d_conv, d_in)) / math.sqrt(cfg.d_conv)
    ).astype(layout.param_dtype)
    s["conv_w"] = (None, TP)
    p["conv_b"] = jnp.zeros((d_in,), layout.param_dtype); s["conv_b"] = (TP,)
    p["x_proj"], s["x_proj"] = dense_init(ks[2], d_in, dtr + 2 * N, TP, None, layout)
    p["dt_proj"], s["dt_proj"] = dense_init(ks[3], dtr, d_in, None, TP, layout)
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], (d_in,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    p["dt_bias"] = (dt_init + jnp.log(-jnp.expm1(-dt_init))).astype(jnp.float32)
    s["dt_bias"] = (TP,)
    p["A_log"] = jnp.log(
        jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    )
    s["A_log"] = (TP, None)
    p["D"] = jnp.ones((d_in,), jnp.float32); s["D"] = (TP,)
    p["out_proj"], s["out_proj"] = dense_init(ks[5], d_in, d_model, TP, FSDP, layout)
    # Jamba normalizes dt/B/C (b_c_dt_rms)
    p["dt_norm"] = jnp.ones((dtr,), jnp.float32); s["dt_norm"] = (None,)
    p["b_norm"] = jnp.ones((N,), jnp.float32); s["b_norm"] = (None,)
    p["c_norm"] = jnp.ones((N,), jnp.float32); s["c_norm"] = (None,)
    return p, s


def _dt_b_c(p, cfg: SSMConfig, xc, eps=1e-5):
    """xc: [..., d_in] (post-conv). Returns dt [..., d_in], B,C [..., N]."""
    N = cfg.d_state
    dbl = xc @ p["x_proj"]
    dtr = dbl.shape[-1] - 2 * N
    dt_low, Bm, Cm = dbl[..., :dtr], dbl[..., dtr : dtr + N], dbl[..., dtr + N :]
    dt_low = rms_norm(dt_low, p["dt_norm"], eps)
    Bm = rms_norm(Bm, p["b_norm"], eps).astype(jnp.float32)
    Cm = rms_norm(Cm, p["c_norm"], eps).astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )
    return dt, Bm, Cm


def _causal_conv(p, cfg: SSMConfig, x, conv_state=None):
    """Depthwise causal conv along T. x: [B, T, d_in]."""
    K = cfg.d_conv
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1], :] * p["conv_w"][i]
    out = out + p["conv_b"]
    new_state = xp[:, -(K - 1) :, :] if K > 1 else None
    return jax.nn.silu(out), new_state


def ssm_apply(p, cfg: SSMConfig, x: jax.Array, return_state: bool = False):
    """Training/prefill. x: [B, T, D]. With ``return_state`` also returns
    (conv_state, h_final) for decode."""
    B, T, D = x.shape
    N = cfg.d_state
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard_hint(xin, DP, None, TP)
    xc, _ = _causal_conv(p, cfg, xin)
    conv_tail = xin[:, -(cfg.d_conv - 1) :, :] if cfg.d_conv > 1 else None
    dt, Bm, Cm = _dt_b_c(p, cfg, xc)
    A = -jnp.exp(p["A_log"])                                   # [d_in, N]

    L = min(cfg.chunk, T)
    assert T % L == 0, f"T={T} % chunk={L} != 0"
    nc = T // L
    sdt = jnp.dtype(cfg.scan_dtype)
    xcf = xc.astype(sdt)

    def seg(a):
        return a.reshape(B, nc, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    dt_c, B_c, C_c, x_c = seg(dt.astype(sdt)), seg(Bm), seg(Cm), seg(xcf)

    def chunk_body(h, inputs):
        dtc, Bc, Cc, xc_ = inputs                    # [B, L, ...]
        da = jnp.exp(dtc[..., :, None] * A).astype(sdt)   # [B, L, d_in, N]
        dbx = ((dtc * xc_)[..., :, None] * Bc[..., None, :]).astype(sdt)

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        da_s, dbx_s = jax.lax.associative_scan(comb, (da, dbx), axis=1)
        hs = da_s * h[:, None].astype(sdt) + dbx_s   # [B, L, d_in, N]
        y = jnp.einsum("blcn,bln->blc", hs, Cc.astype(sdt),
                       preferred_element_type=jnp.float32)
        return hs[:, -1].astype(sdt), y

    h0 = jnp.zeros((B, xc.shape[-1], N), sdt)
    h_fin, ys = jax.lax.scan(chunk_body, h0, (dt_c, B_c, C_c, x_c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, T, -1)
    y = y + xcf.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    y = shard_hint(y, DP, None, TP)
    out = y @ p["out_proj"]
    if return_state:
        return out, (conv_tail, h_fin)
    return out


def ssm_decode(p, cfg: SSMConfig, x, state):
    """x: [B, 1, D]; state = (conv_state [B, K-1, d_in], h [B, d_in, N])."""
    conv_state, h = state
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(p, cfg, xin, conv_state)
    dt, Bm, Cm = _dt_b_c(p, cfg, xc)
    A = -jnp.exp(p["A_log"])
    dt0, B0, C0, x0 = dt[:, 0], Bm[:, 0], Cm[:, 0], xc[:, 0].astype(jnp.float32)
    da = jnp.exp(dt0[..., None] * A)                           # [B, d_in, N]
    h_new = da * h + (dt0 * x0)[..., None] * B0[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h_new, C0) + x0 * p["D"]
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"], (new_conv, h_new)
