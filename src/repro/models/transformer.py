"""Model assembly: period-pattern layer stacks under ``lax.scan``.

A config's layer stack is ``pattern * n_periods + remainder``. All periods
share one traced body (compile time stays flat in depth); parameters are
stacked with a leading ``n_periods`` dim. Sublayer kinds:

    mixer: attn (global), local (sliding window), mamba, rwkv, attnx
           (self+cross, whisper decoder)
    ffn:   mlp (SwiGLU), moe, rwkv (channel-mix)

Three entry points per model: ``apply`` (train/prefill logits),
``prefill`` (logits + caches), ``decode_step`` (one token with caches).
Cross-entropy is computed in sequence chunks so the [B,S,V] fp32 logits
tensor never materializes (mistral-large/llama4 vocabs would be tens of
GB otherwise).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DP, FSDP, TP, shard_hint
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Layout,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_init,
    rms_norm,
    unembed_logits,
)

# ============================================================== sublayers
def _entry_init(key, entry: str, cfg: ModelConfig, layout: Layout):
    mixer, ffn = entry.split(":")
    kmix, kffn, kx = jax.random.split(key, 3)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["norm1"], s["norm1"] = norm_init(cfg.d_model, layout)
    p["norm2"], s["norm2"] = norm_init(cfg.d_model, layout)
    if mixer in ("attn", "local", "attnx"):
        p["mixer"], s["mixer"] = attn.attn_init(
            kmix, cfg.attention, cfg.d_model, layout, cfg.norm_eps
        )
        if mixer == "attnx":
            p["xnorm"], s["xnorm"] = norm_init(cfg.d_model, layout)
            p["xattn"], s["xattn"] = attn.attn_init(
                kx, cfg.attention, cfg.d_model, layout, cfg.norm_eps
            )
    elif mixer == "mamba":
        p["mixer"], s["mixer"] = ssm_mod.ssm_init(kmix, cfg.ssm, cfg.d_model, layout)
    elif mixer == "rwkv":
        p["mixer"], s["mixer"] = rwkv_mod.rwkv_block_init(
            kmix, cfg.rwkv, cfg.d_model, layout
        )
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if ffn == "mlp":
        p["ffn"], s["ffn"] = mlp_init(kffn, cfg.d_model, cfg.d_ff, layout)
    elif ffn == "moe":
        p["ffn"], s["ffn"] = moe_mod.moe_init(kffn, cfg.moe, cfg.d_model, layout)
    elif ffn == "rwkv":
        p["ffn"], s["ffn"] = rwkv_mod.rwkv_ffn_init(
            kffn, cfg.d_model, cfg.d_ff, layout
        )
    else:
        raise ValueError(f"unknown ffn {ffn!r}")
    return p, s


def _entry_apply(p, entry: str, cfg: ModelConfig, x, ctx) -> tuple[jax.Array, jax.Array]:
    """Pre-LN residual block. Returns (x, aux_loss)."""
    mixer, ffn = entry.split(":")
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer in ("attn", "local"):
        h = attn.attn_apply(
            p["mixer"], cfg.attention, h,
            local=(mixer == "local"), eps=cfg.norm_eps,
            positions=ctx.get("positions"),
        )
    elif mixer == "attnx":
        h = attn.attn_apply(
            p["mixer"], cfg.attention, h, local=False, eps=cfg.norm_eps,
            positions=ctx.get("positions"),
        )
        x = x + h
        hx = rms_norm(x, p["xnorm"], cfg.norm_eps)
        h = _cross_attn_apply(p["xattn"], cfg, hx, ctx["encoder_out"])
    elif mixer == "mamba":
        h = ssm_mod.ssm_apply(p["mixer"], cfg.ssm, h)
    elif mixer == "rwkv":
        h = rwkv_mod.rwkv_block_apply(p["mixer"], cfg.rwkv, h)
    x = x + h
    x = shard_hint(x, DP, None, None)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if ffn == "mlp":
        h = mlp_apply(p["ffn"], h, cfg.act)
    elif ffn == "moe":
        h, aux = moe_mod.moe_apply(p["ffn"], cfg.moe, h, cfg.act)
    elif ffn == "rwkv":
        h = rwkv_mod.rwkv_ffn_apply(p["ffn"], h)
    x = x + h
    return shard_hint(x, DP, None, None), aux


def _cross_attn_apply(p, cfg: ModelConfig, x, enc_out):
    """Cross-attention: queries from x, keys/values from encoder output.
    No RoPE on cross attention (whisper-style absolute positions)."""
    a = cfg.attention
    B, S, D = x.shape
    H, Hk, Dh = a.num_heads, a.num_kv_heads, a.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    k = (enc_out @ p["wk"]).reshape(B, enc_out.shape[1], Hk, Dh)
    v = (enc_out @ p["wv"]).reshape(B, enc_out.shape[1], Hk, Dh)
    o = attn.chunked_attention(
        q, k, v, causal=False, q_chunk=a.q_chunk, kv_chunk=a.kv_chunk
    )
    return o.reshape(B, S, H * Dh) @ p["wo"]


# -------------------------------------------------------------- caches
def _entry_cache_init(entry: str, cfg: ModelConfig, batch: int, cache_len: int,
                      dtype) -> dict:
    mixer, _ffn = entry.split(":")
    c: dict[str, Any] = {}
    if mixer in ("attn", "local", "attnx"):
        shape, dt = attn.attn_cache_shape(
            cfg.attention, batch, cache_len, mixer == "local", dtype
        )
        c["k"] = jnp.zeros(shape, dt)
        c["v"] = jnp.zeros(shape, dt)
        if mixer == "attnx":
            a = cfg.attention
            xl = cfg.encdec.cross_len_decode if cfg.encdec else 1500
            c["xk"] = jnp.zeros((batch, xl, a.num_kv_heads, a.head_dim), dt)
            c["xv"] = jnp.zeros((batch, xl, a.num_kv_heads, a.head_dim), dt)
    elif mixer == "mamba":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        c["conv"] = jnp.zeros((batch, s.d_conv - 1, d_in), dtype)
        c["h"] = jnp.zeros((batch, d_in, s.d_state), jnp.float32)
    elif mixer == "rwkv":
        r = cfg.rwkv
        H = cfg.d_model // r.head_size
        c["x_tm"] = jnp.zeros((batch, cfg.d_model), dtype)
        c["S"] = jnp.zeros((batch, H, r.head_size, r.head_size), jnp.float32)
    if entry.endswith(":rwkv"):
        c["x_cm"] = jnp.zeros((batch, cfg.d_model), dtype)
    return c


def _entry_decode(p, entry: str, cfg: ModelConfig, x, cache, lengths, ctx):
    """One-token step. x: [B,1,D]. Returns (x, new_cache)."""
    mixer, ffn = entry.split(":")
    new_cache = dict(cache)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer in ("attn", "local", "attnx"):
        h, nk, nv = attn.attn_decode(
            p["mixer"], cfg.attention, h, cache["k"], cache["v"], lengths,
            local=(mixer == "local"), eps=cfg.norm_eps,
        )
        new_cache["k"], new_cache["v"] = nk, nv
        if mixer == "attnx":
            x = x + h
            hx = rms_norm(x, p["xnorm"], cfg.norm_eps)
            h = _cross_decode(p["xattn"], cfg, hx, cache["xk"], cache["xv"])
    elif mixer == "mamba":
        h, (nc, nh) = ssm_mod.ssm_decode(
            p["mixer"], cfg.ssm, h, (cache["conv"], cache["h"])
        )
        new_cache["conv"], new_cache["h"] = nc, nh
    elif mixer == "rwkv":
        h, (nx, nS) = rwkv_mod.rwkv_block_decode(
            p["mixer"], cfg.rwkv, h, (cache["x_tm"], cache["S"])
        )
        new_cache["x_tm"], new_cache["S"] = nx, nS
    x = x + h
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if ffn == "mlp":
        h = mlp_apply(p["ffn"], h, cfg.act)
    elif ffn == "moe":
        h, _ = moe_mod.moe_apply(p["ffn"], cfg.moe, h, cfg.act)
    elif ffn == "rwkv":
        h, nx = rwkv_mod.rwkv_ffn_decode(p["ffn"], h, cache["x_cm"])
        new_cache["x_cm"] = nx
    return x + h, new_cache


def _entry_prefill(p, entry: str, cfg: ModelConfig, x, cache_len: int, ctx):
    """Like _entry_apply but also builds the decode cache for this entry."""
    mixer, ffn = entry.split(":")
    c: dict[str, Any] = {}
    B, S, D = x.shape
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer in ("attn", "local", "attnx"):
        a = cfg.attention
        positions = ctx.get("positions")
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        theta = a.rope_theta_local if mixer == "local" else a.rope_theta
        q, k, v = attn._project_qkv(p["mixer"], a, h, positions, theta, cfg.norm_eps)
        window = a.sliding_window if mixer == "local" else None
        o = attn.chunked_attention(
            q, k, v, causal=a.causal, window=window,
            q_chunk=a.q_chunk, kv_chunk=a.kv_chunk, softcap=a.logit_softcap,
        )
        h = o.reshape(B, S, -1) @ p["mixer"]["wo"]
        # build the cache
        if mixer == "local" and a.sliding_window and a.sliding_window < cache_len:
            W = a.sliding_window
            take = min(W, S)
            idx = (jnp.arange(S - take, S)) % W
            ck = jnp.zeros((B, W, a.num_kv_heads, a.head_dim), k.dtype)
            cv = jnp.zeros_like(ck)
            c["k"] = ck.at[:, idx].set(k[:, S - take:])
            c["v"] = cv.at[:, idx].set(v[:, S - take:])
        else:
            ck = jnp.zeros((B, cache_len, a.num_kv_heads, a.head_dim), k.dtype)
            cv = jnp.zeros_like(ck)
            c["k"] = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=1)
            c["v"] = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=1)
        if mixer == "attnx":
            x = x + h
            hx = rms_norm(x, p["xnorm"], cfg.norm_eps)
            enc = ctx["encoder_out"]
            h = _cross_attn_apply(p["xattn"], cfg, hx, enc)
            xk = (enc @ p["xattn"]["wk"]).reshape(
                B, enc.shape[1], a.num_kv_heads, a.head_dim
            )
            xv = (enc @ p["xattn"]["wv"]).reshape(
                B, enc.shape[1], a.num_kv_heads, a.head_dim
            )
            c["xk"], c["xv"] = xk, xv
    elif mixer == "mamba":
        h, (conv, hs) = ssm_mod.ssm_apply(p["mixer"], cfg.ssm, h, return_state=True)
        c["conv"], c["h"] = conv, hs
    elif mixer == "rwkv":
        h, (x_tm, S_fin) = rwkv_mod.rwkv_block_prefill(p["mixer"], cfg.rwkv, h)
        c["x_tm"], c["S"] = x_tm, S_fin
    x = x + h
    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if ffn == "mlp":
        h2o = mlp_apply(p["ffn"], h2, cfg.act)
    elif ffn == "moe":
        h2o, _ = moe_mod.moe_apply(p["ffn"], cfg.moe, h2, cfg.act)
    elif ffn == "rwkv":
        h2o = rwkv_mod.rwkv_ffn_apply(p["ffn"], h2)
        c["x_cm"] = h2[:, -1, :]
    return x + h2o, c


def _cross_decode(p, cfg: ModelConfig, x, xk, xv):
    a = cfg.attention
    B, _, D = x.shape
    q = (x @ p["wq"]).reshape(B, 1, a.num_heads, a.head_dim)
    o = attn.decode_attention(q, xk, xv, xk.shape[1])
    return o.reshape(B, 1, a.num_heads * a.head_dim) @ p["wo"]


# ============================================================== stacks
def _stack_init(key, entries: tuple[str, ...], n: int, cfg: ModelConfig,
                layout: Layout):
    """Stack each pattern position's params over n periods (leading dim)."""
    p, s = {}, {}
    for pos, entry in enumerate(entries):
        keys = jax.random.split(jax.random.fold_in(key, pos), n)
        p[f"pat{pos}"] = jax.vmap(
            lambda k, e=entry: _entry_init(k, e, cfg, layout)[0]
        )(keys)
        # specs are identical across periods: prepend the periods dim (None)
        spec_one = _entry_init(jax.random.PRNGKey(0), entry, cfg, layout)[1]
        s[f"pat{pos}"] = jax.tree.map(
            lambda sp: (None, *sp),
            spec_one,
            is_leaf=lambda v: isinstance(v, tuple) and all(
                a is None or isinstance(a, (str, tuple)) for a in v
            ),
        )
    return p, s


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


# ============================================================== the model
class LM:
    """Functional decoder-only (or encoder-decoder) language model."""

    @staticmethod
    def init(key, cfg: ModelConfig):
        layout = Layout.from_config(cfg)
        keys = jax.random.split(key, 8)
        p: dict[str, Any] = {}
        s: dict[str, Any] = {}
        p["embed"], s["embed"] = embed_init(
            keys[0], cfg.vocab_padded, cfg.d_model, layout
        )
        if not cfg.tie_embeddings:
            p["lm_head"], s["lm_head"] = dense_init(
                keys[1], cfg.d_model, cfg.vocab_padded, FSDP, TP, layout
            )
        p["final_norm"], s["final_norm"] = norm_init(cfg.d_model, layout)
        if cfg.n_periods > 0:
            p["stack"], s["stack"] = _stack_init(
                keys[2], cfg.pattern, cfg.n_periods, cfg, layout
            )
        for i, entry in enumerate(cfg.remainder):
            p[f"rem{i}"], s[f"rem{i}"] = _entry_init(
                jax.random.fold_in(keys[3], i), entry, cfg, layout
            )
        if cfg.encdec is not None:
            ed = cfg.encdec
            enc_entries = ("attn:mlp",) * ed.n_encoder_layers
            p["enc_stack"], s["enc_stack"] = _stack_init(
                keys[4], ("attn:mlp",), ed.n_encoder_layers, cfg, layout
            )
            p["enc_norm"], s["enc_norm"] = norm_init(cfg.d_model, layout)
            del enc_entries
        return p, s

    # ---------------------------------------------------------- embedding
    @staticmethod
    def embed_tokens(p, cfg: ModelConfig, tokens, embeds=None):
        layout = Layout.from_config(cfg)
        x = jnp.take(p["embed"], tokens, axis=0).astype(layout.compute_dtype)
        x = x * math.sqrt(cfg.d_model)
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(layout.compute_dtype), x], axis=1)
        return shard_hint(x, DP, None, None)

    # ---------------------------------------------------------- encoder
    @staticmethod
    def encode(p, cfg: ModelConfig, frames):
        """Bidirectional encoder over stub frame embeddings [B, S, D]."""
        layout = Layout.from_config(cfg)
        x = frames.astype(layout.compute_dtype)
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)
        cfg_enc = cfg.replace(
            attention=cfg.attention and
            _dc_replace(cfg.attention, causal=False)
        )
        ctx = {"positions": None, "encoder_out": None}

        def body(xc, params):
            y, _ = _entry_apply(params, "attn:mlp", cfg_enc, xc, ctx)
            return y, None

        if cfg.unroll_stack:
            wrapped = _remat_wrap(body, cfg)
            n_enc = cfg.encdec.n_encoder_layers
            for i in range(n_enc):
                x, _ = wrapped(x, _tree_index(p["enc_stack"]["pat0"], i))
        else:
            x, _ = jax.lax.scan(
                _remat_wrap(body, cfg), x, p["enc_stack"]["pat0"]
            )
        return rms_norm(x, p["enc_norm"], cfg.norm_eps)

    # ---------------------------------------------------------- forward
    @staticmethod
    def backbone(p, cfg: ModelConfig, x, encoder_out=None, positions=None):
        """Residual stream through the full layer stack. x: [B,S,D]."""
        if positions is None:
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        ctx = {"positions": positions, "encoder_out": encoder_out}
        aux_total = jnp.zeros((), jnp.float32)
        if cfg.n_periods > 0:
            def period_body(carry, params):
                xc, aux = carry
                for pos, entry in enumerate(cfg.pattern):
                    xc, a = _entry_apply(params[f"pat{pos}"], entry, cfg, xc, ctx)
                    aux = aux + a
                return (xc, aux), None

            if cfg.unroll_stack:
                body = _remat_wrap(period_body, cfg)
                for i in range(cfg.n_periods):
                    (x, aux_total), _ = body(
                        (x, aux_total), _tree_index(p["stack"], i)
                    )
            else:
                (x, aux_total), _ = jax.lax.scan(
                    _remat_wrap(period_body, cfg), (x, aux_total), p["stack"]
                )
        for i, entry in enumerate(cfg.remainder):
            x, a = _entry_apply(p[f"rem{i}"], entry, cfg, x, ctx)
            aux_total = aux_total + a
        return rms_norm(x, p["final_norm"], cfg.norm_eps), aux_total

    @staticmethod
    def apply(p, cfg: ModelConfig, tokens, *, embeds=None, encoder_frames=None,
              positions=None):
        """Full forward returning (final_hidden, aux). Call ``loss`` or
        ``logits`` on the hidden state."""
        enc = (
            LM.encode(p, cfg, encoder_frames) if encoder_frames is not None else None
        )
        x = LM.embed_tokens(p, cfg, tokens, embeds)
        return LM.backbone(p, cfg, x, encoder_out=enc, positions=positions)

    # ---------------------------------------------------------- loss
    @staticmethod
    def unembed_table(p, cfg: ModelConfig):
        return p["embed"] if cfg.tie_embeddings else p["lm_head"]

    @staticmethod
    def loss(p, cfg: ModelConfig, hidden, labels, mask=None, seq_chunk: int = 512):
        """Chunked CE over the sequence: [B,S,V] never materializes."""
        B, S, D = hidden.shape
        table = LM.unembed_table(p, cfg)
        nchunk = -(-S // seq_chunk)
        pad = nchunk * seq_chunk - S
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            m = jnp.pad(
                jnp.ones((B, S), jnp.float32) if mask is None else mask,
                ((0, 0), (0, pad)),
            )
        else:
            m = jnp.ones((B, S), jnp.float32) if mask is None else mask

        hs = hidden.reshape(B, nchunk, seq_chunk, D).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, nchunk, seq_chunk).transpose(1, 0, 2)
        ms = m.reshape(B, nchunk, seq_chunk).transpose(1, 0, 2)

        vmask = _vocab_pad_mask(cfg)

        def chunk_body(acc, inp):
            h, lab, mk = inp
            logits = unembed_logits(h, table) + vmask
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
            nll = (logz - gold) * mk
            return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mk)), None

        (tot, cnt), _ = jax.lax.scan(
            chunk_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hs, ls, ms),
        )
        return tot / jnp.maximum(cnt, 1.0)

    @staticmethod
    def logits(p, cfg: ModelConfig, hidden):
        return unembed_logits(hidden, LM.unembed_table(p, cfg)) + _vocab_pad_mask(cfg)

    # ---------------------------------------------------------- caches
    @staticmethod
    def init_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype):
        caches: dict[str, Any] = {}
        if cfg.n_periods > 0:
            def one(entry):
                return _entry_cache_init(entry, cfg, batch, cache_len, dtype)

            stack = {}
            for pos, entry in enumerate(cfg.pattern):
                c = one(entry)
                stack[f"pat{pos}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (cfg.n_periods, *a.shape)
                    ).copy(),
                    c,
                )
            caches["stack"] = stack
        for i, entry in enumerate(cfg.remainder):
            caches[f"rem{i}"] = _entry_cache_init(entry, cfg, batch, cache_len, dtype)
        return caches

    @staticmethod
    def prefill(p, cfg: ModelConfig, tokens, cache_len: int, *, embeds=None,
                encoder_frames=None):
        """Forward pass that also builds decode caches. Returns
        (last-position logits [B, V], caches, n_prefilled [B])."""
        enc = (
            LM.encode(p, cfg, encoder_frames) if encoder_frames is not None else None
        )
        x = LM.embed_tokens(p, cfg, tokens, embeds)
        S = x.shape[1]
        ctx = {"positions": jnp.arange(S, dtype=jnp.int32)[None, :],
               "encoder_out": enc}
        caches: dict[str, Any] = {}
        if cfg.n_periods > 0:
            def body(xc, params):
                cache = {}
                for pos, entry in enumerate(cfg.pattern):
                    xc, cache[f"pat{pos}"] = _entry_prefill(
                        params[f"pat{pos}"], entry, cfg, xc, cache_len, ctx
                    )
                return xc, cache

            if cfg.unroll_stack:
                cs = []
                for i in range(cfg.n_periods):
                    x, c = body(x, _tree_index(p["stack"], i))
                    cs.append(c)
                caches["stack"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *cs
                )
            else:
                x, stack_caches = jax.lax.scan(body, x, p["stack"])
                caches["stack"] = stack_caches
        for i, entry in enumerate(cfg.remainder):
            x, caches[f"rem{i}"] = _entry_prefill(
                p[f"rem{i}"], entry, cfg, x, cache_len, ctx
            )
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        logits = LM.logits(p, cfg, x[:, -1:])[:, 0]
        n = jnp.full((tokens.shape[0],), S, jnp.int32)
        return logits, caches, n

    @staticmethod
    def decode_step(p, cfg: ModelConfig, token, caches, lengths):
        """token: [B, 1] int32; lengths: [B] tokens already in cache.
        Returns (logits [B, V] fp32, new caches)."""
        x = LM.embed_tokens(p, cfg, token)
        ctx: dict[str, Any] = {}
        new_caches = dict(caches)
        if cfg.n_periods > 0:
            def body(xc, scanned):
                params, cache = scanned
                for pos, entry in enumerate(cfg.pattern):
                    xc, cache[f"pat{pos}"] = _entry_decode(
                        params[f"pat{pos}"], entry, cfg, xc,
                        cache[f"pat{pos}"], lengths, ctx,
                    )
                return xc, cache

            if cfg.unroll_stack:
                ncs = []
                for i in range(cfg.n_periods):
                    x, c = body(
                        x,
                        (_tree_index(p["stack"], i),
                         _tree_index(caches["stack"], i)),
                    )
                    ncs.append(c)
                new_caches["stack"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *ncs
                )
            else:
                x, new_stack = jax.lax.scan(
                    body, x, (p["stack"], caches["stack"])
                )
                new_caches["stack"] = new_stack
        for i, entry in enumerate(cfg.remainder):
            x, new_caches[f"rem{i}"] = _entry_decode(
                p[f"rem{i}"], entry, cfg, x, caches[f"rem{i}"], lengths, ctx
            )
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        logits = LM.logits(p, cfg, x)[:, 0]
        return logits, new_caches


# ------------------------------------------------------------------ misc
def _tree_index(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def _vocab_pad_mask(cfg: ModelConfig) -> jax.Array:
    """-inf additive mask over padded vocab rows (0 where real)."""
    Vp, V = cfg.vocab_padded, cfg.vocab_size
    if Vp == V:
        return jnp.zeros((Vp,), jnp.float32)
    return jnp.where(jnp.arange(Vp) >= V, -1e30, 0.0).astype(jnp.float32)


def _sinusoidal(S: int, D: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / D)
    pe = jnp.zeros((S, D), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe[None].astype(dtype)


def _dc_replace(obj, **kw):
    import dataclasses

    return dataclasses.replace(obj, **kw)
