"""Native optimizers (no optax): AdamW with configurable state dtype,
Adafactor (factored second moment) for the 100B+ models, LR schedules,
global-norm clipping, and optional gradient compression hooks.

Optimizer state shards exactly like the parameters (same PartitionSpecs),
which is what makes ZeRO-3-style FSDP work under pjit: XLA keeps m/v
distributed and the update is fully local.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ schedules
@dataclass(frozen=True)
class Schedule:
    base_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    kind: str = "cosine"            # cosine | linear | constant

    def __call__(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(self.warmup_steps, 1), 1.0)
        if self.kind == "constant":
            frac = jnp.ones(())
        else:
            t = jnp.clip(
                (step - self.warmup_steps)
                / jnp.maximum(self.decay_steps - self.warmup_steps, 1),
                0.0,
                1.0,
            )
            if self.kind == "cosine":
                frac = 0.5 * (1 + jnp.cos(jnp.pi * t))
            else:
                frac = 1.0 - t
        frac = self.min_lr_ratio + (1 - self.min_lr_ratio) * frac
        return self.base_lr * warm * frac


# ------------------------------------------------------------------ clipping
def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ------------------------------------------------------------------ AdamW
@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    state_dtype: str = "float32"     # bf16 for >=100B-param models
    schedule: Schedule = dataclasses.field(default_factory=Schedule)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_state_specs(param_specs):
    """Optimizer-state PartitionSpecs mirror the parameter specs."""
    return {
        "m": param_specs,
        "v": param_specs,
        "step": (),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cfg.schedule(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    sdt = jnp.dtype(cfg.state_dtype)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(sdt), vf.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# ------------------------------------------------------------------ Adafactor
@dataclass(frozen=True)
class AdafactorConfig:
    """Factored second moment: O(n+m) state for an n*m matrix — the
    memory-frugal option for 400B-class runs (beyond-paper extension)."""

    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    max_grad_norm: float = 1.0
    weight_decay: float = 0.0
    schedule: Schedule = dataclasses.field(default_factory=Schedule)


def adafactor_init(params, cfg: AdafactorConfig):
    def rows_cols(p):
        if p.ndim < 2:
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "vr": jnp.zeros(p.shape[:-1], jnp.float32),
            "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
        }

    return {
        "factored": jax.tree.map(rows_cols, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, cfg: AdafactorConfig):
    step = state["step"] + 1
    lr = cfg.schedule(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-cfg.decay)

    def upd(g, st, p):
        gf = g.astype(jnp.float32)
        g2 = jnp.square(gf) + cfg.eps
        if p.ndim < 2:
            v = st["v"] * beta + g2 * (1 - beta)
            u = gf / jnp.sqrt(v)
            new_st = {"v": v}
        else:
            vr = st["vr"] * beta + jnp.mean(g2, axis=-1) * (1 - beta)
            vc = st["vc"] * beta + jnp.mean(g2, axis=-2) * (1 - beta)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps)
                + cfg.eps
            )
            cfac = jax.lax.rsqrt(vc + cfg.eps)
            u = gf * rfac[..., None] * cfac[..., None, :]
            new_st = {"vr": vr, "vc": vc}
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if p.ndim >= 2 and cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["factored"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "factored": treedef.unflatten([o[1] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# ------------------------------------------------------------------ facade
@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"              # adamw | adafactor
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    adafactor: AdafactorConfig = dataclasses.field(default_factory=AdafactorConfig)


def make_optimizer(opt_cfg: OptimizerConfig):
    if opt_cfg.kind == "adamw":
        return (
            partial(adamw_init, cfg=opt_cfg.adamw),
            partial(adamw_update, cfg=opt_cfg.adamw),
        )
    if opt_cfg.kind == "adafactor":
        return (
            partial(adafactor_init, cfg=opt_cfg.adafactor),
            partial(adafactor_update, cfg=opt_cfg.adafactor),
        )
    raise ValueError(opt_cfg.kind)
