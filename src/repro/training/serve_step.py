"""Serving: prefill + decode steps and a batched greedy/sampling loop.

``make_serve_fns`` returns jit-able (prefill_fn, decode_fn); ``generate``
drives them for the runnable examples. The decode step is the function the
multi-pod dry-run lowers for ``decode_*`` / ``long_*`` shape cells.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import LM


def make_serve_fns(cfg: ModelConfig, cache_len: int):
    def prefill_fn(params, tokens, embeds=None, frames=None):
        return LM.prefill(
            params, cfg, tokens, cache_len, embeds=embeds, encoder_frames=frames
        )

    def decode_fn(params, token, caches, lengths):
        return LM.decode_step(params, cfg, token, caches, lengths)

    return prefill_fn, decode_fn


def sample_token(logits: jax.Array, key, temperature: float = 0.0) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


def generate(
    params,
    cfg: ModelConfig,
    prompt: jax.Array,            # [B, S] int32
    max_new_tokens: int,
    *,
    cache_len: int | None = None,
    temperature: float = 0.0,
    key=None,
    embeds=None,
    frames=None,
    jit: bool = True,
) -> jax.Array:
    """Batched autoregressive generation. Returns [B, max_new_tokens]."""
    B, S = prompt.shape
    cache_len = cache_len or (S + max_new_tokens)
    key = key if key is not None else jax.random.PRNGKey(0)
    prefill_fn, decode_fn = make_serve_fns(cfg, cache_len)
    if jit:
        prefill_fn = jax.jit(prefill_fn)
        decode_fn = jax.jit(decode_fn)

    logits, caches, lengths = prefill_fn(params, prompt, embeds, frames)
    tok = sample_token(logits, key, temperature)[:, None]
    outs = [tok]
    for i in range(max_new_tokens - 1):
        key = jax.random.fold_in(key, i)
        logits, caches = decode_fn(params, tok, caches, lengths)
        lengths = lengths + 1
        tok = sample_token(logits, key, temperature)[:, None]
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def decode_input_state(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    """Zero caches + mid-stream lengths: the structural input of one decode
    step with a cache of ``cache_len`` tokens (dry-run decode cells)."""
    caches = LM.init_caches(cfg, batch, cache_len, dtype)
    lengths = jnp.full((batch,), cache_len - 1, jnp.int32)
    token = jnp.zeros((batch, 1), jnp.int32)
    return token, caches, lengths
