"""Train-step factory: loss + grads (+ microbatch accumulation, gradient
compression hook) + optimizer update, as a single pjit-able function.

State layout:
    state = {"params": pytree, "opt": optimizer state, "step": i32}

The factory also produces the state's PartitionSpec tree (params and
optimizer state shard identically) so launchers can pjit with explicit
in/out shardings and checkpointing can reshard elastically.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import LM
from repro.training.optimizer import (
    OptimizerConfig,
    adamw_state_specs,
    make_optimizer,
)


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    microbatches: int = 1
    aux_loss_weight: float = 0.01
    seq_chunk_loss: int = 512
    compression: str = "none"        # none | bf16 | int8 (see distributed.compression)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        hidden, aux = LM.apply(
            params,
            cfg,
            batch["tokens"],
            embeds=batch.get("embeds"),
            encoder_frames=batch.get("frames"),
        )
        labels = batch["labels"]
        mask = batch.get("mask")
        if cfg.frontend_tokens:
            # frontend stub embeddings are prepended: no loss on them
            B = labels.shape[0]
            pad = jnp.zeros((B, cfg.frontend_tokens), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
            m = jnp.concatenate(
                [
                    jnp.zeros((B, cfg.frontend_tokens), jnp.float32),
                    jnp.ones(batch["labels"].shape, jnp.float32)
                    if mask is None
                    else mask,
                ],
                axis=1,
            )
            mask = m
        loss = LM.loss(params, cfg, hidden, labels, mask,
                       seq_chunk=tcfg.seq_chunk_loss)
        total = loss + tcfg.aux_loss_weight * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} % microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns (init_state_fn, train_step_fn, state_spec_fn)."""
    opt_init, opt_update = make_optimizer(tcfg.optimizer)
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if tcfg.compression != "none":
        from repro.distributed.compression import compress_decompress

        def grad_filter(g):
            return compress_decompress(g, tcfg.compression)
    else:
        def grad_filter(g):
            return g

    def init_state(key) -> dict:
        params, _ = LM.init(key, cfg)
        return {"params": params, "opt": opt_init(params), "step": jnp.zeros((), jnp.int32)}

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if tcfg.microbatches <= 1:
            (total, metrics), grads = grad_fn(params, batch)
        else:
            mb = _split_microbatches(batch, tcfg.microbatches)

            def acc_body(carry, mbatch):
                gacc, lacc = carry
                (tot, met), g = grad_fn(params, mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g
                )
                return (gacc, lacc + met["loss"]), met["aux_loss"]

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), auxes = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(
                lambda g, p: (g / tcfg.microbatches).astype(p.dtype), gsum, params
            )
            metrics = {
                "loss": lsum / tcfg.microbatches,
                "aux_loss": jnp.mean(auxes),
            }
            total = metrics["loss"]
        grads = grad_filter(grads)
        new_params, new_opt, om = opt_update(grads, state["opt"], params)
        metrics.update(om)
        metrics["total_loss"] = total
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, metrics

    def state_specs(param_specs) -> dict:
        if tcfg.optimizer.kind == "adamw":
            opt_specs = adamw_state_specs(param_specs)
        else:
            # adafactor: factored leaves drop the last/second-to-last dims;
            # replicate factored state (it is tiny relative to params)
            def fact(spec):
                return {"vr": spec[:-1], "vc": spec[:-2] + spec[-1:]} \
                    if isinstance(spec, tuple) and len(spec) >= 2 else {"v": spec}

            opt_specs = {
                "factored": jax.tree.map(
                    fact, param_specs,
                    is_leaf=lambda v: isinstance(v, tuple) and all(
                        a is None or isinstance(a, (str, tuple)) for a in v
                    ),
                ),
                "step": (),
            }
        return {"params": param_specs, "opt": opt_specs, "step": ()}

    return init_state, train_step, state_specs


def make_state_shardings(mesh, state_specs):
    """NamedSharding tree from a state PartitionSpec-tuple tree — the
    plumbing from ``state_specs(...)`` to the checkpoint manager's
    addressable-shard save: place the state with these shardings and
    ``CheckpointManager.save`` writes each shard exactly once per
    cluster (each host serializes only its ``replica_id == 0`` shards),
    and ``restore(..., shardings=...)`` reshards elastically."""
    from jax.sharding import NamedSharding, PartitionSpec

    def is_spec(v):
        return isinstance(v, tuple) and all(
            a is None or isinstance(a, (str, tuple)) for a in v
        )

    return jax.tree.map(
        lambda spec: NamedSharding(mesh, PartitionSpec(*spec)),
        state_specs,
        is_leaf=is_spec,
    )
