"""Minimal stand-in for `hypothesis` so tier-1 collection works without it.

Property tests decorated with the stub's ``given`` are *skipped* (cleanly,
with a reason) instead of breaking collection of the whole module — the
non-property tests in the same file still run. When the real `hypothesis`
is installed (e.g. in CI), the stub is never imported.
"""

from __future__ import annotations

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _Strategy:
    """Opaque placeholder: strategies are never drawn from (the test is
    skipped before it runs), they only need to be constructible."""

    def __init__(self, name):
        self.name = name

    def __call__(self, *a, **kw):
        return self

    def __getattr__(self, name):
        return _Strategy(f"{self.name}.{name}")

    def __repr__(self):  # pragma: no cover
        return f"<stub strategy {self.name}>"


class _StrategiesModule:
    def __getattr__(self, name):
        return _Strategy(name)


strategies = _StrategiesModule()
st = strategies
