"""Shared test plumbing.

Ensures the tests directory itself is importable so test modules can fall
back to the local ``_hypothesis_stub`` when `hypothesis` is not installed
(the container's tier-1 environment does not ship it).
"""

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)
