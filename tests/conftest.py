"""Shared test plumbing.

Ensures the tests directory itself is importable so test modules can fall
back to the local ``_hypothesis_stub`` when `hypothesis` is not installed
(the container's tier-1 environment does not ship it), and — under
``SEACHECK=1`` — arms the seacheck runtime lock-order detector *before*
any test module imports ``repro`` (dataclass ``default_factory=
threading.Lock`` binds the factory at class-creation time, so the patch
must win that race).
"""

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

_TOOLS = os.path.join(os.path.dirname(_HERE), "tools")
if _TOOLS not in sys.path:
    sys.path.insert(0, _TOOLS)

if os.environ.get("SEACHECK") == "1":
    from seacheck import runtime as _seacheck_runtime

    _seacheck_runtime.install()
    # adopt the plugin's per-test drain fixture + session-end sweep
    from seacheck.pytest_plugin import (  # noqa: F401
        _seacheck_findings_guard,
        pytest_sessionfinish,
    )
