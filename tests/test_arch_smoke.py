"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED config of its family and runs one forward + one train step + one
decode step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.archs import reduced
from repro.configs.base import get_config, list_archs
from repro.models.transformer import LM
from repro.training.optimizer import AdamWConfig, OptimizerConfig, Schedule
from repro.training.serve_step import decode_input_state, generate
from repro.training.train_step import TrainConfig, make_train_step

ARCHS = list_archs()


def _batch_for(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(7)
    batch = {}
    n_text = S
    if cfg.frontend_tokens:
        n_text = S - cfg.frontend_tokens
        batch["embeds"] = (
            jax.random.normal(key, (B, cfg.frontend_tokens, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    if cfg.encdec is not None:
        batch["frames"] = (
            jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
        n_text = max(S // cfg.encdec.decoder_seq_divisor, 8)
    toks = jax.random.randint(key, (B, n_text), 0, cfg.vocab_size)
    batch["tokens"] = toks
    batch["labels"] = jnp.roll(toks, -1, axis=1)
    return batch


@pytest.fixture(scope="module")
def tcfg():
    return TrainConfig(
        optimizer=OptimizerConfig(
            kind="adamw",
            adamw=AdamWConfig(schedule=Schedule(base_lr=1e-3, warmup_steps=2,
                                                decay_steps=10)),
        ),
        seq_chunk_loss=16,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.n_layers == cfg.n_periods * len(cfg.pattern) + len(cfg.remainder)
    assert cfg.param_count() > 0
    if cfg.moe is not None:
        # EP divisibility over the 16-way model axis
        assert (cfg.moe.num_experts + cfg.moe.padded_experts) % 16 == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, tcfg):
    cfg = reduced(get_config(arch))
    init_state, train_step, state_specs = make_train_step(cfg, tcfg)
    state = init_state(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    state2, metrics = jax.jit(train_step)(state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(metrics["loss"]), (arch, metrics)
    assert loss > 0
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(state2["params"])[0]
    assert not jnp.allclose(p0, p1)
    # second step decreases loss on the same batch (sanity of the update)
    state3, metrics2 = jax.jit(train_step)(state2, batch)
    assert jnp.isfinite(metrics2["loss"])


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduced(get_config(arch))
    params, _ = LM.init(jax.random.PRNGKey(0), cfg)
    B, cache_len = 2, 64
    token, caches, lengths = decode_input_state(cfg, B, cache_len, jnp.bfloat16)
    logits, new_caches = jax.jit(
        lambda p, t, c, l: LM.decode_step(p, cfg, t, c, l)
    )(params, token, caches, lengths)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    # cache trees keep their structure
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-7b", "jamba-v0.1-52b"])
def test_generate_matches_prefill_then_decode(arch):
    """Greedy generation runs end to end and produces tokens in range."""
    cfg = reduced(get_config(arch))
    params, _ = LM.init(jax.random.PRNGKey(1), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_decode_consistency_with_forward():
    """Decode steps reproduce the full-forward logits step by step (the
    cache path is numerically consistent with the training path)."""
    cfg = reduced(get_config("granite-3-2b"))
    params, _ = LM.init(jax.random.PRNGKey(3), cfg)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)
    hidden, _ = LM.apply(params, cfg, toks)
    full_logits = LM.logits(params, cfg, hidden)  # [B, S, V]

    caches = LM.init_caches(cfg, B, S, jnp.bfloat16)
    lengths = jnp.zeros((B,), jnp.int32)
    step_logits = []
    for t in range(S):
        lg, caches = LM.decode_step(params, cfg, toks[:, t:t + 1], caches, lengths)
        lengths = lengths + 1
        step_logits.append(lg)
    step_logits = jnp.stack(step_logits, axis=1)
    # bf16 params, fp32 logits: tolerances sized for accumulation-order diffs
    assert jnp.allclose(full_logits, step_logits, atol=0.15, rtol=0.05), (
        jnp.max(jnp.abs(full_logits - step_logits))
    )


def test_decode_consistency_rwkv():
    cfg = reduced(get_config("rwkv6-7b"))
    params, _ = LM.init(jax.random.PRNGKey(5), cfg)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0, cfg.vocab_size)
    hidden, _ = LM.apply(params, cfg, toks)
    full_logits = LM.logits(params, cfg, hidden)
    caches = LM.init_caches(cfg, B, S, jnp.bfloat16)
    lengths = jnp.zeros((B,), jnp.int32)
    outs = []
    for t in range(S):
        lg, caches = LM.decode_step(params, cfg, toks[:, t:t + 1], caches, lengths)
        lengths = lengths + 1
        outs.append(lg)
    step_logits = jnp.stack(outs, axis=1)
    assert jnp.allclose(full_logits, step_logits, atol=0.15, rtol=0.05), (
        jnp.max(jnp.abs(full_logits - step_logits))
    )


def test_prefill_matches_decode_chain():
    """prefill(S tokens) == S decode steps (same final logits + caches work)."""
    cfg = reduced(get_config("qwen3-4b"))
    params, _ = LM.init(jax.random.PRNGKey(8), cfg)
    B, S, cache_len = 1, 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, cfg.vocab_size)
    pf_logits, pf_caches, n = LM.prefill(params, cfg, toks, cache_len)
    caches = LM.init_caches(cfg, B, cache_len, jnp.bfloat16)
    lengths = jnp.zeros((B,), jnp.int32)
    for t in range(S):
        lg, caches = LM.decode_step(params, cfg, toks[:, t:t + 1], caches, lengths)
        lengths = lengths + 1
    assert jnp.allclose(pf_logits, lg, atol=0.15, rtol=0.05)
    assert int(n[0]) == S
