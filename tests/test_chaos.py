"""Chaos suite for the failure-domain layer (ISSUE 10).

Covers the unified errno classification, the fault-injection plane's spec
grammar and seeded determinism, the per-root circuit breaker state machine,
and the end-to-end degradation contracts: a cache root killed mid-workload
(EIO and hung-I/O variants) must leave every read byte-exact, keep opens
succeeding via other roots/peers/base, release reservations on deadline
aborts, and re-admit the root through a half-open probe after recovery.

Seeded reruns: set SEA_CHAOS_SEED to reproduce a CI leg (conftest-free —
each test derives its schedule from the printed seed).
"""

import errno
import io
import os
import random
import threading
import time

import pytest

from repro.core import Sea, SeaConfig, TierSpec
from repro.core import faults
from repro.core.faults import CAPACITY, PERMANENT, TRANSIENT, FaultPlane, classify
from repro.core.health import CLOSED, HALF_OPEN, OPEN, HealthTracker
from repro.core.ledger import scan_root
from repro.core.transfer import TransferDeadlineError

#: randomized-but-printed seed: CI exports SEA_CHAOS_SEED so a failing leg
#: reruns bit-identically (`SEA_CHAOS_SEED=<printed> pytest tests/test_chaos.py`)
CHAOS_SEED = int(os.environ.get("SEA_CHAOS_SEED", "0") or "0") or random.SystemRandom().randrange(1 << 30)
print(f"sea-chaos: SEA_CHAOS_SEED={CHAOS_SEED}")


@pytest.fixture(autouse=True)
def _no_leaked_plane():
    """The fault plane is process-global: never leak one across tests."""
    faults.deactivate()
    yield
    faults.deactivate()


def make_sea(tmp_path, *, roots=("c0",), **kw):
    cfg = SeaConfig(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(
                name="cache", roots=tuple(str(tmp_path / r) for r in roots)
            ),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 16,
        n_procs=1,
        # fast breaker so chaos tests settle in milliseconds, not 30s
        health_window_s=5.0,
        health_min_events=4,
        health_error_threshold=0.5,
        health_open_s=0.2,
        **kw,
    )
    return Sea(cfg)


# ------------------------------------------------------------ classification
def test_classify_table():
    assert classify(OSError(errno.ENOSPC, "")) is CAPACITY
    assert classify(OSError(errno.EDQUOT, "")) is CAPACITY
    assert classify(OSError(errno.EACCES, "")) is PERMANENT
    assert classify(OSError(errno.EISDIR, "")) is PERMANENT
    assert classify(OSError(errno.EIO, "")) is TRANSIENT
    assert classify(ValueError("no errno")) is TRANSIENT
    assert classify(IOError("errno-less IOError")) is TRANSIENT


# ------------------------------------------------------------ fault plane
def test_fault_spec_parsing():
    p = FaultPlane.from_spec(
        "transfer.chunk:errno=EIO,p=0.5,n=3;"
        "seafs.open:delay=0.01,path=*/c0/*;"
        "flusher.flush:torn;"
        "shared_ledger.append:errno=5,after=2"
    )
    actions = [(r.site, r.action) for r in p.rules]
    assert actions == [
        ("transfer.chunk", "errno"),
        ("seafs.open", "delay"),
        ("flusher.flush", "torn"),
        ("shared_ledger.append", "errno"),
    ]
    assert p.rules[0].errno == errno.EIO and p.rules[0].limit == 3
    assert p.rules[1].path_glob == "*/c0/*"
    assert p.rules[3].errno == 5 and p.rules[3].after == 2
    with pytest.raises(ValueError):
        FaultPlane.from_spec("transfer.chunk")  # no action
    with pytest.raises(ValueError):
        FaultPlane.from_spec("transfer.chunk:bogus=1")


def test_fault_schedule_is_seed_deterministic():
    def schedule(seed):
        p = FaultPlane.from_spec("site:errno=EIO,p=0.5", seed=seed)
        out = []
        for _ in range(64):
            try:
                p.fire("site")
                out.append(0)
            except OSError:
                out.append(1)
        return out

    a, b = schedule(CHAOS_SEED), schedule(CHAOS_SEED)
    assert a == b, "same seed must replay the same schedule"
    assert 0 < sum(a) < 64, "p=0.5 should fire sometimes, not always"
    assert schedule(CHAOS_SEED + 1) != a or schedule(CHAOS_SEED + 2) != a


def test_fault_limit_after_and_path_filter(tmp_path):
    p = FaultPlane.from_spec("s:errno=EIO,n=2,after=1,path=*/bad/*")
    faults.activate(p)
    # path filter: non-matching paths never fire
    for _ in range(8):
        faults.fire("s", path="/ok/x")
    # hit 1 skipped (after=1), hits 2-3 fire (n=2), then disarmed
    fired = 0
    for _ in range(8):
        try:
            faults.fire("s", path="/bad/x")
        except OSError:
            fired += 1
    assert fired == 2


def test_fault_delay_is_cancel_aware():
    faults.activate(FaultPlane.from_spec("s:delay=30"))
    cancel = threading.Event()
    t0 = time.monotonic()
    threading.Timer(0.05, cancel.set).start()
    faults.fire("s", cancel=cancel)
    assert time.monotonic() - t0 < 5, "cancel event must unblock the hang"


# ------------------------------------------------------------ breaker unit
def test_breaker_state_machine():
    ht = HealthTracker(min_events=4, error_threshold=0.5, open_s=0.1)
    r = "/r0"
    # below min_events: failures alone never open
    ht.record_failure(r, OSError(errno.EIO, ""))
    ht.record_failure(r, OSError(errno.EIO, ""))
    assert ht.breaker_state(r) == CLOSED and ht.allow(r)
    # 4th event at 75% error rate opens
    ht.record_success(r)
    ht.record_failure(r, OSError(errno.EIO, ""))
    assert ht.breaker_state(r) == OPEN
    assert not ht.allow(r) and ht.quarantined(r)
    # open_s elapsed: exactly one probe admitted (half-open)
    time.sleep(0.12)
    assert ht.allow(r)
    assert ht.breaker_state(r) == HALF_OPEN
    assert not ht.allow(r), "only one outstanding probe"
    # probe success closes and clears the window
    ht.record_success(r)
    assert ht.breaker_state(r) == CLOSED and ht.allow(r)
    snap = ht.snapshot()[r]
    assert snap["state"] == CLOSED and snap["events"] <= 1


def test_breaker_capacity_trips_instantly_and_halfopen_failure_reopens():
    ht = HealthTracker(min_events=100, open_s=0.05)
    r = "/r0"
    ht.record_failure(r, OSError(errno.ENOSPC, ""))  # one event, way below min
    assert ht.breaker_state(r) == OPEN
    time.sleep(0.07)
    assert ht.allow(r)  # half-open probe
    ht.record_failure(r, OSError(errno.EIO, ""))  # probe failed
    assert ht.breaker_state(r) == OPEN
    assert not ht.allow(r)


def test_breaker_stale_probe_claim_expires():
    ht = HealthTracker(open_s=0.05)
    r = "/r0"
    ht.trip(r)
    time.sleep(0.07)
    assert ht.allow(r)  # probe claimed... and the prober dies silently
    assert not ht.allow(r)
    time.sleep(0.07)
    assert ht.allow(r), "a crashed prober must not wedge re-admission"


def test_breaker_telemetry_counters():
    from repro.core.telemetry import Telemetry

    t = Telemetry()
    ht = HealthTracker(open_s=0.02, telemetry=t)
    ht.trip("/r0")
    assert t.breaker_opens == 1 and t.root_quarantines == 1
    time.sleep(0.03)
    assert ht.allow("/r0")
    ht.record_failure("/r0", OSError(errno.EIO, ""))  # half-open re-open
    assert t.breaker_opens == 2
    assert t.root_quarantines == 1, "re-opening is not a NEW quarantine"


# ------------------------------------------------------------ e2e: EIO root
def test_eio_killed_root_degrades_and_readmits(tmp_path):
    sea = make_sea(tmp_path)
    fs = sea.fs
    c0 = str(tmp_path / "c0")
    try:
        payloads = {}
        for i in range(6):
            p = os.path.join(fs.mount, f"f{i}.bin")
            payloads[p] = bytes([i]) * (512 + i)
            with fs.open(p, "wb") as f:
                f.write(payloads[p])
            fs.persist(p)  # a base replica exists: degradation has a target
        # reads must route through the (about to die) cache replica, not
        # the location persist just noted
        fs.resolver.invalidate_all()
        # kill c0: every open of a real under it raises EIO
        faults.activate(FaultPlane.from_spec(f"seafs.open:errno=EIO,path={c0}/*"))
        for p, want in payloads.items():
            with fs.open(p, "rb") as f:
                assert f.read() == want, "degraded read must stay byte-exact"
            fs.resolver.invalidate(fs.key_of(p))  # next read re-hits c0 too
        snap = fs.telemetry.snapshot()
        assert snap["degraded_reads"] >= 6
        # the failure feed opened the breaker: new writes avoid the dead root
        assert fs.health.breaker_state(c0) == OPEN
        for i in range(4):
            p = os.path.join(fs.mount, f"g{i}.bin")
            with fs.open(p, "wb") as f:
                f.write(b"z" * 64)
            with fs.open(p, "rb") as f:
                assert f.read() == b"z" * 64
            assert not os.path.exists(os.path.join(c0, f"g{i}.bin"))
        # recovery: lift the fault, wait out open_s — a half-open probe
        # write re-admits the root
        faults.deactivate()
        deadline = time.time() + 10
        while fs.health.breaker_state(c0) != CLOSED and time.time() < deadline:
            time.sleep(fs.config.health_open_s / 2)
            q = os.path.join(fs.mount, f"probe{time.monotonic_ns()}.bin")
            with fs.open(q, "wb") as f:
                f.write(b"p" * 32)
        assert fs.health.breaker_state(c0) == CLOSED, "root must re-admit"
        # and new writes land on the recovered root again
        p = os.path.join(fs.mount, "recovered.bin")
        with fs.open(p, "wb") as f:
            f.write(b"r" * 64)
        assert os.path.exists(os.path.join(c0, "recovered.bin"))
    finally:
        sea.shutdown()


# ------------------------------------------------------------ e2e: hung I/O
def test_hung_write_aborts_within_deadline_and_releases_reservation(tmp_path):
    sea = make_sea(tmp_path, transfer_deadline_s=0.25)
    fs = sea.fs
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    src = str(tmp_path / "pfs" / "hung.bin")
    with open(src, "wb") as f:
        f.write(b"h" * 4096)
    free_before = tier.free_bytes(root)
    faults.activate(FaultPlane.from_spec("transfer.chunk:delay=60,n=1"))
    t0 = time.monotonic()
    try:
        with pytest.raises(TransferDeadlineError) as ei:
            fs.transfer.copy(
                src,
                os.path.join(root, "hung.bin"),
                src_tier=fs.hierarchy.base,
                dst_tier=tier,
                dst_root=root,
                key="hung.bin",
                admit="require",
            )
        elapsed = time.monotonic() - t0
        assert elapsed < 10, f"hung copy must abort cooperatively ({elapsed:.1f}s)"
        assert ei.value.errno == errno.ETIMEDOUT
        snap = fs.telemetry.snapshot()
        assert snap["deadline_aborts"] == 1
        assert fs.health.breaker_state(root) == OPEN, "deadline trips the breaker"
        assert tier.reserved_bytes(root) == 0, "aborted copy must release its budget"
        assert tier.free_bytes(root) == free_before
        assert not os.path.exists(os.path.join(root, "hung.bin"))
        residue = [n for n in os.listdir(root) if ".sea_tmp" in n]
        assert residue == [], residue
    finally:
        sea.shutdown()


# ------------------------------------------------------ e2e: ENOSPC per root
@pytest.mark.parametrize("bad", ["c0", "c1"])
def test_enospc_mid_write_degrades_on_each_root(tmp_path, bad):
    sea = make_sea(tmp_path, roots=("c0", "c1"))
    fs = sea.fs
    badroot = str(tmp_path / bad)
    want = b"A" * 300 + b"B" * 300 + b"C" * 300
    try:
        faults.activate(
            FaultPlane.from_spec(f"seafs.write:errno=ENOSPC,path={badroot}/*")
        )
        # keep writing until a write actually started on the bad root
        # (placement shuffles roots), then every later write avoids it
        for i in range(12):
            p = os.path.join(fs.mount, f"e{i}.bin")
            with fs.open(p, "wb") as f:
                f.write(want[:300])
                f.write(want[300:600])
                f.write(want[600:])
            with fs.open(p, "rb") as f:
                assert f.read() == want, f"{p} must stay byte-exact"
        assert fs.health.breaker_state(badroot) == OPEN
        faults.deactivate()
        # ledger matches a walk on every root (no phantom/missing bytes)
        for tier in fs.hierarchy.tiers:
            if tier.ledger is None:
                continue
            for r in tier.roots:
                walked = sum(scan_root(r).values())
                assert tier.used_bytes(r) == walked, (r, tier.used_bytes(r), walked)
                assert tier.reserved_bytes(r) == 0
        # no torn staging residue anywhere
        for r, _, names in os.walk(tmp_path):
            for n in names:
                assert not n.endswith((".sea_part", ".sea_tmp")), os.path.join(r, n)
    finally:
        sea.shutdown()


# ------------------------------------------- relocation: partial raw write
class _PartialFullRaw(io.RawIOBase):
    """Raw writer that lands a prefix of a large write on disk and then
    raises ENOSPC — what a filling device does to a BufferedWriter whose
    big write bypasses the buffer. Post-failure tell() counts the landed
    prefix, so relocation trusting it would duplicate those bytes."""

    def __init__(self, path, fire_at, partial):
        super().__init__()
        self._f = open(path, "wb", buffering=0)
        self._fire_at = fire_at
        self._partial = partial
        self.fired = False

    def writable(self):
        return True

    def seekable(self):
        return True

    def write(self, b):
        b = bytes(b)
        if not self.fired and len(b) >= self._fire_at:
            self.fired = True
            self._f.write(b[: self._partial])
            raise OSError(errno.ENOSPC, "device full (injected, partial)")
        return self._f.write(b)

    def seek(self, pos, whence=0):
        return self._f.seek(pos, whence)

    def tell(self):
        return self._f.tell()

    def fileno(self):
        return self._f.fileno()

    def close(self):
        if not self.closed:
            self._f.close()
        super().close()


def test_enospc_partial_direct_write_relocates_without_duplication(tmp_path):
    """REVIEW regression: ENOSPC striking after k bytes of a big buffered
    write already reached the raw fd must not duplicate those k bytes
    when the handle migrates — the relocated file rewinds to the
    pre-write position, not to post-failure tell()."""
    sea = make_sea(tmp_path, roots=("c0", "c1"))
    fs = sea.fs
    prefix, big = b"x" * 10, b"D" * 100
    try:
        p = os.path.join(fs.mount, "dup.bin")
        f = fs.open(p, "wb")
        old_real = f._real
        f._raw.close()  # swap in a raw layer that fails like a full disk
        f._raw = io.BufferedWriter(
            _PartialFullRaw(old_real, fire_at=64, partial=7), buffer_size=16
        )
        assert f.write(prefix) == len(prefix)  # sits in the buffer
        # big write: buffer flushes (10B), then 7B of `big` land on the
        # raw fd before ENOSPC -> handle must relocate and keep going
        assert f.write(big) == len(big)
        assert f._real != old_real, "handle must have migrated"
        f.close()
        with fs.open(p, "rb") as g:
            got = g.read()
        assert got == prefix + big, (
            f"relocated write duplicated the partially-landed prefix: "
            f"len={len(got)}, want={len(prefix + big)}"
        )
    finally:
        sea.shutdown()


# ---------------------------------------------- enumeration vs. probe claim
def test_admissible_is_pure_and_allow_still_claims():
    ht = HealthTracker(open_s=0.05)
    r = "/r0"
    ht.trip(r)
    assert not ht.admissible(r)
    time.sleep(0.07)
    for _ in range(10):
        assert ht.admissible(r), "enumeration must be repeatable (no claim)"
    assert ht.breaker_state(r) == OPEN, "pure queries must not transition"
    assert ht.allow(r), "the actual claim still gets the probe slot"
    assert ht.breaker_state(r) == HALF_OPEN
    assert not ht.admissible(r), "a fresh outstanding probe filters the root"
    time.sleep(0.07)
    assert ht.admissible(r), "a stale probe claim re-opens enumeration"


def test_enumeration_does_not_starve_halfopen_readmission(tmp_path):
    """REVIEW regression: placement/spill eligibility queries used to call
    allow(), consuming the single half-open probe slot without doing any
    I/O — starving a recovered root's re-admission indefinitely."""
    sea = make_sea(tmp_path)
    fs = sea.fs
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    try:
        fs.health.trip(root)
        time.sleep(fs.config.health_open_s + 0.05)
        for _ in range(25):  # placement queries / spill checks
            fs.policy.eligible_roots(tier)
        assert fs.health.breaker_state(root) == OPEN, (
            "eligibility enumeration must not consume the probe slot"
        )
        p = os.path.join(fs.mount, "probe.bin")
        with fs.open(p, "wb") as f:
            f.write(b"p" * 32)
        assert fs.health.breaker_state(root) == CLOSED, (
            "the first real write claims the probe and re-admits the root"
        )
    finally:
        sea.shutdown()


# --------------------------------------------------- watchdog thread hygiene
def test_idle_watchdog_thread_exits_and_respawns(tmp_path):
    """REVIEW regression: the deadline watchdog used to spin for the life
    of the process once armed — it must exit when nothing is in flight
    and respawn lazily for the next armed copy."""
    sea = make_sea(tmp_path, transfer_deadline_s=0.2)
    fs = sea.fs
    try:
        src = str(tmp_path / "pfs" / "w.bin")
        with open(src, "wb") as f:
            f.write(b"w" * 1024)
        for i in range(2):
            fs.transfer.copy(src, str(tmp_path / "pfs" / f"w{i}.out"))
            deadline = time.monotonic() + 5
            while (
                fs.transfer._watch_thread is not None
                and time.monotonic() < deadline
            ):
                time.sleep(0.02)
            assert fs.transfer._watch_thread is None, (
                "watchdog must exit once no copies are in flight"
            )
    finally:
        sea.shutdown()


# ------------------------------------------------ extent stalls feed breaker
def test_range_deadline_abort_trips_destination_breaker(tmp_path):
    """REVIEW regression: a deadline abort on an extent/range copy used to
    pass root=None, so extent stalls never quarantined the destination
    root the way whole-file stalls do."""
    sea = make_sea(tmp_path, transfer_deadline_s=0.25, transfer_chunk_bytes=2048)
    fs = sea.fs
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    src = str(tmp_path / "pfs" / "ext.bin")
    with open(src, "wb") as f:
        f.write(b"e" * 8192)
    dst = os.path.join(root, "ext.partfile")
    with open(dst, "wb") as f:
        f.truncate(8192)
    faults.activate(FaultPlane.from_spec("transfer.range_chunk:delay=60,n=1"))
    try:
        with pytest.raises(TransferDeadlineError):
            fs.transfer.copy_range(
                src,
                dst,
                0,
                8192,
                src_tier=fs.hierarchy.base,
                dst_tier=tier,
                dst_root=root,
            )
        assert fs.health.breaker_state(root) == OPEN, (
            "an extent-stage stall must trip the destination root's breaker"
        )
        assert fs.telemetry.snapshot()["deadline_aborts"] >= 1
    finally:
        sea.shutdown()


# ------------------------------------------------------------ flusher fixes
def test_flusher_retries_all_eligible_failed_keys(tmp_path):
    sea = make_sea(tmp_path)
    fl = sea.flusher
    try:
        resubmitted = []
        fl.submit = lambda key: resubmitted.append(key)  # record, don't flush
        now = time.monotonic()
        with fl._cv:
            fl._failed.update(
                {"a": now - 1, "b": now - 1, "c": now - 1, "later": now + 60}
            )
        fl._maybe_retry_failed()
        assert sorted(resubmitted) == ["a", "b", "c"], (
            "a recovered tier must drain the whole backlog in one tick"
        )
        with fl._cv:
            assert set(fl._failed) == {"later"}, "unexpired backoffs stay parked"
    finally:
        sea.shutdown()


class _HungThread:
    name = "sea-fake-hung"

    def join(self, timeout=None):
        pass  # "times out" instantly

    def is_alive(self):
        return True

    def start(self):
        pass


def test_hung_thread_joins_counted_on_stop(tmp_path, capsys):
    sea = make_sea(tmp_path)
    fs = sea.fs
    try:
        fl = sea.flusher
        fl.stop()  # settle the real workers first
        fl._threads = [_HungThread()]
        fl._q.put(None)
        fl.stop()
        assert fs.telemetry.hung_thread_joins == 1
        fs.prefetcher._thread = _HungThread()
        fs.prefetcher.stop()
        assert fs.telemetry.hung_thread_joins == 2
        err = capsys.readouterr().err
        assert "still alive" in err
    finally:
        sea.shutdown()


# ------------------------------------------------------------ config plumbing
def test_config_activates_fault_plane(tmp_path):
    sea = make_sea(
        tmp_path, faults="transfer.chunk:errno=EIO,p=0.0", fault_seed=CHAOS_SEED
    )
    try:
        plane = faults.active_plane()
        assert plane is not None and plane.seed == CHAOS_SEED
        assert [r.site for r in plane.rules] == ["transfer.chunk"]
    finally:
        sea.shutdown()
