"""Burst-buffer checkpoint manager: roundtrip, atomicity, corruption
fallback, GC, elastic restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint import serialization as ser
from repro.core import Sea, SeaConfig, TierSpec


def make_sea(tmp_path, **kw):
    cfg = SeaConfig(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 22,
        n_procs=1,
        flushlist=("checkpoints/*/*",),
        evictlist=("checkpoints/*/*",),
        **kw,
    )
    return Sea(cfg)


def state_tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {
            "w": jax.random.normal(k, (16, 32)).astype(jnp.bfloat16),
            "b": jnp.zeros((32,), jnp.float32),
        },
        "opt": {"m": jnp.ones((16, 32), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


def test_roundtrip_through_burst_buffer(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    st = state_tree()
    d = mgr.save(5, st)
    # the write itself landed on the fast tier
    assert sea.fs.where(os.path.join(d, "manifest.json")) == "tmpfs"
    got = mgr.restore(5, jax.eval_shape(lambda: st))
    assert trees_equal(st, got)
    # after the final flush, files live on the persistent tier only (MOVE)
    sea.flusher.scan()
    sea.flusher._process_all_sync()
    assert sea.fs.where(os.path.join(d, "manifest.json")) == "pfs"
    got2 = mgr.restore(5, jax.eval_shape(lambda: st))
    assert trees_equal(st, got2)


def test_restore_latest_and_gc(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, state_tree(step))
    steps = mgr.available_steps()
    assert steps == [3, 4]  # GC kept last 2
    s, got = mgr.restore_latest(jax.eval_shape(lambda: state_tree()))
    assert s == 4
    assert trees_equal(got, state_tree(4))


def test_corrupt_checkpoint_falls_back(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    mgr.save(1, state_tree(1))
    mgr.save(2, state_tree(2))
    # corrupt one leaf file of step 2 (wherever it lives)
    d2 = mgr._step_dir(2)
    key = sea.fs.key_of(os.path.join(d2, "00000.npy"))
    tier, real = sea.fs.hierarchy.locate(key)
    with open(real, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    s, got = mgr.restore_latest(jax.eval_shape(lambda: state_tree()))
    assert s == 1
    assert trees_equal(got, state_tree(1))


def test_incomplete_checkpoint_ignored(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    mgr.save(1, state_tree(1))
    # a partial save: files but no _COMPLETE marker
    d2 = mgr._step_dir(2)
    ser.save_tree(state_tree(2), d2, open_fn=sea.fs.open)
    assert mgr.available_steps() == [1]


def test_elastic_restore_resharded(tmp_path):
    """Restore onto an explicit (1,1) mesh sharding — the reshard path used
    when a job restarts on a different topology."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=1)
    st = state_tree()
    mgr.save(1, st)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: st)
    )
    got = mgr.restore(1, jax.eval_shape(lambda: st), shardings=shardings)
    assert trees_equal(st, got)
    leaf = got["params"]["w"]
    assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


def test_bf16_bit_exact(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea)
    st = {"w": (jnp.arange(1024, dtype=jnp.float32) * 1.37e-3).astype(jnp.bfloat16)}
    mgr.save(1, st)
    got = mgr.restore(1, jax.eval_shape(lambda: st))
    assert np.array_equal(
        np.asarray(st["w"]).view(np.uint16), np.asarray(got["w"]).view(np.uint16)
    )
