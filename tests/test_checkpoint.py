"""Burst-buffer checkpoint manager: roundtrip, atomicity, corruption
fallback, GC, elastic restore, async saves, crash-mid-save windows."""

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, SaveHandle
from repro.checkpoint import serialization as ser
from repro.core import Sea, SeaConfig, TierSpec


def make_sea(tmp_path, **kw):
    cfg = SeaConfig(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 22,
        n_procs=1,
        flushlist=("checkpoints/*/*",),
        evictlist=("checkpoints/*/*",),
        **kw,
    )
    return Sea(cfg)


def state_tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {
            "w": jax.random.normal(k, (16, 32)).astype(jnp.bfloat16),
            "b": jnp.zeros((32,), jnp.float32),
        },
        "opt": {"m": jnp.ones((16, 32), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


def test_roundtrip_through_burst_buffer(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    st = state_tree()
    d = mgr.save(5, st)
    # the write itself landed on the fast tier
    assert sea.fs.where(os.path.join(d, "manifest.json")) == "tmpfs"
    got = mgr.restore(5, jax.eval_shape(lambda: st))
    assert trees_equal(st, got)
    # after the final flush, files live on the persistent tier only (MOVE)
    sea.flusher.scan()
    sea.flusher._process_all_sync()
    assert sea.fs.where(os.path.join(d, "manifest.json")) == "pfs"
    got2 = mgr.restore(5, jax.eval_shape(lambda: st))
    assert trees_equal(st, got2)


def test_restore_latest_and_gc(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, state_tree(step))
    steps = mgr.available_steps()
    assert steps == [3, 4]  # GC kept last 2
    s, got = mgr.restore_latest(jax.eval_shape(lambda: state_tree()))
    assert s == 4
    assert trees_equal(got, state_tree(4))


def test_corrupt_checkpoint_falls_back(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    mgr.save(1, state_tree(1))
    mgr.save(2, state_tree(2))
    # corrupt one leaf file of step 2 (wherever it lives)
    d2 = mgr._step_dir(2)
    key = sea.fs.key_of(os.path.join(d2, "00000.npy"))
    tier, real = sea.fs.hierarchy.locate(key)
    with open(real, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    s, got = mgr.restore_latest(jax.eval_shape(lambda: state_tree()))
    assert s == 1
    assert trees_equal(got, state_tree(1))


def test_incomplete_checkpoint_ignored(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    mgr.save(1, state_tree(1))
    # a partial save: files but no _COMPLETE marker
    d2 = mgr._step_dir(2)
    ser.save_tree(state_tree(2), d2, open_fn=sea.fs.open)
    assert mgr.available_steps() == [1]


def test_elastic_restore_resharded(tmp_path):
    """Restore onto an explicit (1,1) mesh sharding — the reshard path used
    when a job restarts on a different topology."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=1)
    st = state_tree()
    mgr.save(1, st)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: st)
    )
    got = mgr.restore(1, jax.eval_shape(lambda: st), shardings=shardings)
    assert trees_equal(st, got)
    leaf = got["params"]["w"]
    assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


def test_bf16_bit_exact(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea)
    st = {"w": (jnp.arange(1024, dtype=jnp.float32) * 1.37e-3).astype(jnp.bfloat16)}
    mgr.save(1, st)
    got = mgr.restore(1, jax.eval_shape(lambda: st))
    assert np.array_equal(
        np.asarray(st["w"]).view(np.uint16), np.asarray(got["w"]).view(np.uint16)
    )


# ------------------------------------------------------------ async + crash
def assert_ledger_matches_walk(fs):
    """No leaked reservations / phantom bytes (mirrors tests/test_ledger)."""
    ledger = fs.hierarchy.ledger
    assert ledger is not None
    for tier in fs.hierarchy:
        for root in tier.roots:
            got, want = ledger.verify(root)
            assert got == want, f"{tier.name}:{root} ledger={got} walk={want}"


def test_async_save_roundtrip(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    st = state_tree(9)
    h = mgr.save(9, st, async_=True)
    assert h.step == 9
    d = h.result(timeout=30)
    assert h.done() and d == mgr._step_dir(9)
    assert mgr.available_steps() == [9]
    got = mgr.restore(9, jax.eval_shape(lambda: st))
    assert trees_equal(st, got)
    snap = sea.fs.telemetry.snapshot()
    assert snap["ckpt_bytes"] > 0
    assert snap["ckpt_save_s"] >= 0.0


def test_async_save_overlap_counted_when_unwaited(tmp_path):
    """A background write that finishes before anyone blocks on the
    handle is a fully hidden save — the overlap counter must say so."""
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea)
    h = mgr.save(1, state_tree(1), async_=True)
    deadline = time.time() + 30
    while not h.done() and time.time() < deadline:
        time.sleep(0.002)  # poll done() — never block in result()
    assert h.done()
    assert sea.fs.telemetry.snapshot()["ckpt_overlap_hits"] == 1
    assert h.result() == mgr._step_dir(1)  # after-the-fact result is free


def test_saves_serialize_and_new_save_surfaces_old_failure(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=5)
    mgr.open_fn = _FailOnWrite(sea.fs, fail_on=1)
    h = mgr.save(1, state_tree(1), async_=True)
    with pytest.raises(IOError, match="injected"):
        mgr.save(2, state_tree(2))  # waits for (and re-raises) save 1
    mgr.open_fn = None
    assert mgr.save(3, state_tree(3))  # manager stays usable
    assert mgr.available_steps() == [3]
    assert h.done()


def test_savehandle_finish_marks_consumed_before_releasing_waiter():
    """Race regression: a result() caller blocked on a failing save must
    consume the outcome atomically with being released — otherwise
    _unsettled() in another thread can pop the failed handle in the
    window before the waiter sets _consumed and re-raise the same
    failure a second time to the next save()/wait()."""
    h = SaveHandle(1, "/d")
    raised = []

    def waiter():
        try:
            h.result(timeout=10)
        except IOError as e:
            raised.append(e)

    t = threading.Thread(target=waiter)
    t.start()
    deadline = time.time() + 5
    while h._waiters == 0 and time.time() < deadline:
        time.sleep(0.001)
    assert h._waiters == 1
    overlapped = h._finish(IOError("boom"))
    assert not overlapped
    assert h._consumed, "consumed must be set BEFORE the waiter is released"
    t.join(10)
    assert len(raised) == 1


def test_failure_observed_via_result_is_not_resurfaced(tmp_path):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=5)
    mgr.open_fn = _FailOnWrite(sea.fs, fail_on=1)
    h = mgr.save(1, state_tree(1), async_=True)
    with pytest.raises(IOError, match="injected"):
        h.result(timeout=30)  # the direct waiter observes the failure
    mgr.open_fn = None
    mgr.wait()  # consumed: must be a no-op, never a second raise
    assert mgr.save(2, state_tree(2))  # ditto for the next save
    assert mgr.available_steps() == [2]


def test_gc_reaps_unmarkered_partials_and_empty_dirs(tmp_path):
    """Seed leak regression: crashed-partial (un-markered) step dirs were
    invisible to available_steps so GC never cleaned them, and pruned
    steps left their empty step_XXXXXXXX directory behind forever."""
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=1)
    mgr.save(1, state_tree(1))
    d2 = mgr._step_dir(2)
    ser.save_tree(state_tree(2), d2, open_fn=sea.fs.open)  # no marker
    assert mgr.available_steps() == [1]
    mgr.save(3, state_tree(3))  # GC: prunes step 1, reaps partial step 2
    assert mgr.available_steps() == [3]
    for root in (tmp_path / "t0", tmp_path / "pfs"):
        ckdir = root / "checkpoints"
        if ckdir.is_dir():
            names = set(os.listdir(ckdir))
            assert names <= {"step_00000003"}, names
    assert_ledger_matches_walk(sea.fs)


def test_restore_fallback_counted_and_logged(tmp_path, caplog):
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea, keep_n=3)
    mgr.save(1, state_tree(1))
    mgr.save(2, state_tree(2))
    d2 = mgr._step_dir(2)
    key = sea.fs.key_of(os.path.join(d2, "00000.npy"))
    tier, real = sea.fs.hierarchy.locate(key)
    with open(real, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    with caplog.at_level("WARNING", logger="repro.checkpoint"):
        s, got = mgr.restore_latest(jax.eval_shape(lambda: state_tree()))
    assert s == 1
    assert sea.fs.telemetry.snapshot()["ckpt_restore_fallbacks"] == 1
    assert any("step 2" in r.getMessage() for r in caplog.records)


class _FailOnWrite:
    """open_fn hook that kills the writer at the Nth write-open — the
    crash-boundary injection (leaf / manifest / marker)."""

    def __init__(self, fs, fail_on: int, mid_write: bool = False):
        self.fs = fs
        self.fail_on = fail_on
        self.mid_write = mid_write
        self.opens = 0
        self._lock = threading.Lock()

    def __call__(self, path, mode="r"):
        if "w" not in mode:
            return self.fs.open(path, mode)
        with self._lock:
            n = self.opens
            self.opens += 1
        if n != self.fail_on:
            return self.fs.open(path, mode)
        if not self.mid_write:
            raise IOError(f"injected writer death opening write #{n}")
        return _DieAfterFirstWrite(self.fs.open(path, mode))


class _DieAfterFirstWrite:
    """File proxy that dies after the first chunk: the file commits
    half-written (close still runs — reservations must not leak)."""

    def __init__(self, f):
        self._f = f
        self._writes = 0

    def write(self, b):
        self._writes += 1
        if self._writes > 1:
            raise IOError("injected writer death mid-stream")
        return self._f.write(b)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._f.close()


# state_tree has 4 leaves: write-opens are leaves 0-3, manifest #4, marker #5
@pytest.mark.parametrize("workers", [1, 4])
@pytest.mark.parametrize(
    "boundary", ["between_leaves", "mid_leaf", "before_manifest", "before_marker"]
)
def test_crash_mid_save_leaves_nothing_restorable(tmp_path, workers, boundary):
    fail_on, mid = {
        "between_leaves": (2, False),
        "mid_leaf": (1, True),
        "before_manifest": (4, False),
        "before_marker": (5, False),
    }[boundary]
    sea = make_sea(tmp_path, checkpoint_workers=workers)
    mgr = CheckpointManager(sea, keep_n=3)
    mgr.save(1, state_tree(1))
    mgr.open_fn = _FailOnWrite(sea.fs, fail_on, mid_write=mid)
    h = mgr.save(2, state_tree(2), async_=True)
    with pytest.raises(IOError, match="injected"):
        h.result(timeout=30)
    mgr.open_fn = None
    # the dead partial is invisible: restore falls back to step 1 ...
    assert mgr.available_steps() == [1]
    s, got = mgr.restore_latest(jax.eval_shape(lambda: state_tree()))
    assert s == 1 and trees_equal(got, state_tree(1))
    # ... the ledger reconciles clean (no leaked reservations) ...
    assert_ledger_matches_walk(sea.fs)
    # ... and the next save's GC reaps the partial: zero leaves visible
    mgr.save(3, state_tree(3))
    assert mgr.available_steps() == [1, 3]
    for root in (tmp_path / "t0", tmp_path / "pfs"):
        assert not (root / "checkpoints" / "step_00000002").exists()
    assert_ledger_matches_walk(sea.fs)


def test_sharded_leaf_written_once_and_reassembled(tmp_path):
    """A leaf sharded over the local devices must serialize each shard
    exactly once (replica_id-0 only) and restore bit-exact."""
    sea = make_sea(tmp_path)
    mgr = CheckpointManager(sea)
    st = state_tree(3)
    n_dev = len(jax.devices())
    if n_dev > 1:
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        mesh = Mesh(np.asarray(jax.devices()).reshape(n_dev), ("d",))
        st = {
            "w": jax.device_put(
                jnp.arange(n_dev * 8 * 4, dtype=jnp.float32).reshape(n_dev * 8, 4),
                NamedSharding(mesh, P("d", None)),
            ),
            "r": jax.device_put(  # fully replicated: still one file
                jnp.ones((6,), jnp.float32), NamedSharding(mesh, P())
            ),
        }
    mgr.save(1, st)
    man = ser.load_manifest(mgr._step_dir(1), open_fn=sea.fs.open)
    logical = sum(np.asarray(x).nbytes for x in jax.tree.leaves(st))
    files = [s["file"] for m in man["leaves"].values() for s in m["shards"]]
    assert len(files) == len(set(files))  # each shard exactly once
    payload = sum(
        s["bytes"] for m in man["leaves"].values() for s in m["shards"]
    )
    headers = len(files) * 200  # .npy header slop upper bound
    assert logical <= payload <= logical + headers
    got = mgr.restore(1, jax.eval_shape(lambda: st))
    assert trees_equal(st, got)
