"""Sea-staged data pipeline: staging, eviction, work stealing, epochs."""

import os

import numpy as np
import pytest

from repro.core import Sea, SeaConfig, TierSpec
from repro.data.pipeline import DataPipeline, write_dataset


@pytest.fixture
def sea(tmp_path):
    cfg = SeaConfig(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 22,
        n_procs=1,
    )
    s = Sea(cfg)
    yield s
    s.shutdown()


def test_dataset_lands_on_persistent_tier(sea):
    write_dataset(sea, "c", n_shards=3, tokens_per_shard=1000, vocab_size=100)
    # dataset shards are written via Sea -> fastest tier first; after the
    # final flush they must exist on the persistent tier for reuse
    sea.flusher.scan()
    p = os.path.join(sea.fs.mount, "dataset", "c", "shard_00000.npy")
    assert sea.fs.exists(p)


def test_pipeline_shapes_and_coverage(sea):
    write_dataset(sea, "c", n_shards=4, tokens_per_shard=4096, vocab_size=977)
    pipe = DataPipeline(sea, "c", batch_size=4, seq_len=64, evict_consumed=False)
    batches = list(pipe)
    assert len(batches) == (4 * 4096) // (4 * 65)
    for b in batches:
        assert b["tokens"].shape == (4, 64)
        assert b["labels"].shape == (4, 64)
        assert (b["tokens"] >= 0).all() and (b["tokens"] < 977).all()
        # labels are next-token shifted views of the same stream
        assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    pipe.close()


def test_pipeline_evicts_consumed_shards(sea):
    write_dataset(sea, "c", n_shards=3, tokens_per_shard=2048, vocab_size=100)
    # make sure shards sit on the persistent tier (as on a real cluster)
    sea.flusher.scan()
    sea.flusher._process_all_sync()
    pipe = DataPipeline(sea, "c", batch_size=2, seq_len=32, evict_consumed=True)
    for _ in pipe:
        pass
    # cache tiers hold no dataset files; persistent copies remain
    for tier in sea.fs.hierarchy.cache_tiers:
        for root in tier.roots:
            for dirpath, _d, files in os.walk(root):
                assert not [f for f in files if f.startswith("shard_")], (
                    dirpath, files)
    assert sea.fs.exists(
        os.path.join(sea.fs.mount, "dataset", "c", "shard_00002.npy")
    )
    assert pipe.stats.shards_consumed == 3
    pipe.close()


def test_work_stealing_partition(sea):
    """Two workers with strided assignment consume disjoint shard sets."""
    write_dataset(sea, "c", n_shards=6, tokens_per_shard=2048, vocab_size=50)
    p0 = DataPipeline(sea, "c", batch_size=2, seq_len=32, worker_id=0,
                      n_workers=2, evict_consumed=False)
    p1 = DataPipeline(sea, "c", batch_size=2, seq_len=32, worker_id=1,
                      n_workers=2, evict_consumed=False)
    n0 = sum(1 for _ in p0)
    n1 = sum(1 for _ in p1)
    assert n0 == n1 > 0
    assert p0.stats.shards_consumed + p1.stats.shards_consumed == 6
    p0.close(); p1.close()


def test_close_joins_staging_thread(sea):
    """close() must stop AND join the staging thread — even when it is
    blocked putting into the bounded staged queue — so no daemon thread
    keeps reading shards after close returns."""
    write_dataset(sea, "c", n_shards=6, tokens_per_shard=2048, vocab_size=50)
    pipe = DataPipeline(sea, "c", batch_size=2, seq_len=32, prefetch_shards=1)
    # do not consume: the staging thread fills the queue and blocks
    pipe.close()
    assert not pipe._thread.is_alive()


def test_mid_iteration_close_joins(sea):
    write_dataset(sea, "c", n_shards=4, tokens_per_shard=2048, vocab_size=50)
    pipe = DataPipeline(sea, "c", batch_size=2, seq_len=32)
    it = iter(pipe)
    next(it)
    pipe.close()
    assert not pipe._thread.is_alive()


def test_resume_after_close_returns_instead_of_hanging(sea):
    """Pulling the iterator again after close() must terminate, not
    block forever on the drained staged queue."""
    import threading

    write_dataset(sea, "c", n_shards=3, tokens_per_shard=2048, vocab_size=50)
    pipe = DataPipeline(sea, "c", batch_size=2, seq_len=32)
    it = iter(pipe)
    next(it)
    pipe.close()
    done = threading.Event()

    def drain():
        list(it)
        done.set()

    threading.Thread(target=drain, daemon=True).start()
    assert done.wait(10)


def test_context_manager_closes_on_error_path(sea):
    """Satellite regression: a failed training loop must not leave the
    staging thread reading shards — `with` closes on the error path."""
    write_dataset(sea, "c", n_shards=3, tokens_per_shard=2048, vocab_size=50)
    with pytest.raises(RuntimeError, match="boom"):
        with DataPipeline(sea, "c", batch_size=2, seq_len=32) as pipe:
            next(iter(pipe))
            raise RuntimeError("boom")
    assert not pipe._thread.is_alive()


def test_device_iter_matches_host_iter(sea):
    write_dataset(sea, "c", n_shards=3, tokens_per_shard=4096, vocab_size=97)
    with DataPipeline(
        sea, "c", batch_size=2, seq_len=64, evict_consumed=False
    ) as p:
        host = list(p)
    with DataPipeline(
        sea, "c", batch_size=2, seq_len=64, evict_consumed=False
    ) as p:
        dev = list(p.device_iter(depth=2))
    assert len(dev) == len(host) > 0
    for a, b in zip(host, dev):
        assert np.array_equal(a["tokens"], np.asarray(b["tokens"]))
        assert np.array_equal(a["labels"], np.asarray(b["labels"]))
    # batches arrive already on device
    import jax

    assert isinstance(dev[0]["tokens"], jax.Array)


def test_device_iter_custom_put_and_stall_counter(sea):
    write_dataset(sea, "c", n_shards=2, tokens_per_shard=2048, vocab_size=50)
    before = sea.fs.telemetry.snapshot()["device_feed_stalls"]
    with DataPipeline(
        sea, "c", batch_size=2, seq_len=32, evict_consumed=False
    ) as p:
        seen = sum(1 for _ in p.device_iter(depth=1, put_fn=lambda b: b))
    assert seen > 0
    # an unthrottled consumer outruns the feeder: stalls were recorded
    assert sea.fs.telemetry.snapshot()["device_feed_stalls"] > before


def test_device_iter_early_exit_joins_feeder(sea):
    write_dataset(sea, "c", n_shards=4, tokens_per_shard=4096, vocab_size=50)
    pipe = DataPipeline(sea, "c", batch_size=2, seq_len=32)
    it = pipe.device_iter(depth=2, put_fn=lambda b: b)
    next(it)
    it.close()  # generator finally must stop + join the feeder thread
    import threading

    feeders = [
        t for t in threading.enumerate() if t.name == "sea-device-feed"
    ]
    assert not any(t.is_alive() for t in feeders)
    pipe.close()


def test_batches_identical_across_batch_sizes(sea):
    """The chunk-cursor assembly must yield the exact token stream the
    old whole-buffer concatenation produced: same data, any batch shape."""
    import numpy as np

    write_dataset(sea, "c", n_shards=3, tokens_per_shard=4096, vocab_size=211)
    stream_a = np.concatenate(
        [
            np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1).ravel()
            for b in DataPipeline(
                sea, "c", batch_size=1, seq_len=64, evict_consumed=False
            )
        ]
    )
    stream_b = np.concatenate(
        [
            np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1).ravel()
            for b in DataPipeline(
                sea, "c", batch_size=4, seq_len=16, evict_consumed=False
            )
        ]
    )
    n = min(stream_a.size, stream_b.size)
    assert n > 0
    assert np.array_equal(stream_a[:n], stream_b[:n])
