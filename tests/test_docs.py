"""Doc-drift gates: the config reference must cover the dataclass.

``docs/CONFIG.md`` documents every ``SeaConfig`` field; this test
introspects the dataclass so adding a knob without documenting it
fails CI rather than rotting silently. The architecture doc and README
are held to the weaker (but still load-bearing) invariant that the
files they link to exist.
"""

from __future__ import annotations

import dataclasses
import pathlib
import re

import pytest

from repro.core import SeaConfig

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def config_md() -> str:
    p = REPO / "docs" / "CONFIG.md"
    assert p.exists(), "docs/CONFIG.md is missing"
    return p.read_text()


def test_every_seaconfig_field_documented(config_md):
    missing = [
        f.name
        for f in dataclasses.fields(SeaConfig)
        if f"`{f.name}`" not in config_md
    ]
    assert not missing, (
        f"SeaConfig fields missing from docs/CONFIG.md: {missing} "
        f"(document each as a `field` table row)"
    )


def test_no_ghost_fields_documented(config_md):
    """Rows documenting fields that no longer exist are as misleading
    as missing rows: every backticked first-column cell must be a real
    dataclass field."""
    real = {f.name for f in dataclasses.fields(SeaConfig)}
    documented = re.findall(r"^\| `(\w+)` \|", config_md, flags=re.M)
    ghosts = [name for name in documented if name not in real]
    assert not ghosts, f"docs/CONFIG.md documents nonexistent fields: {ghosts}"


def test_architecture_doc_exists_and_covers_layers():
    p = REPO / "docs" / "ARCHITECTURE.md"
    assert p.exists(), "docs/ARCHITECTURE.md is missing"
    text = p.read_text()
    for subsystem in (
        "intercept",
        "resolver",
        "placement",
        "ledger",
        "transfer",
        "extents",
        "prefetcher",
        "federation",
        "flusher",
    ):
        assert subsystem in text, (
            f"docs/ARCHITECTURE.md no longer mentions '{subsystem}'"
        )


def test_readme_links_to_docs():
    text = (REPO / "README.md").read_text()
    for target in ("docs/ARCHITECTURE.md", "docs/CONFIG.md"):
        assert target in text, f"README.md does not link to {target}"
        assert (REPO / target).exists()
