"""Extent-granular data plane: block-level placement, partially-staged
streaming reads, and extent-aware eviction.

Covers the three invariants the extent plane adds on top of PRs 1-5:

* a reader through a partial replica sees EXACTLY the base bytes, no
  matter which subset of extents is staged, punched, or in flight;
* the capacity ledger stays walk-consistent while sparse part files
  grow and shrink (``st_blocks`` accounting), so a file bigger than the
  cache tier streams through it without over-committing;
* the validity journal is crash-durable: a SIGKILL (or injected fault)
  at any chunk boundary leaves the mid-flight extent unmarked, never
  torn-but-valid, and a fresh process re-adopts the journal.
"""

from __future__ import annotations

import os
import signal
import subprocess
import sys
import textwrap
import time

import pytest

from repro.core import PART_SUFFIX, SeaConfig, SeaFS, SeaMount, TierSpec
from repro.core.extents import extent_token, journal_path, split_extent_token

EXT = 128 << 10   # extent size: small, 4096-aligned (exact sparse accounting)
CHUNK = 16 << 10  # transfer chunk: several chunks per extent


def make_config(tmp_path, **kw) -> SeaConfig:
    defaults = dict(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(
                name="fast",
                roots=(str(tmp_path / "fast"),),
                capacity=kw.pop("fast_capacity", None),
            ),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=EXT,
        extent_map=True,
        extent_bytes=EXT,
        transfer_chunk_bytes=CHUNK,
        transfer_retries=0,
        transfer_backoff_s=0.0,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


def seed_base(fs: SeaFS, key: str, nbytes: int) -> bytes:
    """Place a file directly on the base tier (a cold PFS-resident input)."""
    data = os.urandom(nbytes)
    real = os.path.join(fs.hierarchy.base.roots[0], key)
    os.makedirs(os.path.dirname(real), exist_ok=True)
    with open(real, "wb") as f:
        f.write(data)
    return data


def part_files(root) -> list[str]:
    out = []
    for dirpath, _d, files in os.walk(root):
        out += [os.path.join(dirpath, f) for f in files if f.endswith(PART_SUFFIX)]
    return out


def ext_snap(fs: SeaFS) -> dict:
    return {k: v for k, v in fs.telemetry.snapshot().items() if "extent" in k}


def quiesce(fs: SeaFS, timeout: float = 10.0) -> None:
    """Stop the within-file readahead and wait out its in-flight staging
    jobs, so telemetry/ledger assertions are race-free."""
    fs.prefetcher.stop()
    deadline = time.time() + timeout
    while time.time() < deadline and fs.prefetcher._inflight > 0:
        time.sleep(0.01)


# ------------------------------------------------------------- read behaviour
def test_streaming_read_matches_base_and_promotes(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    data = seed_base(fs, "big.bin", 5 * EXT + 4096)
    p = os.path.join(fs.mount, "big.bin")
    with fs.open(p, "rb") as f:
        assert f.read() == data
    quiesce(fs)
    snap = ext_snap(fs)
    assert snap["extents_staged"] == 6
    assert snap["extent_staged_bytes"] == len(data)
    # every extent landed: the part file was promoted to a plain replica
    # and the journal retired — the key now resolves to the cache tier
    assert snap["extent_promotions"] == 1
    fast = fs.hierarchy.cache_tiers[0].roots[0]
    assert os.path.exists(os.path.join(fast, "big.bin"))
    assert not part_files(fast)
    assert not os.path.exists(journal_path(fast, "big.bin"))
    assert fs.where(p) == "fast"
    with fs.open(p, "rb") as f:
        assert f.read() == data


def test_random_access_stages_only_touched_extents(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    data = seed_base(fs, "r.bin", 8 * EXT)
    p = os.path.join(fs.mount, "r.bin")
    with fs.open(p, "rb") as f:
        for off in (0, 5 * EXT + 7, 2 * EXT + 100):
            f.seek(off)
            assert f.read(64) == data[off : off + 64]
    snap = ext_snap(fs)
    # only the touched extents (0, 5, 2 — plus at most readahead's
    # speculation) were staged, never the whole file
    assert 3 <= snap["extents_staged"] < 8
    fast = fs.hierarchy.cache_tiers[0].roots[0]
    assert part_files(fast)  # still partial: no promotion
    assert os.path.exists(journal_path(fast, "r.bin"))


def test_small_files_skip_the_extent_plane(tmp_path):
    """A file that fits one extent goes through the whole-file plane —
    no part file, no journal."""
    fs = SeaFS(make_config(tmp_path))
    data = seed_base(fs, "small.bin", EXT // 2)
    with fs.open(os.path.join(fs.mount, "small.bin"), "rb") as f:
        assert f.read() == data
    assert not part_files(fs.hierarchy.cache_tiers[0].roots[0])
    assert ext_snap(fs)["extents_staged"] == 0


def test_extent_map_off_never_creates_part_files(tmp_path):
    fs = SeaFS(make_config(tmp_path, extent_map=False))
    data = seed_base(fs, "w.bin", 4 * EXT)
    with fs.open(os.path.join(fs.mount, "w.bin"), "rb") as f:
        assert f.read() == data
    assert not part_files(fs.hierarchy.cache_tiers[0].roots[0])
    assert fs.extents is None


def test_extent_map_requires_transfer_engine(tmp_path):
    with pytest.raises(ValueError):
        make_config(tmp_path, transfer_engine=False)


# ------------------------------------------------- capacity / ledger behaviour
def test_file_bigger_than_tier_streams_with_walk_consistent_ledger(tmp_path):
    cap = 4 * EXT
    fs = SeaFS(
        make_config(tmp_path, fast_capacity=cap, lru_evict=True)
    )
    data = seed_base(fs, "huge.bin", 16 * EXT)  # 4x the cache tier
    p = os.path.join(fs.mount, "huge.bin")
    with fs.open(p, "rb") as f:
        assert f.read() == data
    quiesce(fs)
    snap = ext_snap(fs)
    assert snap["extents_staged"] >= 16     # every extent passed through
    assert snap["extents_punched"] > 0      # cold blocks were punched out
    assert snap["extent_promotions"] == 0   # never whole on the tier
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    used = tier.used_bytes(root)
    assert used == tier.scan_used_bytes(root)  # ledger == the walk
    assert used <= cap
    # random access into a punched region re-faults correctly
    with fs.open(p, "rb") as f:
        f.seek(100)
        assert f.read(4096) == data[100 : 100 + 4096]
    assert tier.used_bytes(root) == tier.scan_used_bytes(root)


def test_getsize_and_stat_report_logical_size_while_partial(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    data = seed_base(fs, "s.bin", 6 * EXT)
    p = os.path.join(fs.mount, "s.bin")
    with fs.open(p, "rb") as f:
        f.read(100)  # stage only the first extent
    assert fs.getsize(p) == len(data)
    assert fs.stat(p).st_size == len(data)
    # the sparse part file itself also carries the logical size
    parts = part_files(fs.hierarchy.cache_tiers[0].roots[0])
    assert parts and os.stat(parts[0]).st_size == len(data)


def test_scan_used_bytes_counts_staged_blocks_not_holes(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    seed_base(fs, "h.bin", 8 * EXT)
    with fs.open(os.path.join(fs.mount, "h.bin"), "rb") as f:
        f.read(100)  # one extent staged, seven holes
    tier = fs.hierarchy.cache_tiers[0]
    root = tier.roots[0]
    scanned = tier.scan_used_bytes(root)
    staged = ext_snap(fs)["extent_staged_bytes"]
    assert staged < 8 * EXT  # partial by construction
    assert scanned == staged  # holes cost nothing; no double-count
    assert tier.used_bytes(root) == scanned


# ----------------------------------------------------------- crash consistency
def test_injected_fault_mid_extent_leaves_no_torn_valid(tmp_path):
    fs = SeaFS(make_config(tmp_path, fast_capacity=16 * EXT))
    data = seed_base(fs, "c.bin", 4 * EXT)
    p = os.path.join(fs.mount, "c.bin")

    calls = {"n": 0}

    def boom(copied, total, dst):
        calls["n"] += 1
        if calls["n"] >= 2:  # first chunk commits, then every attempt dies
            raise RuntimeError("injected crash")

    fs.transfer.chunk_hook = boom
    with fs.open(p, "rb") as f:
        got = f.read(100)
    # the reader FELL BACK to the base replica for the failed extent and
    # still produced exact bytes
    assert got == data[:100]
    em = fs.extents.get("c.bin")
    assert em is not None
    assert 0 not in em.valid  # the faulted extent was never marked valid
    # the admission reservation was released, not leaked
    tier = fs.hierarchy.cache_tiers[0]
    assert tier.reserved_bytes(tier.roots[0]) == 0
    assert tier.used_bytes(tier.roots[0]) == tier.scan_used_bytes(tier.roots[0])
    # with the fault gone, a later read re-faults and heals the extent
    fs.transfer.chunk_hook = None
    with fs.open(p, "rb") as f:
        assert f.read() == data
    quiesce(fs)
    assert ext_snap(fs)["extent_promotions"] == 1  # fully staged in the end


def test_sigkill_mid_stage_journal_readoptable(tmp_path):
    """A process SIGKILLed between chunk commits of an extent stage must
    leave a journal a fresh process can trust: the in-flight extent is
    unmarked, every marked extent holds exact base bytes."""
    base = tmp_path / "pfs"
    base.mkdir()
    data = os.urandom(6 * EXT)
    (base / "k.bin").write_bytes(data)
    script = textwrap.dedent(
        f"""
        import os, signal
        from repro.core import SeaConfig, SeaFS, TierSpec
        cfg = SeaConfig(
            mount={str(tmp_path / "mount")!r},
            tiers=[
                TierSpec(name="fast", roots=({str(tmp_path / "fast")!r},)),
                TierSpec(name="pfs", roots=({str(base)!r},), persistent=True),
            ],
            max_file_size={EXT},
            extent_map=True,
            extent_bytes={EXT},
            transfer_chunk_bytes={CHUNK},
            transfer_retries=0,
        )
        fs = SeaFS(cfg)
        calls = {{"n": 0}}
        def hook(copied, total, dst):
            calls["n"] += 1
            if calls["n"] == {EXT // CHUNK + 3}:
                # two extents committed; die mid-chunk of the third
                os.kill(os.getpid(), signal.SIGKILL)
        fs.transfer.chunk_hook = hook
        with fs.open(os.path.join(fs.mount, "k.bin"), "rb") as f:
            f.read()
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", script], cwd="/root/repo", env=env, timeout=60
    )
    assert proc.returncode == -signal.SIGKILL
    # the part file + journal survive; a fresh process re-adopts them
    fast = str(tmp_path / "fast")
    assert part_files(fast)
    fs2 = SeaFS(make_config(tmp_path))
    em = fs2.extents.load("k.bin", fs2.hierarchy.cache_tiers)
    assert em is not None
    assert em.valid  # the completed extents were journalled...
    part = part_files(fast)[0]
    with open(part, "rb") as f:
        for idx in sorted(em.valid):
            start, length = em.extent_range(idx)
            f.seek(start)
            assert f.read(length) == data[start : start + length]
    # ...and a full read through the adopted replica is exact
    with fs2.open(os.path.join(fs2.mount, "k.bin"), "rb") as f:
        assert f.read() == data


def test_stale_journal_dropped_when_base_rewritten(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    seed_base(fs, "m.bin", 4 * EXT)
    p = os.path.join(fs.mount, "m.bin")
    with fs.open(p, "rb") as f:
        f.read(100)
    assert fs.extents.get("m.bin") is not None
    # overwrite through the mount: the partial replica is stale
    new = os.urandom(3 * EXT)
    with fs.open(p, "wb") as f:
        f.write(new)
    assert fs.extents.get("m.bin") is None
    with fs.open(p, "rb") as f:
        assert f.read() == new


# ----------------------------------------------------------------- truncate
def test_truncate_updates_ledger_and_invalidates_extents(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    seed_base(fs, "t.bin", 4 * EXT)
    p = os.path.join(fs.mount, "t.bin")
    with fs.open(p, "rb") as f:
        f.read(100)  # create a partial replica
    assert fs.extents.get("t.bin") is not None
    fs.truncate(p, EXT)
    assert fs.extents.get("t.bin") is None  # extent state invalidated
    assert not part_files(fs.hierarchy.cache_tiers[0].roots[0])
    assert fs.getsize(p) == EXT
    base_tier = fs.hierarchy.base
    assert base_tier.used_bytes(base_tier.roots[0]) == base_tier.scan_used_bytes(
        base_tier.roots[0]
    )


def test_truncate_missing_key_raises_enoent(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    with pytest.raises(FileNotFoundError):
        fs.truncate(os.path.join(fs.mount, "nope.bin"), 0)


def test_ftruncate_settles_accounting_for_sea_fds(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "w.bin")
    with fs.open(p, "wb") as f:
        f.write(b"x" * (2 * EXT))
        f.flush()
        fs.ftruncate(f.fileno(), 4096)
    assert fs.getsize(p) == 4096
    tier, real = fs.resolver.resolve("w.bin")
    root = tier.root_of(real)
    assert tier.used_bytes(root) == tier.scan_used_bytes(root)


def test_os_truncate_intercepted_under_mount(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    seed_base(fs, "i.bin", 2 * EXT)
    p = os.path.join(fs.mount, "i.bin")
    outside = tmp_path / "outside.bin"
    outside.write_bytes(b"y" * 100)
    with SeaMount(fs):
        os.truncate(p, 4096)
        assert os.path.getsize(p) == 4096
        os.truncate(str(outside), 10)  # non-sea paths pass through
    assert outside.stat().st_size == 10
    assert fs.getsize(p) == 4096
    # restored after the context
    assert os.truncate is not None and fs.getsize(p) == 4096


# ------------------------------------------------------------------ readahead
def test_sequential_scan_predicts_extents(tmp_path):
    """A block-sequential scan feeds extent tokens to the stride
    detector; the predictor issues within-file readahead."""
    fs = SeaFS(make_config(tmp_path))
    data = seed_base(fs, "seq.bin", 10 * EXT)
    p = os.path.join(fs.mount, "seq.bin")
    with fs.open(p, "rb") as f:
        for _ in range(10):
            assert f.read(EXT)  # one extent per read
            time.sleep(0.01)   # let the digestion thread keep up
    deadline = time.time() + 5
    while time.time() < deadline:
        if fs.telemetry.snapshot()["readahead_predictions"] > 0:
            break
        time.sleep(0.05)
    assert fs.telemetry.snapshot()["readahead_predictions"] > 0
    fs.prefetcher.stop()


def test_extent_token_roundtrip():
    tok = extent_token("a/b/c_0012.npy", 7)
    assert split_extent_token(tok) == ("a/b/c_0012.npy", 7)
    assert split_extent_token("plain/key.npy") is None


# ----------------------------------------------------------------- namespace
def test_part_files_invisible_to_listdir_and_flusher(tmp_path):
    from repro.core import Sea

    sea = Sea(make_config(tmp_path, flushlist=("*",))).start()
    try:
        data = seed_base(sea.fs, "d/v.bin", 4 * EXT)
        p = os.path.join(sea.fs.mount, "d/v.bin")
        with sea.fs.open(p, "rb") as f:
            f.read(100)  # partial replica exists on the cache tier
        assert part_files(sea.fs.hierarchy.cache_tiers[0].roots[0])
        assert sea.fs.listdir(os.path.join(sea.fs.mount, "d")) == ["v.bin"]
        sea.flusher.scan()
        sea.flusher.drain()
        # the flusher never treated the part file as a flushable key
        assert not os.path.exists(
            os.path.join(
                sea.fs.hierarchy.base.roots[0], "d", "v.bin" + PART_SUFFIX
            )
        )
        with sea.fs.open(p, "rb") as f:
            assert f.read() == data
    finally:
        sea.shutdown()
