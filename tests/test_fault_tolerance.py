"""Fault tolerance: crash/restart through Sea checkpoints (subprocess
integration), heartbeats, stragglers, restart policy, pipeline parallelism
(multi-device subprocess)."""

import os
import subprocess
import sys
import time

import pytest

from repro.distributed.fault import (
    HeartbeatMonitor,
    RestartPolicy,
    StragglerDetector,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def run_train(workdir, *extra, check=True):
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "small", "--params-m", "2", "--steps", "12",
        "--batch", "2", "--seq", "64", "--ckpt-every", "4",
        "--n-shards", "2", "--workdir", workdir, "--quiet", *extra,
    ]
    return subprocess.run(cmd, env=ENV, capture_output=True, text=True,
                          timeout=600, check=check)


@pytest.mark.slow
def test_crash_restart_resumes_from_checkpoint(tmp_path):
    wd = str(tmp_path / "run")
    # first run aborts hard at step 6 (after the step-4 checkpoint)
    r1 = run_train(wd, "--simulate-failure", "6", check=False)
    assert r1.returncode == 17, r1.stderr[-2000:]
    # relaunch with the same workdir: must resume (not restart from 0)
    r2 = run_train(wd)
    assert r2.returncode == 0, r2.stderr[-2000:]
    # resumed run saved later checkpoints; the final one is step 12
    ckpts = sorted(os.listdir(os.path.join(wd, "pfs", "checkpoints")))
    assert any("00000012" in c for c in ckpts), ckpts


def test_heartbeat_monitor(tmp_path):
    hb0 = HeartbeatMonitor(str(tmp_path), 0, timeout_s=0.5)
    hb1 = HeartbeatMonitor(str(tmp_path), 1, timeout_s=0.5)
    hb0.beat(1)
    hb1.beat(1)
    assert hb0.dead_workers([0, 1]) == []
    time.sleep(0.7)
    hb0.beat(2)  # worker 0 stays live, worker 1 goes silent
    assert hb0.dead_workers([0, 1]) == [1]
    assert hb0.dead_workers([0, 1, 2]) == [1, 2]  # never-seen worker is dead


def test_straggler_detector():
    det = StragglerDetector(threshold=1.5, window=8)
    for step in range(8):
        det.record(0, 1.0)
        det.record(1, 1.05)
        det.record(2, 3.0)   # 3x median
    assert det.stragglers() == [2]


def test_restart_policy_budget_and_backoff():
    rp = RestartPolicy(max_restarts=3, backoff_base_s=1.0, backoff_cap_s=10)
    delays = [rp.next_delay() for _ in range(4)]
    assert delays[:3] == [1.0, 2.0, 4.0]
    assert delays[3] is None       # budget exhausted
    rp.reset()
    assert rp.next_delay() == 1.0


PIPELINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.distributed.pipeline import pipeline_forward, split_microbatches

n_stages, n_micro, Bm, D = 4, 8, 2, 16
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("pod",))
key = jax.random.PRNGKey(0)
params = jax.random.normal(key, (n_stages, D, D)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, Bm, D))
out = pipeline_forward(stage_fn, params, x, mesh, axis="pod")

# oracle: sequential application of the 4 stages
ref = x
for s in range(n_stages):
    ref = jax.vmap(lambda xb: stage_fn(params[s], xb))(ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential(tmp_path):
    """GPipe pipeline over a 4-device 'pod' axis == sequential oracle."""
    r = subprocess.run(
        [sys.executable, "-c", PIPELINE_SCRIPT],
        env=ENV, capture_output=True, text=True, timeout=600,
    )
    assert "PIPELINE_OK" in r.stdout, r.stderr[-3000:]
