"""Cluster-federation tests: shared registry membership + key-location
journal, the resolver's peer tier, and the peer-pull read path.

The fault scenarios are the acceptance criteria of the federation PR:
a peer dying mid-pull must leave no partial destination visible and no
leaked reservation (the read falls back to the base tier), a stale
registry entry (peer evicted the file but the journal still lists it)
must fall back and be expunged, and a dead node's heartbeat + journal
entries must be expired by reconcile without ever blocking a reader.
"""

from __future__ import annotations

import json
import os
import time

import pytest

from repro.core import FederationRegistry, SeaConfig, SeaFS, TierSpec
from repro.core.simulator import ClusterSpec, Simulator, Workload

PAYLOAD = 40_000  # < max_file_size: cache-placed on write


def make_fs(tmp_path, node: str, cache_capacity=None, **kw) -> SeaFS:
    cfg = SeaConfig(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(
                name="cache",
                roots=(str(tmp_path / f"cache_{node}"),),
                capacity=cache_capacity,
            ),
            TierSpec(
                name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True
            ),
        ],
        max_file_size=1 << 16,
        shared_ledger=True,
        ledger_reconcile_interval_s=1e9,
        federation=True,
        federation_node=node,
        readahead=False,
        transfer_retries=0,
        transfer_backoff_s=0.0,
        **kw,
    )
    return SeaFS(cfg)


def cache_files(root: str) -> list[str]:
    from repro.core.ledger import LEDGER_DIRNAME

    out = []
    for dirpath, dirnames, files in os.walk(root):
        if LEDGER_DIRNAME in dirnames:
            dirnames.remove(LEDGER_DIRNAME)
        out += [os.path.join(dirpath, f) for f in files]
    return out


def test_federation_requires_shared_ledger(tmp_path):
    with pytest.raises(ValueError, match="shared_ledger"):
        SeaConfig(
            mount=str(tmp_path / "mount"),
            tiers=[
                TierSpec(name="c", roots=(str(tmp_path / "c"),)),
                TierSpec(
                    name="p", roots=(str(tmp_path / "p"),), persistent=True
                ),
            ],
            federation=True,
        )


def test_federation_ttl_must_exceed_heartbeat(tmp_path):
    with pytest.raises(ValueError, match="federation_node_ttl_s"):
        SeaConfig(
            mount=str(tmp_path / "mount"),
            tiers=[
                TierSpec(name="c", roots=(str(tmp_path / "c"),)),
                TierSpec(
                    name="p", roots=(str(tmp_path / "p"),), persistent=True
                ),
            ],
            shared_ledger=True,
            federation=True,
            federation_heartbeat_s=5.0,
            federation_node_ttl_s=5.0,
        )


def test_peer_pull_happy_path(tmp_path):
    """B's open resolves a key held only in A's cache (not even in the
    base tier yet) and pulls it peer-to-peer."""
    a = make_fs(tmp_path, "a")
    b = make_fs(tmp_path, "b")
    payload = os.urandom(PAYLOAD)
    p = os.path.join(a.mount, "x.bin")
    with a.open(p, "wb") as f:
        f.write(payload)
    assert "a" in a.federation.holders("x.bin")

    with b.open(os.path.join(b.mount, "x.bin"), "rb") as f:
        assert f.sea_tier == "cache"  # served from B's own cache post-pull
        assert f.read() == payload
    snap = b.telemetry.snapshot()
    assert snap["peer_hits"] == 1
    assert snap["peer_pull_bytes"] == PAYLOAD
    assert snap["peer_fallbacks"] == 0
    # the pulled replica was published: B is now a holder too
    assert set(a.federation.holders("x.bin")) == {"a", "b"}
    a.transfer.close()
    b.transfer.close()


def test_peer_dies_mid_pull_falls_back_clean(tmp_path):
    """A transfer killed at a chunk boundary must fall back to the base
    tier with bit-exact content, leave nothing in the puller's cache,
    release its reservation, and expunge the failed candidate."""
    a = make_fs(tmp_path, "a")
    payload = os.urandom(PAYLOAD)
    p = os.path.join(a.mount, "x.bin")
    with a.open(p, "wb") as f:
        f.write(payload)
    a.persist(p)  # base copy: the fallback target

    b = make_fs(tmp_path, "b", cache_capacity=1 << 20)

    def boom(copied, total, dst):
        raise OSError(5, "injected peer death", dst)

    b.transfer.chunk_hook = boom
    with b.open(os.path.join(b.mount, "x.bin"), "rb") as f:
        assert f.sea_tier == "pfs"  # fell through to base
        assert f.read() == payload
    b.transfer.chunk_hook = None

    snap = b.telemetry.snapshot()
    assert snap["peer_hits"] == 0
    assert snap["peer_fallbacks"] == 1
    # no partial/tmp file ever became visible in B's cache
    assert cache_files(str(tmp_path / "cache_b")) == []
    cache = b.hierarchy.tiers[0]
    assert cache.reserved_bytes(cache.roots[0]) == 0
    # the failed candidate was expunged: the next open goes straight to
    # base without another fallback
    assert "a" not in a.federation.holders("x.bin")
    with b.open(os.path.join(b.mount, "x.bin"), "rb") as f:
        assert f.read() == payload
    assert b.telemetry.snapshot()["peer_fallbacks"] == 1
    a.transfer.close()
    b.transfer.close()


def test_stale_registry_entry_after_peer_eviction(tmp_path):
    """The journal still lists A as a holder, but A's cache copy is
    gone: the pull fails, the reader falls back to base, and the stale
    entry is expunged so later readers skip it."""
    a = make_fs(tmp_path, "a")
    payload = os.urandom(PAYLOAD)
    p = os.path.join(a.mount, "x.bin")
    with a.open(p, "wb") as f:
        f.write(payload)
    a.persist(p)
    # evict behind the registry's back (divergence, not a clean evict)
    (croot, _size) = a.federation.holders("x.bin")["a"]
    os.unlink(os.path.join(croot, "x.bin"))

    b = make_fs(tmp_path, "b")
    with b.open(os.path.join(b.mount, "x.bin"), "rb") as f:
        assert f.read() == payload
    snap = b.telemetry.snapshot()
    assert snap["peer_hits"] == 0
    assert snap["peer_fallbacks"] == 1
    assert "a" not in a.federation.holders("x.bin")
    a.transfer.close()
    b.transfer.close()


def test_remove_and_eviction_unpublish(tmp_path):
    a = make_fs(tmp_path, "a")
    p = os.path.join(a.mount, "x.bin")
    with a.open(p, "wb") as f:
        f.write(b"z" * 1024)
    assert "a" in a.federation.holders("x.bin")
    a.remove(p)
    assert a.federation.holders("x.bin") == {}
    a.transfer.close()


def test_retire_leaves_cluster(tmp_path):
    a = make_fs(tmp_path, "a")
    b = make_fs(tmp_path, "b")
    with a.open(os.path.join(a.mount, "x.bin"), "wb") as f:
        f.write(b"z" * 1024)
    assert "a" in b.federation.live_nodes()
    a.federation.retire()
    time.sleep(0.3)  # let B's nodes-file cache lapse
    assert "a" not in b.federation.live_nodes()
    assert b.federation.lookup("x.bin") == []
    a.transfer.close()
    b.transfer.close()


def test_dead_node_heartbeat_expiry(tmp_path):
    """A node on another host that stopped heartbeating is skipped by
    lookup immediately and its journal entries are expired by
    reconcile (heartbeat file removed too)."""
    base = str(tmp_path / "pfs")
    os.makedirs(base)
    reg = FederationRegistry(base, "alive", node_ttl_s=30.0)
    ghost = FederationRegistry(base, "ghost", node_ttl_s=30.0)
    ghost.publish("k.bin", str(tmp_path / "cache_ghost"), 123)
    # first lookup on a fresh journal runs the initial reconcile pass
    # (header reconcile_ts is unset) — do it while ghost is still alive
    # so the later assertions see the lazy-reconcile *bound*, not the
    # bootstrap pass
    assert [n for n, _p, _s in reg.lookup("k.bin")] == ["ghost"]
    # rewrite ghost's heartbeat as a long-dead remote node: the
    # same-host pid probe must not apply, only the stale timestamp
    hb = reg._hb_path("ghost")
    with open(hb, "w") as f:
        json.dump(
            {"node": "ghost", "host": "elsewhere", "pid": 1,
             "ts": time.time() - 999},
            f,
        )
    time.sleep(0.3)  # let the registry's nodes-file cache lapse

    assert reg.lookup("k.bin") == []          # dead holder is skipped
    assert "ghost" in reg.holders("k.bin")    # ...but the entry remains
    assert reg.reconcile() >= 1
    assert reg.holders("k.bin") == {}
    assert not os.path.exists(hb)


def test_simulator_federation_peer_hits_and_makespan():
    """With a congested base read path, re-reads of a shared input set
    resolve to sibling caches: peer hits appear and makespan drops."""
    cl = ClusterSpec(c=4, p=2, L_stream_r=1.5e8, L_backend_r=6e8)
    wl = Workload(B=64, n=2, F=512e6)
    cold = Simulator(cl, wl, "sea", shared_input_files=5).run()
    fed = Simulator(
        cl, wl, "sea", shared_input_files=5, federation=True
    ).run()
    assert cold.peer_hits == 0
    assert fed.peer_hits == 12
    assert fed.peer_pull_bytes == pytest.approx(12 * wl.F)
    assert fed.makespan < cold.makespan
    assert cold.makespan / fed.makespan >= 1.2
