"""Intercept-layer satellites of ISSUE 5: the ``os.makedirs`` wrapper
must forward the positional ``mode`` argument (the seed's lambda routed
``*a`` nowhere), and intercepted ``shutil.copyfile`` for sea↔sea paths
streams through the TransferEngine with ``follow_symlinks`` handled
explicitly."""

import os
import shutil
import stat
import time

import pytest

from repro.core import SeaConfig, SeaFS, SeaMount, TierSpec


def make_config(tmp_path, **kw):
    defaults = dict(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 16,
        n_procs=1,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


# --------------------------------------------------------------- makedirs
def test_makedirs_forwards_positional_mode(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    with SeaMount(fs):
        p = os.path.join(fs.mount, "modedir")
        os.makedirs(p, 0o700)
        real = os.path.join(fs.hierarchy.base.roots[0], "modedir")
        assert stat.S_IMODE(os.stat(real).st_mode) == 0o700
        # positional exist_ok must route as well
        os.makedirs(p, 0o700, True)
        with pytest.raises(FileExistsError):
            os.makedirs(p, 0o700)


def test_makedirs_keyword_args_still_work(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    with SeaMount(fs):
        p = os.path.join(fs.mount, "kwdir")
        os.makedirs(p, exist_ok=True)
        os.makedirs(p, mode=0o750, exist_ok=True)
        assert os.path.isdir(p)


# --------------------------------------------------------------- copyfile
def test_copyfile_sea_to_sea_through_engine(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    data = os.urandom(8192)
    src = os.path.join(fs.mount, "a/src.bin")
    dst = os.path.join(fs.mount, "b/dst.bin")
    fs.write_bytes(src, data)
    with SeaMount(fs):
        assert shutil.copyfile(src, dst) == dst
    assert fs.read_bytes(dst) == data
    assert fs.read_bytes(src) == data  # source untouched
    # the bytes moved through the engine: per-pair transfer counters
    transfers = fs.telemetry.snapshot()["transfers"]
    assert sum(c["files"] for c in transfers.values()) >= 1
    # destination accounting is ledger-consistent
    got, want = fs.hierarchy.ledger.verify(fs.hierarchy.tiers[0].roots[0])
    assert got == want


def test_copyfile_overwrite_drops_stale_replicas(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    src = os.path.join(fs.mount, "src.bin")
    dst = os.path.join(fs.mount, "dst.bin")
    fs.write_bytes(dst, b"old" * 100)
    fs.persist(dst)  # a second (base-tier) replica of dst
    fs.write_bytes(src, b"new" * 200)
    with SeaMount(fs):
        shutil.copyfile(src, dst)
    # every remaining replica of dst holds the new content
    for _tier, real in fs.hierarchy.locate_all("dst.bin"):
        with open(real, "rb") as f:
            assert f.read() == b"new" * 200


def test_copyfile_external_to_sea(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    ext = str(tmp_path / "outside.bin")
    with open(ext, "wb") as f:
        f.write(b"e" * 4096)
    dst = os.path.join(fs.mount, "in.bin")
    with SeaMount(fs):
        shutil.copyfile(ext, dst)
    assert fs.read_bytes(dst) == b"e" * 4096
    assert fs.where(dst) == "tmpfs"


def test_copyfile_sea_to_external(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    src = os.path.join(fs.mount, "out.bin")
    fs.write_bytes(src, b"s" * 4096)
    ext = str(tmp_path / "exported.bin")
    with SeaMount(fs):
        shutil.copyfile(src, ext)
    with open(ext, "rb") as f:
        assert f.read() == b"s" * 4096


def test_copyfile_missing_source_raises_enoent(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    with SeaMount(fs):
        with pytest.raises(FileNotFoundError):
            shutil.copyfile(
                os.path.join(fs.mount, "nope.bin"),
                str(tmp_path / "never.bin"),
            )


def test_copyfile_same_file_raises(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "same.bin")
    fs.write_bytes(p, b"x" * 64)
    with SeaMount(fs):
        with pytest.raises(shutil.SameFileError):
            shutil.copyfile(p, p)


def test_copyfile_symlink_into_mount_rejected(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    target = str(tmp_path / "target.bin")
    with open(target, "wb") as f:
        f.write(b"t" * 64)
    link = str(tmp_path / "link.bin")
    os.symlink(target, link)
    with SeaMount(fs):
        with pytest.raises(NotImplementedError):
            shutil.copyfile(
                link, os.path.join(fs.mount, "in.bin"), follow_symlinks=False
            )
        # dereferencing remains explicit and allowed
        shutil.copyfile(link, os.path.join(fs.mount, "deref.bin"))
    assert fs.read_bytes(os.path.join(fs.mount, "deref.bin")) == b"t" * 64


def test_copyfile_symlink_honored_outward(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    # an externally-created symlink inside a tier root (Sea never makes
    # them, but copyfile must honor follow_symlinks=False when asked)
    target = str(tmp_path / "real_target.bin")
    with open(target, "wb") as f:
        f.write(b"r" * 32)
    root = fs.hierarchy.base.roots[0]
    os.makedirs(root, exist_ok=True)
    os.symlink(target, os.path.join(root, "ln.bin"))
    dst = str(tmp_path / "copied_link.bin")
    with SeaMount(fs):
        shutil.copyfile(
            os.path.join(fs.mount, "ln.bin"), dst, follow_symlinks=False
        )
    assert os.path.islink(dst)
    assert os.readlink(dst) == target


def test_copyfile_does_not_copy_permissions_or_mtime(tmp_path):
    """shutil.copyfile copies DATA only: destination permissions come
    from the umask and the mtime is fresh (copy2 preserves stats —
    copyfile must not)."""
    fs = SeaFS(make_config(tmp_path))
    src = os.path.join(fs.mount, "locked.bin")
    fs.write_bytes(src, b"l" * 128)
    sreal = fs.resolve(src)
    os.chmod(sreal, 0o400)
    old = time.time() - 3600
    os.utime(sreal, (old, old))
    ext = str(tmp_path / "copy_out.bin")
    dst = os.path.join(fs.mount, "copy_in.bin")
    with SeaMount(fs):
        shutil.copyfile(src, ext)
        shutil.copyfile(src, dst)
    for p in (ext, fs.resolve(dst)):
        st = os.stat(p)
        assert stat.S_IMODE(st.st_mode) & 0o200  # writable per umask
        assert st.st_mtime > old + 1800  # fresh, not the source's


def test_copyfile_destination_reaches_flusher(tmp_path):
    """A copyfile destination is a committed write: the flusher must
    pick it up like a closed write handle (COPY-mode flush to base
    without waiting for drain)."""
    from repro.core import Sea

    cfg = make_config(tmp_path, flushlist=("flushed/*",))
    with Sea(cfg) as sea:
        fs = sea.fs
        src = os.path.join(fs.mount, "src.bin")
        dst = os.path.join(fs.mount, "flushed/out.bin")
        fs.write_bytes(src, b"f" * 512)
        with SeaMount(fs):
            shutil.copyfile(src, dst)
        base = os.path.join(fs.hierarchy.base.roots[0], "flushed/out.bin")
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and not os.path.exists(base):
            time.sleep(0.01)
        assert os.path.exists(base)  # flushed by the daemon, not drain


def test_copyfile_same_key_different_spelling_raises(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "same.bin")
    fs.write_bytes(p, b"x" * 64)
    dotted = os.path.join(fs.mount, ".", "same.bin")
    with SeaMount(fs):
        with pytest.raises(shutil.SameFileError):
            shutil.copyfile(p, dotted)
    assert fs.read_bytes(p) == b"x" * 64


def test_copyfile_outside_mount_untouched(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    a, b = str(tmp_path / "plain_a.bin"), str(tmp_path / "plain_b.bin")
    with open(a, "wb") as f:
        f.write(b"p" * 128)
    with SeaMount(fs):
        shutil.copyfile(a, b)
    with open(b, "rb") as f:
        assert f.read() == b"p" * 128
