"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes/dtypes, plus hypothesis property tests on invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dependency: property tests skip cleanly
    from _hypothesis_stub import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ------------------------------------------------------------------ flash
FLASH_SWEEP = [
    # (B, H, Hk, Sq, Sk, Dh, causal, window, dtype)
    (1, 4, 4, 128, 128, 64, True, None, jnp.float32),
    (2, 8, 2, 256, 256, 64, True, None, jnp.float32),      # GQA 4:1
    (1, 4, 1, 128, 128, 128, True, None, jnp.float32),     # MQA
    (1, 4, 4, 200, 200, 64, True, None, jnp.float32),      # ragged/padded
    (1, 4, 2, 256, 256, 64, True, 64, jnp.float32),        # sliding window
    (1, 4, 4, 128, 128, 64, False, None, jnp.float32),     # bidirectional
    (2, 4, 2, 256, 256, 64, True, None, jnp.bfloat16),
    (1, 8, 8, 512, 512, 96, True, None, jnp.bfloat16),     # phi3 head_dim
]


@pytest.mark.parametrize(
    "B,H,Hk,Sq,Sk,Dh,causal,window,dtype", FLASH_SWEEP
)
def test_flash_attention_matches_ref(B, H, Hk, Sq, Sk, Dh, causal, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(k1, (B, Sq, H, Dh), dtype)
    k = rand(k2, (B, Sk, Hk, Dh), dtype)
    v = rand(k3, (B, Sk, Hk, Dh), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=64, block_k=64, interpret=True,
    )
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
    ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_causality_property():
    """Perturbing future tokens never changes past outputs."""
    key = jax.random.PRNGKey(1)
    B, H, S, Dh = 1, 2, 128, 64
    q = rand(key, (B, S, H, Dh))
    k = rand(jax.random.fold_in(key, 1), (B, S, H, Dh))
    v = rand(jax.random.fold_in(key, 2), (B, S, H, Dh))
    out1 = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                           interpret=True)
    k2 = k.at[:, 100:].set(99.0)
    v2 = v.at[:, 100:].set(-99.0)
    out2 = flash_attention(q, k2, v2, causal=True, block_q=32, block_k=32,
                           interpret=True)
    np.testing.assert_allclose(
        np.asarray(out1[:, :100]), np.asarray(out2[:, :100]), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(
    sq=st.integers(16, 160),
    hk=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    blk=st.sampled_from([32, 64]),
)
def test_flash_attention_block_invariance(sq, hk, g, blk):
    """Output is independent of the block decomposition."""
    key = jax.random.PRNGKey(sq)
    B, Dh = 1, 64
    H = hk * g
    q = rand(key, (B, sq, H, Dh))
    k = rand(jax.random.fold_in(key, 1), (B, sq, hk, Dh))
    v = rand(jax.random.fold_in(key, 2), (B, sq, hk, Dh))
    a = flash_attention(q, k, v, block_q=blk, block_k=blk, interpret=True)
    b = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)


# ------------------------------------------------------------------ wkv6
WKV_SWEEP = [
    # (B, H, T, N, chunk, dtype)
    (1, 2, 64, 16, 16, jnp.float32),
    (2, 4, 128, 64, 64, jnp.float32),
    (1, 2, 128, 32, 32, jnp.bfloat16),
    (2, 1, 256, 64, 64, jnp.float32),
]


def wkv_inputs(B, H, T, N, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    r = rand(ks[0], (B, T, H, N), dtype)
    k = rand(ks[1], (B, T, H, N), dtype)
    v = rand(ks[2], (B, T, H, N), dtype)
    # realistic decays: w_log = -exp(x) in [-6, 1] -> decay in (0, 1)
    w_log = -jnp.exp(
        jax.random.uniform(ks[3], (B, T, H, N), minval=-6.0, maxval=1.0)
    ).astype(jnp.float32)
    u = rand(ks[4], (H, N)) * 0.5
    return r, k, v, w_log, u


@pytest.mark.parametrize("B,H,T,N,chunk,dtype", WKV_SWEEP)
def test_wkv6_matches_ref(B, H, T, N, chunk, dtype):
    r, k, v, w_log, u = wkv_inputs(B, H, T, N, dtype)
    out = wkv6(r, k, v, w_log, u, chunk=chunk, interpret=True)
    ref = wkv6_ref(
        r.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), w_log.transpose(0, 2, 1, 3), u,
    ).transpose(0, 2, 1, 3)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol)


def test_wkv6_chunk_invariance():
    """Chunk size must not change the result (state handoff correctness)."""
    r, k, v, w_log, u = wkv_inputs(1, 2, 128, 32, jnp.float32, key=3)
    a = wkv6(r, k, v, w_log, u, chunk=16, interpret=True)
    b = wkv6(r, k, v, w_log, u, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_wkv6_matches_model_path():
    """The XLA chunked implementation used by the model (rwkv.wkv6_chunked)
    agrees with the Pallas kernel — kernel and model can swap freely."""
    from repro.models.rwkv import wkv6_chunked

    r, k, v, w_log, u = wkv_inputs(1, 2, 128, 32, jnp.float32, key=5)
    a = wkv6(r, k, v, w_log, u, chunk=32, interpret=True)
    b = wkv6_chunked(r, k, v, w_log, u, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([32, 64, 96]), n=st.sampled_from([16, 32]))
def test_wkv6_decay_forgetting_property(t, n):
    """With total decay -> -inf between two halves, the second half's output
    is independent of the first half (the state is fully forgotten)."""
    r, k, v, w_log, u = wkv_inputs(1, 1, t, n, jnp.float32, key=t * n)
    cut = t // 2
    w_hard = w_log.at[:, cut].set(-50.0)  # one step erases the state
    out_full = wkv6(r, k, v, w_hard, u, chunk=16, interpret=True)
    r2 = r.at[:, :cut].set(0.123)
    k2 = k.at[:, :cut].set(-0.5)
    out_mod = wkv6(r2, k2, v, w_hard, u, chunk=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out_full[:, cut + 1:]), np.asarray(out_mod[:, cut + 1:]),
        rtol=1e-4, atol=1e-4,
    )


# ------------------------------------------------------------------ ssm
SSM_SWEEP = [
    # (B, T, d_in, N, chunk, dblk)
    (1, 64, 64, 8, 16, 32),
    (2, 128, 128, 16, 64, 64),
    (1, 256, 64, 16, 64, 64),
]


def ssm_inputs(B, T, d_in, N, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    dt = jax.nn.softplus(rand(ks[0], (B, T, d_in)))
    x = rand(ks[1], (B, T, d_in))
    Bm = rand(ks[2], (B, T, N))
    Cm = rand(ks[3], (B, T, N))
    A = -jnp.exp(rand(ks[4], (d_in, N)) * 0.5)
    D = rand(ks[5], (d_in,))
    return dt, x, Bm, Cm, A, D


@pytest.mark.parametrize("B,T,d_in,N,chunk,dblk", SSM_SWEEP)
def test_ssm_scan_matches_ref(B, T, d_in, N, chunk, dblk):
    args = ssm_inputs(B, T, d_in, N)
    out = ssm_scan(*args, chunk=chunk, dblk=dblk, interpret=True)
    ref = ssm_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_chunk_invariance():
    args = ssm_inputs(1, 128, 64, 16, key=7)
    a = ssm_scan(*args, chunk=16, dblk=32, interpret=True)
    b = ssm_scan(*args, chunk=128, dblk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("shape", [(4, 128), (2, 64, 256), (3, 5, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(0)
    x = rand(key, shape, dtype, scale=3.0)
    scale = rand(jax.random.fold_in(key, 1), shape[-1:]) + 1.0
    out = rmsnorm(x, scale, interpret=True, block_rows=8)
    ref = rmsnorm_ref(x, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 33),
    d=st.sampled_from([64, 128, 384]),
    amp=st.floats(0.5, 100.0),   # amp >> sqrt(eps): the invariant's domain
)
def test_rmsnorm_output_rms_is_scale_rms(rows, d, amp):
    """RMS of the output equals RMS of the scale vector (norm invariant),
    for inputs well above eps — catches accumulation/layout bugs."""
    key = jax.random.PRNGKey(rows * d)
    x = rand(key, (rows, d), scale=amp)
    scale = jnp.ones((d,))
    out = rmsnorm(x, scale, interpret=True, block_rows=8)
    rms = np.sqrt(np.mean(np.square(np.asarray(out)), axis=-1))
    np.testing.assert_allclose(rms, np.ones_like(rms), rtol=1e-3, atol=1e-3)
