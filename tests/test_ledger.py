"""Tests for the capacity-accounting ledger (O(1) placement hot path).

Covers the PR's acceptance criteria:
  * ledger matches a fresh os.walk after mixed create/overwrite/flush/
    evict/remove/rename traffic (1k random operations),
  * reservations prevent over-commit under concurrent writers,
  * reconciliation absorbs out-of-band file drops (external writers),
plus worker-pool flusher behaviour and the simulator's placement-cost
model.
"""

import os
import random
import threading
import time

import pytest

from repro.core import Sea, SeaConfig, SeaFS, TierSpec
from repro.core.flusher import Flusher
from repro.core.ledger import CapacityLedger
from repro.core.tiers import Tier


def make_config(tmp_path, **kw):
    defaults = dict(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="disk", roots=(str(tmp_path / "d0"), str(tmp_path / "d1"))),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 16,
        n_procs=2,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


def assert_ledger_matches_walk(fs):
    ledger = fs.hierarchy.ledger
    assert ledger is not None
    for tier in fs.hierarchy:
        for root in tier.roots:
            got, want = ledger.verify(root)
            assert got == want, f"{tier.name}:{root} ledger={got} walk={want}"


# ------------------------------------------------------------ unit behaviour
def test_ledger_basic_accounting(tmp_path):
    led = CapacityLedger(reconcile_interval_s=1e9)
    root = str(tmp_path)
    assert led.used_bytes(root) == 0
    led.note_written(root, "a.bin", 100)
    led.note_written(root, "b.bin", 50)
    assert led.used_bytes(root) == 150
    led.note_written(root, "a.bin", 10)  # overwrite: delta, not sum
    assert led.used_bytes(root) == 60
    led.note_removed(root, "b.bin")
    assert led.used_bytes(root) == 10
    led.note_removed(root, "b.bin")  # double-remove is a no-op
    assert led.used_bytes(root) == 10


def test_ledger_reservation_lifecycle(tmp_path):
    led = CapacityLedger(reconcile_interval_s=1e9)
    root = str(tmp_path)
    led.used_bytes(root)  # initial reconcile of the (empty) root
    res = led.reserve(root, 1000)
    assert led.reserved_bytes(root) == 1000
    led.commit(res, "x.bin", 640)
    assert led.reserved_bytes(root) == 0
    assert led.used_bytes(root) == 640
    # commit is idempotent on the reservation side
    led.commit(res, "x.bin", 640)
    assert led.reserved_bytes(root) == 0
    res2 = led.reserve(root, 500)
    led.release(res2)
    assert led.reserved_bytes(root) == 0
    assert led.used_bytes(root) == 640


def test_ledger_initial_reconcile_absorbs_preexisting_files(tmp_path):
    (tmp_path / "sub").mkdir()
    (tmp_path / "sub" / "old.bin").write_bytes(b"x" * 321)
    led = CapacityLedger(reconcile_interval_s=1e9)
    assert led.used_bytes(str(tmp_path)) == 321


def test_tier_free_bytes_is_ledger_backed(tmp_path):
    spec = TierSpec(name="t", roots=(str(tmp_path / "r"),), capacity=1 << 20)
    led = CapacityLedger(reconcile_interval_s=1e9)
    tier = Tier(spec, 0, led)
    root = tier.roots[0]
    assert tier.free_bytes(root) == 1 << 20
    tier.note_written(root, "f.bin", 1 << 10)
    assert tier.free_bytes(root) == (1 << 20) - (1 << 10)
    res = tier.reserve_write(root, 1 << 12)
    assert tier.free_bytes(root) == (1 << 20) - (1 << 10) - (1 << 12)
    tier.release_write(res)
    assert tier.free_bytes(root) == (1 << 20) - (1 << 10)


# ------------------------------------------------- consistency under traffic
def test_ledger_matches_walk_after_mixed_traffic(tmp_path):
    """1k random create/overwrite/remove/rename/flush/evict operations:
    the ledger must agree with a fresh filesystem walk at the end."""
    cfg = make_config(
        tmp_path,
        flushlist=("*.out",),
        evictlist=("*.out", "*.tmp"),
        ledger_reconcile_interval_s=1e9,  # no reconcile: pure delta tracking
    )
    # small capacities so traffic exercises spill across all three levels
    cfg.tiers[0].capacity = 1 << 18
    cfg.tiers[1].capacity = 1 << 19
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    rng = random.Random(1234)
    live: list[str] = []

    for step in range(1000):
        op = rng.random()
        if op < 0.45 or not live:
            name = f"d{rng.randrange(8)}/f{step}.{rng.choice(['bin', 'out', 'tmp'])}"
            p = os.path.join(fs.mount, name)
            fs.write_bytes(p, os.urandom(rng.randrange(1, 4096)))
            live.append(name)
        elif op < 0.65:
            name = rng.choice(live)
            fs.write_bytes(
                os.path.join(fs.mount, name), os.urandom(rng.randrange(1, 4096))
            )
        elif op < 0.80:
            name = live.pop(rng.randrange(len(live)))
            try:
                fs.remove(os.path.join(fs.mount, name))
            except FileNotFoundError:
                pass  # evicted (REMOVE-mode) by an earlier flusher pass
        elif op < 0.90:
            name = live.pop(rng.randrange(len(live)))
            new = f"mv{step}.bin"
            try:
                fs.rename(
                    os.path.join(fs.mount, name), os.path.join(fs.mount, new)
                )
                live.append(new)
            except FileNotFoundError:
                pass
        else:
            fl.scan()
            fl._process_all_sync()

    fl.scan()
    fl._process_all_sync()
    assert_ledger_matches_walk(fs)


def test_ledger_matches_walk_with_async_pool(tmp_path):
    """Same invariant with the real worker pool doing concurrent flushes."""
    cfg = make_config(
        tmp_path,
        flushlist=("out/*",),
        evictlist=("out/*", "*.tmp"),
        flush_workers=4,
        ledger_reconcile_interval_s=1e9,
    )
    with Sea(cfg) as sea:
        for i in range(40):
            sea.fs.write_bytes(
                os.path.join(sea.fs.mount, f"out/f{i}.bin"), os.urandom(256)
            )
            sea.fs.write_bytes(
                os.path.join(sea.fs.mount, f"s{i}.tmp"), os.urandom(64)
            )
            sea.fs.write_bytes(
                os.path.join(sea.fs.mount, f"keep{i}.bin"), os.urandom(128)
            )
    base = cfg.tiers[-1].roots[0]
    for i in range(40):
        assert os.path.exists(os.path.join(base, f"out/f{i}.bin"))
        assert not os.path.exists(os.path.join(base, f"s{i}.tmp"))
    assert_ledger_matches_walk(sea.fs)


# ------------------------------------------------------ reservation semantics
def test_reservation_prevents_overcommit_with_open_writers(tmp_path):
    """Files opened for write occupy 0 bytes on disk until data lands; the
    seed's stateless rescan let every concurrent open() see the same free
    space and over-commit a capped root. Reservations close that window."""
    F = 1 << 12
    cfg = make_config(tmp_path, max_file_size=F, n_procs=1)
    cfg.tiers[0].capacity = 4 * F
    fs = SeaFS(cfg)
    handles = []
    for i in range(4):
        handles.append(fs.open(os.path.join(fs.mount, f"w{i}.bin"), "wb"))
    # 4 in-flight reservations exhaust the tmpfs cap: the 5th must spill
    f5 = fs.open(os.path.join(fs.mount, "w4.bin"), "wb")
    assert fs.hierarchy.tiers[0].root_of(f5._real) is None
    for h in handles:
        h.write(b"x" * 16)
        h.close()
    f5.close()
    assert fs.where(os.path.join(fs.mount, "w0.bin")) == "tmpfs"
    assert fs.where(os.path.join(fs.mount, "w4.bin")) != "tmpfs"
    assert_ledger_matches_walk(fs)


def test_reservation_released_on_close_and_on_failed_open(tmp_path):
    F = 1 << 12
    cfg = make_config(tmp_path, max_file_size=F, n_procs=1)
    cfg.tiers[0].capacity = 4 * F
    fs = SeaFS(cfg)
    tier0 = fs.hierarchy.tiers[0]
    root0 = tier0.roots[0]
    f = fs.open(os.path.join(fs.mount, "a.bin"), "wb")
    assert tier0.reserved_bytes(root0) == F
    f.write(b"y" * 100)
    f.close()
    assert tier0.reserved_bytes(root0) == 0
    assert tier0.used_bytes(root0) == 100
    # invalid mode -> io.open raises -> reservation must be returned
    with pytest.raises(ValueError):
        fs.open(os.path.join(fs.mount, "b.bin"), "wb+q")
    assert tier0.reserved_bytes(root0) == 0


def test_concurrent_writers_never_overcommit_capped_root(tmp_path):
    """Many threads hammering a small capped root: committed bytes +
    reservations never exceed the cap at placement time. The tiny
    reconcile interval forces walks to race with commits — the ledger's
    version guard must discard those stale snapshots."""
    F = 1 << 10
    cfg = make_config(
        tmp_path, max_file_size=F, n_procs=1, ledger_reconcile_interval_s=0.01
    )
    cap = 8 * F
    cfg.tiers[0].capacity = cap
    fs = SeaFS(cfg)
    errs = []

    def work(i):
        try:
            for j in range(10):
                p = os.path.join(fs.mount, f"t{i}_{j}.bin")
                fs.write_bytes(p, os.urandom(F // 2))
        except Exception as e:  # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=work, args=(i,)) for i in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    # the capped tmpfs root must never physically exceed its capacity
    tier0 = fs.hierarchy.tiers[0]
    assert tier0.scan_used_bytes(tier0.roots[0]) <= cap
    assert_ledger_matches_walk(fs)


def test_reservation_headroom_not_double_counted(tmp_path):
    """``n_procs * F`` is worst-case headroom for *untracked* writers;
    tracked reservations count toward it, not on top of it. Two concurrent
    writers on a 2F-capacity root with n_procs=2 provably fit and must
    BOTH land on the fast tier (the seed admitted both)."""
    F = 1 << 12
    cfg = make_config(tmp_path, max_file_size=F, n_procs=2)
    cfg.tiers[0].capacity = 2 * F
    fs = SeaFS(cfg)
    tier0 = fs.hierarchy.tiers[0]
    f1 = fs.open(os.path.join(fs.mount, "a.bin"), "wb")
    f2 = fs.open(os.path.join(fs.mount, "b.bin"), "wb")
    assert tier0.root_of(f1._real) is not None
    assert tier0.root_of(f2._real) is not None
    # a third concurrent writer would break used+reserved <= capacity
    f3 = fs.open(os.path.join(fs.mount, "c.bin"), "wb")
    assert tier0.root_of(f3._real) is None
    for h in (f1, f2, f3):
        h.write(b"z" * 8)
        h.close()
    assert_ledger_matches_walk(fs)


def test_flusher_defers_busy_reader_until_close(tmp_path):
    """A reader holding a file busy blocks its flush; the deferred flush
    must fire on that reader's close (a read close, which previously never
    re-submitted)."""
    cfg = make_config(tmp_path, flushlist=("*.out",), evictlist=("*.out",))
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "r.out")
    fs.write_bytes(p, b"r" * 32)   # close event queues the key
    f = fs.open(p, "rb")           # reader holds it busy
    fl._process_all_sync()
    assert fs.where(p) == "tmpfs"  # busy: deferred, not moved
    f.close()                      # read close re-submits the deferred key
    fl._process_all_sync()
    assert fs.where(p) == "pfs"


# ------------------------------------------------------------- reconciliation
def test_reconcile_absorbs_out_of_band_drops(tmp_path):
    cfg = make_config(tmp_path, ledger_reconcile_interval_s=1e9)
    cfg.tiers[0].capacity = 1 << 20
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "x.bin")
    fs.write_bytes(p, b"x" * 2048)
    tier0 = fs.hierarchy.tiers[0]
    root0 = tier0.roots[0]
    assert tier0.used_bytes(root0) == 2048
    # an external process deletes the file behind Sea's back
    os.remove(os.path.join(root0, "x.bin"))
    assert tier0.used_bytes(root0) == 2048  # ledger is (intentionally) stale
    fs.hierarchy.reconcile()
    assert tier0.used_bytes(root0) == 0
    assert_ledger_matches_walk(fs)


def test_stale_ledger_reconciles_automatically(tmp_path):
    cfg = make_config(tmp_path, ledger_reconcile_interval_s=0.05)
    cfg.tiers[0].capacity = 1 << 20
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "x.bin")
    fs.write_bytes(p, b"x" * 1024)
    tier0 = fs.hierarchy.tiers[0]
    root0 = tier0.roots[0]
    # an external writer adds a file Sea never saw
    with open(os.path.join(root0, "alien.bin"), "wb") as f:
        f.write(b"a" * 512)
    time.sleep(0.06)  # exceed the staleness bound
    assert tier0.used_bytes(root0) == 1024 + 512
    assert fs.telemetry.ledger_reconciles >= 1


def test_ledger_telemetry_counters(tmp_path):
    cfg = make_config(tmp_path)
    cfg.tiers[0].capacity = 1 << 20
    fs = SeaFS(cfg)
    for i in range(5):
        fs.write_bytes(os.path.join(fs.mount, f"f{i}.bin"), b"z" * 64)
    snap = fs.telemetry.snapshot()
    assert snap["ledger_hits"] >= 5
    assert snap["ledger_reconciles"] >= 1  # the initial walk of the root


def test_capacity_ledger_can_be_disabled(tmp_path):
    """capacity_ledger=False restores the seed's stateless per-call walk."""
    cfg = make_config(tmp_path, capacity_ledger=False)
    cfg.tiers[0].capacity = 1 << 20
    fs = SeaFS(cfg)
    assert fs.hierarchy.ledger is None
    p = os.path.join(fs.mount, "x.bin")
    fs.write_bytes(p, b"x" * 100)
    assert fs.where(p) == "tmpfs"
    assert fs.telemetry.snapshot()["ledger_hits"] == 0


# ------------------------------------------------------------- simulator model
def test_simulator_models_stateless_placement_cost():
    """O(n)-per-decision placement (the seed) must cost strictly more than
    the O(1) ledger, and the gap must grow with iteration count."""
    from repro.core.model import ClusterSpec, MiB, Workload
    from repro.core.simulator import Simulator

    cl = ClusterSpec(c=1, p=2)
    mk = lambda n, **kw: Simulator(
        cl, Workload(B=8, F=64 * MiB, n=n), "sea", **kw
    ).run().makespan

    walk = dict(
        ledger_placement=False, placement_probe_s=1e-4,
        placement_scan_s_per_file=1e-3,
    )
    led = dict(
        ledger_placement=True, placement_probe_s=1e-4,
        placement_scan_s_per_file=1e-3,
    )
    gap_small = mk(4, **walk) - mk(4, **led)
    gap_big = mk(16, **walk) - mk(16, **led)
    assert gap_small > 0
    assert gap_big > gap_small * 2  # superlinear: more cached files per walk
