"""Coverage for the opt-in LRU room-making path (``SeaConfig.lru_evict``).

``SeaFS._lru_make_room`` was exercised by no test: cover eviction under
cache pressure, LRU ordering, busy-file exclusion, the 8-attempt
re-selection loop in ``_resolve_write``, and the base-tier fallback when
no room can be made.
"""

import os

from repro.core import SeaConfig, SeaFS, TierSpec

F = 1 << 12


def make_config(workdir: str, *, capacity: int, **kw) -> SeaConfig:
    defaults = dict(
        mount=os.path.join(workdir, "mount"),
        tiers=[
            TierSpec(
                name="tmpfs", roots=(os.path.join(workdir, "t0"),), capacity=capacity
            ),
            TierSpec(name="pfs", roots=(os.path.join(workdir, "pfs"),), persistent=True),
        ],
        max_file_size=F,
        n_procs=1,
        lru_evict=True,
        ledger_reconcile_interval_s=1e9,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


def test_evicts_lru_under_pressure(tmp_path):
    """A full cache must shed its least-recently-used closed file so a new
    write still lands on the fast tier."""
    fs = SeaFS(make_config(str(tmp_path), capacity=4 * F))
    for i in range(4):  # fills the tmpfs cap exactly
        fs.write_bytes(os.path.join(fs.mount, f"f{i}.bin"), b"x" * F)
    # touch f0 so f1 becomes the LRU candidate
    with fs.open(os.path.join(fs.mount, "f0.bin"), "rb") as f:
        f.read()
    fs.write_bytes(os.path.join(fs.mount, "new.bin"), b"y" * F)
    assert fs.where(os.path.join(fs.mount, "new.bin")) == "tmpfs"
    assert fs.where(os.path.join(fs.mount, "f0.bin")) == "tmpfs"  # recently used
    assert fs.where(os.path.join(fs.mount, "f1.bin")) is None  # evicted (KEEP)
    assert fs.telemetry.evicted_files >= 1
    got, want = fs.hierarchy.ledger.verify(fs.hierarchy.tiers[0].roots[0])
    assert got == want


def test_busy_files_are_never_evicted(tmp_path):
    """Open handles pin their file: pressure must evict only closed files."""
    fs = SeaFS(make_config(str(tmp_path), capacity=2 * F))
    busy_path = os.path.join(fs.mount, "busy.bin")
    busy = fs.open(busy_path, "wb")
    busy.write(b"b" * F)
    busy.flush()
    fs.write_bytes(os.path.join(fs.mount, "idle.bin"), b"i" * F)
    # cache is at capacity; the next write evicts idle.bin, not the open file
    fs.write_bytes(os.path.join(fs.mount, "next.bin"), b"n" * F)
    assert fs.where(os.path.join(fs.mount, "idle.bin")) is None
    assert fs.where(busy_path) == "tmpfs"
    busy.close()
    assert fs.where(busy_path) == "tmpfs"


def test_all_busy_falls_back_to_base_tier(tmp_path):
    """When every cached file is pinned by an open handle nothing can be
    evicted, and the write must fall back to the persistent base tier."""
    fs = SeaFS(make_config(str(tmp_path), capacity=2 * F))
    handles = [fs.open(os.path.join(fs.mount, f"pin{i}.bin"), "wb") for i in range(2)]
    for h in handles:
        h.write(b"p" * F)
        h.flush()
    p = os.path.join(fs.mount, "spill.bin")
    fs.write_bytes(p, b"s" * F)
    assert fs.where(p) == "pfs"
    for h in handles:
        h.close()
    got, want = fs.hierarchy.ledger.verify(fs.hierarchy.tiers[0].roots[0])
    assert got == want


def test_flush_pending_files_are_not_eviction_candidates(tmp_path):
    """COPY/MOVE files awaiting flush must never be dropped by room-making
    (only KEEP/REMOVE modes are candidates)."""
    fs = SeaFS(
        make_config(str(tmp_path), capacity=2 * F, flushlist=("*.out",))
    )
    fs.write_bytes(os.path.join(fs.mount, "pending.out"), b"o" * F)  # COPY, unflushed
    fs.write_bytes(os.path.join(fs.mount, "idle.bin"), b"i" * F)  # KEEP
    fs.write_bytes(os.path.join(fs.mount, "new.bin"), b"n" * F)
    assert fs.where(os.path.join(fs.mount, "pending.out")) == "tmpfs"
    assert fs.where(os.path.join(fs.mount, "idle.bin")) is None


def test_retry_loop_reselects_after_lost_races(tmp_path):
    """The write path re-selects up to 8 times when admission is lost to a
    concurrent writer; a late win must still land on the fast tier."""
    fs = SeaFS(make_config(str(tmp_path), capacity=8 * F, lru_evict=False))
    orig = fs.policy.acquire_write
    calls = {"n": 0}

    def flaky(tier, root):
        calls["n"] += 1
        if calls["n"] < 8:
            return False, None  # lost the admission race
        return orig(tier, root)

    fs.policy.acquire_write = flaky
    p = os.path.join(fs.mount, "late.bin")
    fs.write_bytes(p, b"l" * 64)
    assert calls["n"] == 8
    assert fs.where(p) == "tmpfs"


def test_retry_loop_exhaustion_falls_back_to_base(tmp_path):
    """8 straight lost races give up on the cache: the base tier is the
    unconditional fallback and the write must not be dropped."""
    fs = SeaFS(make_config(str(tmp_path), capacity=8 * F, lru_evict=False))
    fs.policy.acquire_write = lambda tier, root: (False, None)
    p = os.path.join(fs.mount, "exhausted.bin")
    fs.write_bytes(p, b"e" * 64)
    assert fs.where(p) == "pfs"
    got, want = fs.hierarchy.ledger.verify(fs.hierarchy.base.roots[0])
    assert got == want == 64
