"""Adaptive read path: predictive readahead + the open fast path.

Covers the predictor models (numeric runs, successor graph, confidence
gate, depth adaptation, cancellation), end-to-end speculative staging
with ledger admission, eviction shielding of predicted-hot keys, the
read-hit open fast path (counters, toggles, writer diversion), and the
concurrent readers/writers/mover stress required by ISSUE 5."""

import os
import random
import threading
import time

import pytest

from repro.core import Sea, SeaConfig, SeaFS, TierSpec
from repro.core.flusher import Flusher
from repro.core.lists import Mode


def make_config(tmp_path, **kw):
    defaults = dict(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 16,
        n_procs=2,
        readahead=True,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


def wait_until(cond, timeout=5.0, interval=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


def seed_base_shards(fs, n, nbytes=4096, prefix="shard"):
    """Write n sequential shards and leave them ONLY on the base tier."""
    for i in range(n):
        p = os.path.join(fs.mount, f"{prefix}_{i:05d}.bin")
        fs.write_bytes(p, bytes([i % 256]) * nbytes)
        fs.persist(p)
    for tier in fs.hierarchy.cache_tiers:
        tier.wipe()
    fs.resolver.invalidate_all()


# ---------------------------------------------------------------- predictor
def test_numeric_run_detection_and_prediction(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    pf = fs.prefetcher
    now = time.monotonic()
    assert pf._update_numeric("a/shard_00001.npy", now) == []
    assert pf._update_numeric("a/shard_00002.npy", now) == []  # stride set
    preds = pf._update_numeric("a/shard_00003.npy", now)  # confirmed
    assert [p[0] for p in preds] == ["a/shard_00004.npy"]  # depth starts at 1


def test_strided_sequences_predicted(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    pf = fs.prefetcher
    now = time.monotonic()
    for i in (0, 2, 4):
        preds = pf._update_numeric(f"s_{i:04d}.bin", now)
    assert [p[0] for p in preds] == ["s_0006.bin"]


def test_stride_change_resets_confidence(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    pf = fs.prefetcher
    now = time.monotonic()
    for i in (1, 2, 3):
        pf._update_numeric(f"k_{i:03d}", now)
    # jump breaks the run: no prediction until the new stride is confirmed
    assert pf._update_numeric("k_042", now) == []
    assert pf._update_numeric("k_050", now) == []  # stride 8, unconfirmed
    assert [p[0] for p in pf._update_numeric("k_058", now)] == ["k_066"]


def test_random_order_yields_no_numeric_predictions(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    pf = fs.prefetcher
    now = time.monotonic()
    rng = random.Random(7)
    order = rng.sample(range(500), 60)
    preds = []
    for i in order:
        preds += pf._update_numeric(f"r_{i:04d}", now)
    # equal consecutive deltas in a 60-draw random sample are rare
    assert len(preds) <= 3


def test_successor_graph_predicts_repeated_transitions(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    pf = fs.prefetcher
    for _ in range(3):
        pf._update_successor("alpha")
        pf._update_successor("beta")
        pf._update_successor("gamma")
    pf._update_successor("gamma", predict=False)
    assert [p[0] for p in pf._update_successor("alpha")] == ["beta"]


def test_confidence_gate_blocks_short_runs(tmp_path):
    fs = SeaFS(make_config(tmp_path, readahead_min_confidence=0.9))
    pf = fs.prefetcher
    now = time.monotonic()
    preds = []
    for i in range(8):  # run length 7: confidence 1-1/7 ~ 0.857 < 0.9
        preds += pf._update_numeric(f"c_{i:03d}", now)
    assert preds == []
    for i in range(8, 13):  # length 12: 1-1/12 ~ 0.92 >= 0.9
        preds += pf._update_numeric(f"c_{i:03d}", now)
    assert preds


def test_config_validation():
    with pytest.raises(ValueError):
        SeaConfig(
            mount="/tmp/x",
            tiers=[TierSpec(name="b", roots=("/tmp/b",), persistent=True)],
            readahead_depth=0,
        )
    with pytest.raises(ValueError):
        SeaConfig(
            mount="/tmp/x",
            tiers=[TierSpec(name="b", roots=("/tmp/b",), persistent=True)],
            readahead_min_confidence=1.5,
        )


# ------------------------------------------------------- speculative staging
def test_sequential_reads_stage_ahead_and_hit(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    seed_base_shards(fs, 12)
    for i in range(12):
        p = os.path.join(fs.mount, f"shard_{i:05d}.bin")
        with fs.open(p, "rb") as f:
            assert f.read() == bytes([i]) * 4096
        # per-block "compute": the window the predictor stages under
        # (a tight loop would outrun speculation by design)
        time.sleep(0.03)
    fs.prefetcher.stop()
    snap = fs.telemetry.snapshot()
    assert snap["readahead_predictions"] > 0
    assert snap["readahead_staged_files"] >= 3
    assert snap["readahead_hits"] >= 3
    # staged replicas really live on the cache tier and are ledger-visible
    cache = fs.hierarchy.cache_tiers[0]
    got, want = fs.hierarchy.ledger.verify(cache.roots[0])
    assert got == want


def test_depth_widens_with_hits(tmp_path):
    fs = SeaFS(make_config(tmp_path, readahead_depth=4))
    seed_base_shards(fs, 24)
    for i in range(24):
        p = os.path.join(fs.mount, f"shard_{i:05d}.bin")
        with fs.open(p, "rb") as f:
            f.read()
        time.sleep(0.02)
    assert wait_until(
        lambda: any(r.depth > 1 for r in fs.prefetcher._runs.values())
    )
    fs.prefetcher.stop()


def test_random_access_stages_and_wastes_little(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    seed_base_shards(fs, 24)
    rng = random.Random(3)
    order = list(range(24))
    rng.shuffle(order)
    for i in order:
        p = os.path.join(fs.mount, f"shard_{i:05d}.bin")
        with fs.open(p, "rb") as f:
            f.read()
    time.sleep(0.3)  # let in-flight speculation settle
    fs.prefetcher.stop()  # settles pending predictions as waste
    snap = fs.telemetry.snapshot()
    staged = snap["readahead_staged_bytes"]
    wasted = snap["readahead_wasted_bytes"]
    assert wasted <= max(0.2 * staged, 0)


def test_direction_change_cancels_pending(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    pf = fs.prefetcher
    # establish an ascending run whose predictions cannot stage (the
    # keys don't exist), so they stay pending
    for i in (1, 2, 3, 4):
        pf._observe_one(f"ghost_{i:04d}")
    assert wait_until(lambda: pf.pending_count() > 0)
    pf._observe_one("ghost_0002")  # direction change: descending
    assert pf.pending_count() == 0
    fs.prefetcher.stop()


def test_stop_settles_pending_as_waste(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    pf = fs.prefetcher
    for i in (1, 2, 3):
        pf._observe_one(f"ghost_{i:04d}")
    assert wait_until(lambda: pf.pending_count() > 0)
    pf.stop()
    assert pf.pending_count() == 0


def test_disabled_prefetcher_is_inert(tmp_path):
    fs = SeaFS(make_config(tmp_path, readahead=False))
    seed_base_shards(fs, 6)
    for i in range(6):
        with fs.open(os.path.join(fs.mount, f"shard_{i:05d}.bin"), "rb") as f:
            f.read()
    assert fs.prefetcher._thread is None
    snap = fs.telemetry.snapshot()
    assert snap["readahead_predictions"] == 0
    assert snap["readahead_staged_bytes"] == 0
    assert not fs.prefetcher.is_hot("shard_00001.bin")


# ------------------------------------------------------- eviction shielding
def test_flusher_defers_evict_of_predicted_hot_keys(tmp_path):
    cfg = make_config(tmp_path, evictlist=("hotkey.bin",))
    fs = SeaFS(cfg)
    flusher = Flusher(fs)
    p = os.path.join(fs.mount, "hotkey.bin")
    fs.write_bytes(p, b"h" * 128)  # REMOVE mode, sits in cache
    fs.prefetcher._recent["hotkey.bin"] = time.monotonic()  # mark hot
    assert flusher.process("hotkey.bin") is Mode.REMOVE
    assert fs.where(p) == "tmpfs"  # evict was deferred, not executed
    fs.prefetcher._recent.clear()  # hotness gone
    flusher.process("hotkey.bin")
    assert fs.where(p) is None  # now evicted


def test_drain_evicts_hot_keys_anyway(tmp_path):
    cfg = make_config(tmp_path, evictlist=("hotkey.bin",))
    with Sea(cfg) as sea:
        fs = sea.fs
        p = os.path.join(fs.mount, "hotkey.bin")
        fs.write_bytes(p, b"h" * 128)
        fs.prefetcher._recent["hotkey.bin"] = time.monotonic()
    # shutdown drained: REMOVE-mode files must be gone despite hotness
    fs2 = SeaFS(make_config(tmp_path))
    assert fs2.where(os.path.join(fs2.mount, "hotkey.bin")) is None


def test_lru_evicts_cold_before_predicted_hot(tmp_path):
    F = 1 << 10
    cfg = make_config(
        tmp_path, lru_evict=True, max_file_size=F, n_procs=1
    )
    cfg.tiers[0].capacity = 2 * F
    fs = SeaFS(cfg)
    fs.write_bytes(os.path.join(fs.mount, "hot.bin"), b"h" * F)
    fs.write_bytes(os.path.join(fs.mount, "cold.bin"), b"c" * F)
    # hot.bin is older (LRU would pick it) but predicted-hot
    fs._access_clock["hot.bin"] = 1.0
    fs._access_clock["cold.bin"] = 2.0
    fs.prefetcher._recent["hot.bin"] = time.monotonic()
    fs.write_bytes(os.path.join(fs.mount, "new.bin"), b"n" * F)
    assert fs.where(os.path.join(fs.mount, "hot.bin")) == "tmpfs"
    assert fs.where(os.path.join(fs.mount, "cold.bin")) is None


# ------------------------------------------------------------ open fast path
def test_fast_path_serves_warm_rereads(tmp_path):
    fs = SeaFS(make_config(tmp_path, readahead=False))
    p = os.path.join(fs.mount, "warm.bin")
    fs.write_bytes(p, b"w" * 256)
    for _ in range(10):
        with fs.open(p, "rb") as f:
            assert f.read() == b"w" * 256
    snap = fs.telemetry.snapshot()
    assert snap["fastpath_opens"] >= 8
    # batched per-thread read counters fold into the per-tier view
    assert snap["tiers"]["tmpfs"]["bytes_read"] >= 8 * 256


def test_fast_path_toggle_restores_pr4_path(tmp_path):
    fs = SeaFS(make_config(tmp_path, open_fast_path=False, readahead=False))
    p = os.path.join(fs.mount, "warm.bin")
    fs.write_bytes(p, b"w" * 256)
    for _ in range(5):
        with fs.open(p, "rb") as f:
            assert f.read() == b"w" * 256
    assert fs.telemetry.snapshot()["fastpath_opens"] == 0


def test_fast_path_respects_strict_verify_window(tmp_path):
    fs = SeaFS(make_config(tmp_path, resolver_verify_window_s=0.0,
                           readahead=False))
    p = os.path.join(fs.mount, "warm.bin")
    fs.write_bytes(p, b"w" * 256)
    for _ in range(5):
        with fs.open(p, "rb") as f:
            f.read()
    # window 0 = verify every hit: the lock-free path must never serve
    assert fs.telemetry.snapshot()["fastpath_opens"] == 0


def test_fast_path_diverts_while_writer_open(tmp_path):
    fs = SeaFS(make_config(tmp_path, readahead=False))
    p = os.path.join(fs.mount, "rw.bin")
    fs.write_bytes(p, b"x" * 128)
    with fs.open(p, "rb") as f:  # prime the trust window
        f.read()
    before = fs.telemetry.snapshot()["fastpath_opens"]
    w = fs.open(p, "wb")
    try:
        with fs.open(p, "rb") as f:
            f.read()
        assert fs.telemetry.snapshot()["fastpath_opens"] == before
    finally:
        w.close()


def test_fast_path_relative_and_dotted_paths_still_route(tmp_path, monkeypatch):
    """Unnormalized spellings must fall back to the abspath slow path and
    resolve to the same file — never misroute."""
    fs = SeaFS(make_config(tmp_path, readahead=False))
    p = os.path.join(fs.mount, "norm.bin")
    fs.write_bytes(p, b"n" * 64)
    dotted = os.path.join(fs.mount, ".", "norm.bin")
    with fs.open(dotted, "rb") as f:
        assert f.read() == b"n" * 64
    monkeypatch.chdir(fs.mount)
    with fs.open("norm.bin", "rb") as f:
        assert f.read() == b"n" * 64


def test_fast_path_heals_after_external_move(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "mv.bin")
    fs.write_bytes(p, b"m" * 64)
    with fs.open(p, "rb") as f:
        f.read()
    # external process moves the file cache->base (flusher MOVE analogue)
    key = "mv.bin"
    cached = fs.hierarchy.cache_tiers[0].locate(key)
    base = os.path.join(fs.hierarchy.base.roots[0], key)
    os.makedirs(os.path.dirname(base), exist_ok=True)
    os.replace(cached, base)
    with fs.open(p, "rb") as f:  # fast path ENOENT -> slow path heals
        assert f.read() == b"m" * 64


def test_fast_path_stress_no_partial_no_unknown_content(tmp_path):
    """ISSUE 5 satellite: fast-path hits under concurrent writers and
    flusher MOVE migrations must never observe a half-committed write or
    a mid-flush move — every read returns one complete committed
    generation (the zero-stale-reads discipline of test_resolver)."""
    cfg = make_config(
        tmp_path, flushlist=("hot/*",), evictlist=("hot/*",), readahead=False
    )
    n_keys, gens, size = 6, 25, 1024
    errors: list = []
    with Sea(cfg) as sea:
        fs = sea.fs
        valid = {i: set() for i in range(n_keys)}
        stop = threading.Event()

        def writer(i):
            try:
                for g in range(gens):
                    data = bytes([g % 256]) * (size // 2) + bytes([i]) * (
                        size // 2
                    )
                    tmp = os.path.join(fs.mount, f"hot/t{i}_{g}.bin")
                    dst = os.path.join(fs.mount, f"hot/k{i}.bin")
                    fs.write_bytes(tmp, data)
                    valid[i].add(data)  # registered BEFORE it becomes visible
                    fs.rename(tmp, dst)
            except Exception as e:  # pragma: no cover
                errors.append(e)

        def reader():
            try:
                while not stop.is_set():
                    for i in range(n_keys):
                        p = os.path.join(fs.mount, f"hot/k{i}.bin")
                        try:
                            with fs.open(p, "rb") as f:
                                got = f.read()
                        except FileNotFoundError:
                            continue  # mid-move window may miss…
                        if len(got) != size or got not in valid[i]:
                            errors.append(
                                AssertionError(
                                    f"k{i}: read {len(got)} bytes, "
                                    f"known={got in valid[i]}"
                                )
                            )
            except Exception as e:  # pragma: no cover
                errors.append(e)

        writers = [
            threading.Thread(target=writer, args=(i,)) for i in range(n_keys)
        ]
        readers = [threading.Thread(target=reader) for _ in range(3)]
        for t in writers + readers:
            t.start()
        for t in writers:
            t.join()
        stop.set()
        for t in readers:
            t.join()
        assert not errors
        assert fs.telemetry.snapshot()["fastpath_opens"] > 0
    # after drain, every key holds its final generation on the base tier
    fs2 = SeaFS(cfg)
    for i in range(n_keys):
        p = os.path.join(fs2.mount, f"hot/k{i}.bin")
        assert fs2.where(p) == "pfs"
        got = fs2.read_bytes(p)
        assert got == bytes([(gens - 1) % 256]) * (size // 2) + bytes(
            [i]
        ) * (size // 2)


def test_data_pipeline_relies_on_predictor(tmp_path):
    """With readahead on, the pipeline's bespoke staging is dropped and
    the predictor drives staging off the sequential shard opens —
    batches must be identical either way."""
    from repro.data.pipeline import DataPipeline, write_dataset

    cfg = make_config(tmp_path, max_file_size=1 << 22)
    with Sea(cfg) as sea:
        write_dataset(sea, "c", n_shards=5, tokens_per_shard=4096,
                      vocab_size=97)
        for tier in sea.fs.hierarchy.cache_tiers:
            tier.wipe()
        sea.fs.resolver.invalidate_all()
        pipe = DataPipeline(sea, "c", batch_size=2, seq_len=32,
                            evict_consumed=False)
        batches = list(pipe)
        pipe.close()
        assert len(batches) == (5 * 4096) // (2 * 33)
        assert pipe.stats.cache_misses > 0
        # the numbered shard sequence is exactly what the predictor eats
        assert wait_until(
            lambda: sea.fs.telemetry.readahead_predictions > 0
        )


# ------------------------------------------------------------- simulator
def test_simulator_readahead_overlaps_cold_reads():
    from repro.core.model import ClusterSpec, MiB, Workload
    from repro.core.simulator import Simulator

    cl = ClusterSpec(c=2, p=2)
    w = Workload(B=16, F=256 * MiB, n=2)
    kw = dict(compute_s_per_iter=0.1)
    base = Simulator(cl, w, "sea", **kw).run()
    ra = Simulator(cl, w, "sea", readahead=True, **kw).run()
    assert ra.readahead_hits > 0
    assert ra.readahead_staged >= ra.readahead_hits
    # cold-input stalls move off the critical path: the app finishes
    # strictly earlier, and staging hides under compute so the full
    # drain does too
    assert ra.app_done_s < base.app_done_s
    assert ra.makespan < base.makespan
