"""Tests for the namespace-resolver layer (cached key→location index).

Covers: hit-path behaviour (no probe cascade), verify-on-hit fallback
under cross-process moves, negative-cache expiry, invalidation under
concurrent flusher moves/evicts (zero stale reads), the per-directory
child index, and the satellite bugfixes (stat error path, remove of all
replicas).
"""

import os
import shutil
import threading
import time

import pytest

from repro.core import Sea, SeaConfig, SeaFS, TierSpec
from repro.core.flusher import Flusher
from repro.core.ledger import LEDGER_DIRNAME


def make_config(tmp_path, **kw):
    defaults = dict(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="disk", roots=(str(tmp_path / "d0"), str(tmp_path / "d1"))),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 16,
        n_procs=2,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


class _CountingLocate:
    """Wraps Hierarchy.locate to count full probe cascades."""

    def __init__(self, hierarchy):
        self.hierarchy = hierarchy
        self.calls = 0
        self._orig = hierarchy.locate

    def __enter__(self):
        def counting(relpath):
            self.calls += 1
            return self._orig(relpath)

        self.hierarchy.locate = counting
        return self

    def __exit__(self, *exc):
        self.hierarchy.locate = self._orig


# ---------------------------------------------------------------- hit path
def test_hit_path_skips_probe_cascade(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "a/hot.bin")
    fs.write_bytes(p, b"x" * 64)
    fs.read_bytes(p)  # warm
    with _CountingLocate(fs.hierarchy) as cl:
        for _ in range(10):
            assert fs.read_bytes(p) == b"x" * 64
        assert cl.calls == 0  # every resolution served by the index
    assert fs.telemetry.resolver_hits >= 10


def test_resolver_disabled_restores_seed_cascade(tmp_path):
    fs = SeaFS(make_config(tmp_path, resolver_cache=False))
    p = os.path.join(fs.mount, "cold.bin")
    fs.write_bytes(p, b"y" * 16)
    with _CountingLocate(fs.hierarchy) as cl:
        for _ in range(3):
            assert fs.read_bytes(p) == b"y" * 16
        # two full cascades per read, like the seed (the stripe-manifest
        # existence probe plus the file itself)
        assert cl.calls == 6
    assert fs.telemetry.resolver_hits == 0


# ------------------------------------------------------- verify-on-hit
def test_cross_process_move_falls_back_via_verify(tmp_path):
    """Another process's flusher MOVEs the file cache→base without telling
    this resolver: the cached hit must verify-fail and re-scan, never
    return a dead path or stale data."""
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "moved.bin")
    fs.write_bytes(p, b"v1")
    assert fs.where(p) == "tmpfs"  # cached on the fast tier
    # simulate the external mover: copy to base, remove from cache
    src = os.path.join(cfg.tiers[0].roots[0], "moved.bin")
    dst = os.path.join(cfg.tiers[-1].roots[0], "moved.bin")
    shutil.copyfile(src, dst)
    os.remove(src)
    assert fs.read_bytes(p) == b"v1"
    assert fs.where(p) == "pfs"
    assert fs.telemetry.resolver_verify_fails >= 1


def test_external_delete_detected_by_verify(tmp_path):
    # window 0 = strict verify-on-hit: every hit lstats the cached path,
    # so even pure existence answers see the external delete immediately
    cfg = make_config(tmp_path, resolver_verify_window_s=0.0)
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "gone.bin")
    fs.write_bytes(p, b"z")
    os.remove(os.path.join(cfg.tiers[0].roots[0], "gone.bin"))
    assert not fs.exists(p)
    with pytest.raises(FileNotFoundError):
        fs.read_bytes(p)


# ------------------------------------------------------- negative cache
def test_negative_cache_absorbs_miss_storms(tmp_path):
    fs = SeaFS(make_config(tmp_path, resolver_negative_ttl_s=30.0))
    p = os.path.join(fs.mount, "nope.bin")
    assert not fs.exists(p)  # full scan, caches the negative
    with _CountingLocate(fs.hierarchy) as cl:
        for _ in range(10):
            assert not fs.exists(p)
        assert cl.calls == 0
    assert fs.telemetry.resolver_negative_hits >= 10


def test_negative_cache_expires_after_external_create(tmp_path):
    cfg = make_config(tmp_path, resolver_negative_ttl_s=0.05)
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "late.bin")
    assert not fs.exists(p)  # negative entry recorded
    # an external process creates the file directly on a root
    with open(os.path.join(cfg.tiers[-1].roots[0], "late.bin"), "wb") as f:
        f.write(b"here")
    time.sleep(0.06)  # > ttl
    assert fs.exists(p)
    assert fs.read_bytes(p) == b"here"


def test_open_never_spuriously_misses_through_negative_cache(tmp_path):
    """A fresh negative entry must not make open()/stat() raise ENOENT
    for a file another process created moments ago: the miss path does
    one authoritative scan before falling back."""
    cfg = make_config(tmp_path, resolver_negative_ttl_s=30.0)
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "racer.bin")
    assert not fs.exists(p)  # negative entry, trusted for 30s
    with open(os.path.join(cfg.tiers[0].roots[0], "racer.bin"), "wb") as f:
        f.write(b"just created")
    assert fs.read_bytes(p) == b"just created"  # open bypasses the negative
    assert fs.stat(p).st_size == len(b"just created")


def test_write_clears_negative_entry_immediately(tmp_path):
    fs = SeaFS(make_config(tmp_path, resolver_negative_ttl_s=30.0))
    p = os.path.join(fs.mount, "soon.bin")
    assert not fs.exists(p)  # negative cached for 30s
    fs.write_bytes(p, b"now")  # placement must overwrite the negative
    assert fs.exists(p)
    assert fs.read_bytes(p) == b"now"


# ------------------------------------------- invalidation on mutation paths
def test_remove_invalidates_and_removes_all_replicas(tmp_path):
    """COPY mode leaves a base replica next to the cache copy; remove()
    must take out both atomically (satellite: the seed probed per-tier)."""
    cfg = make_config(tmp_path, flushlist=("*.out",))
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "r.out")
    fs.write_bytes(p, b"r" * 32)
    fl.scan()
    fl._process_all_sync()
    # two replicas now: tmpfs (cache) + pfs (COPY flush)
    assert os.path.exists(os.path.join(cfg.tiers[0].roots[0], "r.out"))
    assert os.path.exists(os.path.join(cfg.tiers[-1].roots[0], "r.out"))
    fs.remove(p)
    for tier in cfg.tiers:
        for root in tier.roots:
            assert not os.path.exists(os.path.join(root, "r.out"))
    assert not fs.exists(p)
    assert fs.telemetry.resolver_invalidations >= 1


def test_remove_catches_multi_root_duplicates_on_one_tier(tmp_path):
    """A tier holding copies on two of its roots (external duplication):
    the seed's per-tier locate() removed only the first root's copy."""
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    for root in cfg.tiers[1].roots:  # both disk roots
        with open(os.path.join(root, "dup.bin"), "wb") as f:
            f.write(b"d")
    p = os.path.join(fs.mount, "dup.bin")
    fs.remove(p)
    for root in cfg.tiers[1].roots:
        assert not os.path.exists(os.path.join(root, "dup.bin"))


def test_rename_invalidates_source_and_notes_destination(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    a = os.path.join(fs.mount, "a.bin")
    b = os.path.join(fs.mount, "b.bin")
    fs.write_bytes(a, b"abc")
    fs.read_bytes(a)  # warm the index on the source
    fs.rename(a, b)
    assert not fs.exists(a)
    assert fs.read_bytes(b) == b"abc"


def test_stat_missing_file_names_mount_path(tmp_path):
    """Satellite: the FileNotFoundError must carry the user's path, not
    the translated base-tier path."""
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "absent/sub.bin")
    with pytest.raises(FileNotFoundError) as ei:
        fs.stat(p)
    assert ei.value.filename == p
    with pytest.raises(FileNotFoundError) as ei:
        fs.getsize(p)
    assert ei.value.filename == p
    base_root = fs.hierarchy.base.roots[0]
    assert base_root not in str(ei.value)


def test_remove_missing_file_names_mount_path(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "never.bin")
    with pytest.raises(FileNotFoundError) as ei:
        fs.remove(p)
    assert ei.value.filename == p


# ------------------------------------------------------- concurrent movers
def test_zero_stale_reads_under_concurrent_flusher_moves(tmp_path):
    """Writers produce MOVE-mode files while the async flusher migrates
    them cache→base and readers hammer resolution: every read must return
    the exact bytes written — no stale reads, no dead cached paths."""
    cfg = make_config(tmp_path, flushlist=("mv/*",), evictlist=("mv/*",))
    errors: list = []
    n_keys = 40
    with Sea(cfg) as sea:
        fs = sea.fs
        payloads = {}

        def writer():
            try:
                for i in range(n_keys):
                    data = bytes([i % 256]) * 128
                    p = os.path.join(fs.mount, f"mv/k{i}.bin")
                    fs.write_bytes(p, data)
                    payloads[i] = data
            except Exception as e:  # pragma: no cover
                errors.append(e)

        def reader():
            try:
                for _ in range(200):
                    for i in list(payloads):
                        p = os.path.join(fs.mount, f"mv/k{i}.bin")
                        try:
                            got = fs.read_bytes(p)
                        except FileNotFoundError:
                            continue  # mid-move window is allowed to miss…
                        if got != payloads[i]:  # …but NEVER to be stale
                            errors.append(
                                AssertionError(f"stale read of k{i}: {got[:8]!r}")
                            )
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=writer)] + [
            threading.Thread(target=reader) for _ in range(3)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    # after drain every file lives exactly once, on the base tier
    fs2 = SeaFS(cfg)
    for i in range(n_keys):
        p = os.path.join(fs2.mount, f"mv/k{i}.bin")
        assert fs2.where(p) == "pfs"
        assert fs2.read_bytes(p) == bytes([i % 256]) * 128


def test_lru_eviction_invalidates_index(tmp_path):
    cfg = make_config(tmp_path, lru_evict=True, max_file_size=1 << 10, n_procs=1)
    cfg.tiers[0].capacity = 3 << 10
    cfg.tiers[1].capacity = 1
    fs = SeaFS(cfg)
    keys = ["a", "b", "c"]
    for k in keys:
        fs.write_bytes(os.path.join(fs.mount, f"{k}.bin"), k.encode() * 1024)
        fs.read_bytes(os.path.join(fs.mount, f"{k}.bin"))  # warm the index
    fs.write_bytes(os.path.join(fs.mount, "d.bin"), b"d" * 1024)
    # a was LRU-evicted: the index must not resurrect it
    assert fs.where(os.path.join(fs.mount, "a.bin")) is None
    assert fs.where(os.path.join(fs.mount, "d.bin")) == "tmpfs"


# ------------------------------------------------------- directory index
def _age_dirs(cfg, key: str, seconds: float = 10.0) -> None:
    """Backdate every tier copy of a virtual directory: freshly-mutated
    directories are deliberately not cached (same-mtime-tick races on
    coarse-granularity filesystems), stable ones are."""
    past = time.time() - seconds
    for tier in cfg.tiers:
        for root in tier.roots:
            p = os.path.join(root, key)
            if os.path.isdir(p):
                os.utime(p, (past, past))


def test_listdir_served_from_child_index(tmp_path):
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    for name in ("x.bin", "y.bin"):
        fs.write_bytes(os.path.join(fs.mount, "dir", name), b"1")
    _age_dirs(cfg, "dir")  # stable directory: eligible for the child index
    d = os.path.join(fs.mount, "dir")
    assert fs.listdir(d) == ["x.bin", "y.bin"]  # cold: walks + caches
    before = fs.telemetry.dir_index_hits
    assert fs.listdir(d) == ["x.bin", "y.bin"]  # warm: signature verifies
    assert fs.telemetry.dir_index_hits == before + 1


def test_fresh_directory_not_cached(tmp_path):
    """A directory mutated within the racy-mtime window must not enter
    the child index: a same-tick create would be invisible to the
    signature check."""
    fs = SeaFS(make_config(tmp_path))
    fs.write_bytes(os.path.join(fs.mount, "hot/a.bin"), b"a")
    d = os.path.join(fs.mount, "hot")
    assert fs.listdir(d) == ["a.bin"]
    assert fs.listdir(d) == ["a.bin"]  # still a walk, not an index hit
    assert fs.telemetry.dir_index_hits == 0


def test_invalidation_drops_parent_dir_listing(tmp_path):
    """An in-process mutation must invalidate ancestor dir listings
    immediately — not wait for the mtime signature to catch it."""
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    fs.write_bytes(os.path.join(fs.mount, "d/a.bin"), b"a")
    _age_dirs(cfg, "d")
    d = os.path.join(fs.mount, "d")
    assert fs.listdir(d) == ["a.bin"]
    assert fs.listdir(d) == ["a.bin"]  # cached now
    fs.remove(os.path.join(fs.mount, "d/a.bin"))
    # backdate again so a STALE cache entry would be served if the
    # invalidation had not dropped it
    _age_dirs(cfg, "d")
    assert fs.listdir(d) == []


def test_listdir_detects_external_create(tmp_path):
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    fs.write_bytes(os.path.join(fs.mount, "dir/a.bin"), b"a")
    d = os.path.join(fs.mount, "dir")
    assert fs.listdir(d) == ["a.bin"]
    # external process drops a file into another tier's root: the dir
    # mtime bump must fail the signature check and re-walk
    ext_dir = os.path.join(cfg.tiers[-1].roots[0], "dir")
    os.makedirs(ext_dir, exist_ok=True)
    with open(os.path.join(ext_dir, "b.bin"), "wb") as f:
        f.write(b"b")
    assert fs.listdir(d) == ["a.bin", "b.bin"]


def test_listdir_union_discards_ledger_dirname(tmp_path):
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    fs.write_bytes(os.path.join(fs.mount, "data.bin"), b"1")
    # the shared-ledger bookkeeping store lives inside a root
    os.makedirs(
        os.path.join(cfg.tiers[-1].roots[0], LEDGER_DIRNAME), exist_ok=True
    )
    listing = fs.listdir(fs.mount)
    assert LEDGER_DIRNAME not in listing
    assert "data.bin" in listing
    # …and stays discarded when served from the warm child index
    listing = fs.listdir(fs.mount)
    assert LEDGER_DIRNAME not in listing


def test_listdir_hides_inflight_flush_staging(tmp_path):
    """An in-flight flush stages to <dst>.sea_tmp before its atomic
    rename; the staging file must never leak into the listdir union."""
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    fs.write_bytes(os.path.join(fs.mount, "out/a.bin"), b"a")
    staging = os.path.join(cfg.tiers[-1].roots[0], "out")
    os.makedirs(staging, exist_ok=True)
    with open(os.path.join(staging, "a.bin.sea_tmp"), "wb") as f:
        f.write(b"partial")
    assert fs.listdir(os.path.join(fs.mount, "out")) == ["a.bin"]


def test_exists_and_isdir_for_virtual_directories(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    fs.write_bytes(os.path.join(fs.mount, "deep/nest/f.bin"), b"f")
    assert fs.exists(os.path.join(fs.mount, "deep"))
    assert fs.isdir(os.path.join(fs.mount, "deep/nest"))
    assert not fs.isdir(os.path.join(fs.mount, "deep/nest/f.bin"))
    assert not fs.isdir(os.path.join(fs.mount, "missing"))


# ------------------------------------------------------- flusher interplay
def test_flusher_move_then_read_returns_base_copy(tmp_path):
    cfg = make_config(tmp_path, flushlist=("*.out",), evictlist=("*.out",))
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "r.out")
    fs.write_bytes(p, b"r" * 32)
    fs.read_bytes(p)  # warm the index on the tmpfs copy
    fl.scan()
    fl._process_all_sync()  # MOVE: tmpfs copy gone, base copy exists
    assert fs.where(p) == "pfs"
    assert fs.read_bytes(p) == b"r" * 32
    assert fs.telemetry.resolver_invalidations >= 1


def test_prefetch_notes_staged_location(tmp_path):
    cfg = make_config(tmp_path, prefetchlist=("inputs/*",))
    base = cfg.tiers[-1].roots[0]
    os.makedirs(os.path.join(base, "inputs"), exist_ok=True)
    with open(os.path.join(base, "inputs/in.bin"), "wb") as f:
        f.write(b"i" * 64)
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    fl.prefetch()
    p = os.path.join(fs.mount, "inputs/in.bin")
    with _CountingLocate(fs.hierarchy) as cl:
        assert fs.read_bytes(p) == b"i" * 64
        # the only cascade allowed is the cold stripe-manifest existence
        # probe; the staged file itself was noted, no cascade for it
        assert cl.calls <= 1
    assert fs.where(p) == "tmpfs"
