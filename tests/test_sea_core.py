"""Unit + property tests for the Sea core library (paper §3.1–3.3)."""

import os
import threading

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dependency: property tests skip cleanly
    from _hypothesis_stub import given, settings, st

from repro.core import (
    Mode,
    Sea,
    SeaConfig,
    SeaFS,
    SeaMount,
    TierSpec,
    resolve_mode,
)
from repro.core.flusher import Flusher


def make_config(tmp_path, **kw):
    defaults = dict(
        mount=str(tmp_path / "mount"),
        tiers=[
            TierSpec(name="tmpfs", roots=(str(tmp_path / "t0"),)),
            TierSpec(name="disk", roots=(str(tmp_path / "d0"), str(tmp_path / "d1"))),
            TierSpec(name="pfs", roots=(str(tmp_path / "pfs"),), persistent=True),
        ],
        max_file_size=1 << 16,
        n_procs=2,
    )
    defaults.update(kw)
    return SeaConfig(**defaults)


# ---------------------------------------------------------------- mode table
@pytest.mark.parametrize(
    "flush,evict,expected",
    [
        (("*.out",), (), Mode.COPY),
        ((), ("*.out",), Mode.REMOVE),
        (("*.out",), ("*.out",), Mode.MOVE),
        ((), (), Mode.KEEP),
    ],
)
def test_mode_table(flush, evict, expected):
    """Table 1 of the paper."""
    assert resolve_mode("a/b/x.out", flush, evict) is expected


def test_mode_glob_full_path_and_basename():
    assert resolve_mode("results/iter9/x.npy", ("results/*/*.npy",), ()) is Mode.COPY
    assert resolve_mode("deep/nested/app.log", ("*.npy",), ("*.log",)) is Mode.REMOVE


# ------------------------------------------------------------ placement basics
def test_write_goes_to_fastest_tier(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "a/block.bin")
    fs.write_bytes(p, b"x" * 100)
    assert fs.where(p) == "tmpfs"
    assert fs.read_bytes(p) == b"x" * 100


def test_capacity_spills_to_next_tier(tmp_path):
    cfg = make_config(tmp_path)
    # tmpfs too small for the p*F reservation -> must go to disk
    cfg.tiers[0].capacity = 1 << 10
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "big.bin")
    fs.write_bytes(p, b"y" * 2048)
    assert fs.where(p) == "disk"


def test_capacity_spills_to_base_when_all_full(tmp_path):
    cfg = make_config(tmp_path)
    cfg.tiers[0].capacity = 1
    cfg.tiers[1].capacity = 1
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "big.bin")
    fs.write_bytes(p, b"z" * 4096)
    assert fs.where(p) == "pfs"


def test_reservation_accounts_nprocs_times_filesize(tmp_path):
    """Paper: tier eligible iff free >= n_procs * max_file_size."""
    cfg = make_config(tmp_path, max_file_size=1 << 12, n_procs=4)
    cfg.tiers[0].capacity = (1 << 12) * 3  # room for 3 files, need 4
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "f.bin")
    fs.write_bytes(p, b"q" * 16)
    assert fs.where(p) == "disk"


def test_rewrite_overwrites_in_place(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "f.bin")
    fs.write_bytes(p, b"1" * 8)
    tier0 = fs.where(p)
    fs.write_bytes(p, b"2" * 8)
    assert fs.where(p) == tier0
    assert fs.read_bytes(p) == b"2" * 8
    # exactly one physical copy exists
    copies = [t.locate("f.bin") for t in fs.hierarchy if t.locate("f.bin")]
    assert len(copies) == 1


def test_read_missing_raises_filenotfound(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    with pytest.raises(FileNotFoundError):
        fs.open(os.path.join(fs.mount, "nope.bin"), "rb")


def test_outside_mount_passthrough(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = str(tmp_path / "plain.txt")
    with fs.open(p, "w") as f:
        f.write("hi")
    assert os.path.exists(p)
    assert fs.telemetry.passthrough >= 1


# ------------------------------------------------------------ metadata ops
def test_listdir_union_across_tiers(tmp_path):
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    fs.write_bytes(os.path.join(fs.mount, "dir/a.bin"), b"a")
    # place b directly on pfs (simulates pre-existing input data)
    os.makedirs(os.path.join(cfg.tiers[-1].roots[0], "dir"), exist_ok=True)
    with open(os.path.join(cfg.tiers[-1].roots[0], "dir/b.bin"), "wb") as f:
        f.write(b"b")
    assert fs.listdir(os.path.join(fs.mount, "dir")) == ["a.bin", "b.bin"]


def test_rename_within_mount(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    a = os.path.join(fs.mount, "a.bin")
    b = os.path.join(fs.mount, "b.bin")
    fs.write_bytes(a, b"abc")
    fs.rename(a, b)
    assert not fs.exists(a)
    assert fs.read_bytes(b) == b"abc"


def test_stat_and_getsize(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "s.bin")
    fs.write_bytes(p, b"12345")
    assert fs.getsize(p) == 5
    assert fs.stat(p).st_size == 5


# ------------------------------------------------------------ flusher modes
def test_flush_copy_keeps_cache_copy(tmp_path):
    cfg = make_config(tmp_path, flushlist=("*.out",))
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "r.out")
    fs.write_bytes(p, b"r" * 32)
    fl.scan()
    fl._process_all_sync()
    # on base tier AND still in cache (COPY)
    assert os.path.exists(os.path.join(cfg.tiers[-1].roots[0], "r.out"))
    assert fs.where(p) == "tmpfs"


def test_flush_move_evicts_cache_copy(tmp_path):
    cfg = make_config(tmp_path, flushlist=("*.out",), evictlist=("*.out",))
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "r.out")
    fs.write_bytes(p, b"r" * 32)
    fl.scan()
    fl._process_all_sync()
    assert fs.where(p) == "pfs"  # only the persistent copy remains
    assert fs.read_bytes(p) == b"r" * 32


def test_evict_remove_never_persists(tmp_path):
    cfg = make_config(tmp_path, evictlist=("*.log",))
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "app.log")
    fs.write_bytes(p, b"l" * 32)
    fl.scan()
    fl._process_all_sync()
    assert fs.where(p) is None
    assert not os.path.exists(os.path.join(cfg.tiers[-1].roots[0], "app.log"))


def test_keep_stays_in_cache(tmp_path):
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "keep.bin")
    fs.write_bytes(p, b"k")
    fl.scan()
    fl._process_all_sync()
    assert fs.where(p) == "tmpfs"
    assert not os.path.exists(os.path.join(cfg.tiers[-1].roots[0], "keep.bin"))


def test_async_flusher_end_to_end(tmp_path):
    cfg = make_config(tmp_path, flushlist=("out/*",), evictlist=("out/*", "*.tmp"))
    with Sea(cfg) as sea:
        for i in range(8):
            sea.fs.write_bytes(os.path.join(sea.fs.mount, f"out/f{i}.bin"), b"d" * 64)
            sea.fs.write_bytes(os.path.join(sea.fs.mount, f"scratch_{i}.tmp"), b"t")
    base = cfg.tiers[-1].roots[0]
    for i in range(8):
        assert os.path.exists(os.path.join(base, f"out/f{i}.bin"))
        assert not os.path.exists(os.path.join(base, f"scratch_{i}.tmp"))


def test_flusher_skips_open_files(tmp_path):
    cfg = make_config(tmp_path, flushlist=("*.out",), evictlist=("*.out",))
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    p = os.path.join(fs.mount, "busy.out")
    f = fs.open(p, "wb")
    f.write(b"partial")
    fl.submit("busy.out")
    fl._process_all_sync()
    # still open -> not moved
    assert fs.where(p) == "tmpfs"
    f.close()
    fl._process_all_sync()
    assert fs.where(p) == "pfs"


def test_prefetch_stages_inputs_to_cache(tmp_path):
    cfg = make_config(tmp_path, prefetchlist=("inputs/*",))
    # input data starts on the base tier (within the mountpoint, per paper)
    base = cfg.tiers[-1].roots[0]
    os.makedirs(os.path.join(base, "inputs"), exist_ok=True)
    for i in range(3):
        with open(os.path.join(base, f"inputs/in{i}.bin"), "wb") as f:
            f.write(b"i" * 128)
    fs = SeaFS(cfg)
    fl = Flusher(fs)
    n = fl.prefetch()
    assert n == 3 * 128
    for i in range(3):
        assert fs.where(os.path.join(fs.mount, f"inputs/in{i}.bin")) == "tmpfs"


# ------------------------------------------------------------ interception
def test_seamount_redirects_builtin_open(tmp_path):
    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "inter/x.txt")
    with SeaMount(fs):
        with open(p, "w") as f:
            f.write("hello sea")
        assert os.path.exists(p)
        assert os.path.getsize(p) == 9
        with open(p) as f:
            assert f.read() == "hello sea"
    # the physical file lives on a tier, not under the mountpoint
    assert not os.path.exists(p)
    assert fs.where(p) == "tmpfs"


def test_seamount_numpy_roundtrip(tmp_path):
    """Unmodified numpy code works through interception (reinstrumentation-
    free, the paper's core claim)."""
    import numpy as np

    cfg = make_config(tmp_path)
    fs = SeaFS(cfg)
    p = os.path.join(fs.mount, "arr.npy")
    arr = np.arange(100, dtype=np.int32)
    with SeaMount(fs):
        np.save(p, arr)
        out = np.load(p)
    assert (out == arr).all()
    assert fs.where(p) == "tmpfs"


def test_seamount_restores_builtins(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    orig_open = open
    with SeaMount(fs):
        assert open is not orig_open
    import builtins

    assert builtins.open is orig_open


def test_seamount_isfile_false_for_directories(tmp_path):
    """Tier.locate uses lexists (true for dirs): patched os.path.isfile must
    still report False for directories under the mount."""
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "d/f.txt")
    with SeaMount(fs):
        with open(p, "w") as f:
            f.write("z")
        assert os.path.isfile(p)
        assert not os.path.isfile(os.path.dirname(p))
        assert not os.path.isfile(os.path.join(fs.mount, "missing.txt"))


def test_seamount_handler_errors_propagate(tmp_path):
    """A legitimate error raised by the Sea handler must propagate, not be
    swallowed by the probe guard and silently re-executed on the original."""
    fs = SeaFS(make_config(tmp_path))
    sm = SeaMount(fs)

    def boom(path, *a, **kw):
        raise ValueError("sea handler failure")

    wrapped = sm._path_fn(lambda p, *a, **kw: "orig-ran", boom)
    with pytest.raises(ValueError, match="sea handler failure"):
        wrapped(os.path.join(fs.mount, "x"))
    # outside the mount the original still runs
    assert wrapped(str(tmp_path / "plain")) == "orig-ran"

    def boom2(src, dst, *a, **kw):
        raise ValueError("sea two-path failure")

    wrapped2 = sm._two_path_fn(lambda s, d, *a, **kw: "orig-ran", boom2)
    with pytest.raises(ValueError, match="sea two-path failure"):
        wrapped2(os.path.join(fs.mount, "a"), os.path.join(fs.mount, "b"))
    assert wrapped2(str(tmp_path / "p"), str(tmp_path / "q")) == "orig-ran"


def test_seamount_os_ops(tmp_path):
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, "d/f.txt")
    q = os.path.join(fs.mount, "d/g.txt")
    with SeaMount(fs):
        with open(p, "w") as f:
            f.write("z")
        assert os.path.isfile(p)
        os.replace(p, q)
        assert not os.path.exists(p)
        assert sorted(os.listdir(os.path.dirname(p))) == ["g.txt"]
        os.remove(q)
        assert not os.path.exists(q)


# ------------------------------------------------------------ concurrency
def test_concurrent_writers_thread_safe(tmp_path):
    cfg = make_config(tmp_path, n_procs=8)
    fs = SeaFS(cfg)
    errs = []

    def work(i):
        try:
            for j in range(20):
                p = os.path.join(fs.mount, f"w{i}/f{j}.bin")
                fs.write_bytes(p, bytes([i]) * 256)
                assert fs.read_bytes(p) == bytes([i]) * 256
        except Exception as e:  # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs


# ------------------------------------------------------------ property tests
@settings(max_examples=50, deadline=None)
@given(
    data=st.binary(min_size=0, max_size=4096),
    name=st.from_regex(r"[a-z]{1,8}(/[a-z]{1,8}){0,2}\.(bin|out|log)", fullmatch=True),
)
def test_roundtrip_property(tmp_path_factory, data, name):
    """Whatever Sea places anywhere, reads return identical bytes and the
    file exists on exactly one tier (paper: 'In no instance does it modify
    or alter the data')."""
    tmp_path = tmp_path_factory.mktemp("prop")
    fs = SeaFS(make_config(tmp_path))
    p = os.path.join(fs.mount, name)
    fs.write_bytes(p, data)
    assert fs.read_bytes(p) == data
    key = fs.key_of(p)
    copies = [t for t in fs.hierarchy if t.locate(key)]
    assert len(copies) == 1


@settings(max_examples=100, deadline=None)
@given(
    rel=st.from_regex(r"[a-z]{1,6}(/[a-z]{1,6}){0,3}\.[a-z]{1,4}", fullmatch=True),
    flush=st.booleans(),
    evict=st.booleans(),
)
def test_mode_resolution_total_function(rel, flush, evict):
    """Mode resolution is total and matches Table 1 for any path."""
    fl = (rel,) if flush else ()
    ev = (rel,) if evict else ()
    m = resolve_mode(rel, fl, ev)
    expected = {
        (True, True): Mode.MOVE,
        (True, False): Mode.COPY,
        (False, True): Mode.REMOVE,
        (False, False): Mode.KEEP,
    }[(flush, evict)]
    assert m is expected


def test_lru_evict_makes_room(tmp_path):
    cfg = make_config(tmp_path, lru_evict=True, max_file_size=1 << 10, n_procs=1)
    cfg.tiers[0].capacity = 3 << 10
    cfg.tiers[1].capacity = 1  # disk unusable: spill would go to pfs
    fs = SeaFS(cfg)
    a = os.path.join(fs.mount, "a.bin")
    b = os.path.join(fs.mount, "b.bin")
    c = os.path.join(fs.mount, "c.bin")
    fs.write_bytes(a, b"a" * 1024)
    fs.write_bytes(b, b"b" * 1024)
    fs.write_bytes(c, b"c" * 1024)  # tmpfs now at capacity
    d = os.path.join(fs.mount, "d.bin")
    fs.write_bytes(d, b"d" * 1024)
    # LRU(a) was evicted to make room; d landed on tmpfs
    assert fs.where(d) == "tmpfs"
    assert fs.where(a) is None


# ------------------------------------------------------------ striping (§6)
def test_striped_write_spreads_across_roots(tmp_path):
    """Paper §6 future work: file splitting across same-level devices."""
    cfg = make_config(tmp_path, stripe_chunk_bytes=1 << 10)
    fs = SeaFS(cfg)
    # force placement past tmpfs so the 2-root disk level stripes
    cfg.tiers[0].capacity = 1
    p = os.path.join(fs.mount, "big.bin")
    data = bytes(range(256)) * 24  # 6 KiB -> 6 parts over 2 roots
    fs.write_bytes(p, data)
    assert fs.read_bytes(p) == data
    import glob as _glob

    d0 = _glob.glob(str(tmp_path / "d0" / "*.sea_stripe.0*"))
    d1 = _glob.glob(str(tmp_path / "d1" / "*.sea_stripe.0*"))
    assert len(d0) == 3 and len(d1) == 3  # round-robin across both disks


def test_striped_roundtrip_property(tmp_path):
    cfg = make_config(tmp_path, stripe_chunk_bytes=512)
    cfg.tiers[0].capacity = 1
    fs = SeaFS(cfg)
    for size in (0, 1, 511, 512, 513, 4096, 5000):
        p = os.path.join(fs.mount, f"s{size}.bin")
        data = os.urandom(size)
        fs.write_bytes(p, data)
        assert fs.read_bytes(p) == data, size


def test_striped_write_crash_leaves_no_partial_part(tmp_path, monkeypatch):
    """A failure mid-stripe must never leave a short part under a
    resolvable stripe name: parts commit via tmp + os.replace, so the
    torn write exists only as a .sea_tmp staging orphan (seacheck
    atomic-commit invariant)."""
    import glob as _glob

    import repro.core.seafs as seafs_mod

    cfg = make_config(tmp_path, stripe_chunk_bytes=512)
    cfg.tiers[0].capacity = 1
    fs = SeaFS(cfg)
    real_replace = os.replace
    calls = {"n": 0}

    def exploding_replace(src, dst, *a, **kw):
        if ".sea_stripe." in str(dst):
            calls["n"] += 1
            if calls["n"] == 2:  # part 0 commits; part 1 "crashes"
                raise OSError(5, "injected crash")
        return real_replace(src, dst, *a, **kw)

    monkeypatch.setattr(seafs_mod.os, "replace", exploding_replace)
    p = os.path.join(fs.mount, "crash.bin")
    with pytest.raises(OSError):
        fs.write_bytes(p, os.urandom(2048))
    visible = [
        f
        for f in _glob.glob(str(tmp_path / "*" / "*.sea_stripe.*"))
        if ".sea_tmp" not in f
    ]
    assert calls["n"] == 2
    # every part that became resolvable is a COMPLETE chunk; the torn
    # one never appeared under its stripe name
    assert visible and all(os.path.getsize(f) == 512 for f in visible)


def test_striping_disabled_is_whole_file(tmp_path):
    fs = SeaFS(make_config(tmp_path))  # stripe_chunk_bytes=0
    p = os.path.join(fs.mount, "w.bin")
    fs.write_bytes(p, b"x" * 4096)
    assert fs.where(p) == "tmpfs"
    import glob as _glob

    assert not _glob.glob(str(tmp_path / "*" / "*.sea_stripe.0*"))
