"""Tests for the performance model (Eqs. 1–11) and the cluster simulator,
validated against the paper's reported results (§4)."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dependency: property tests skip cleanly
    from _hypothesis_stub import given, settings, st

from repro.core.model import (
    ClusterSpec,
    GiB,
    MiB,
    Workload,
    lustre_bounds,
    lustre_cached_makespan,
    lustre_makespan,
    lustre_read_bw,
    lustre_write_bw,
    sea_bounds,
    sea_cached_makespan,
    sea_flush_all_makespan,
    sea_makespan,
    sea_tier_volumes,
)
from repro.core.simulator import Simulator

PAPER = ClusterSpec()          # 5 nodes, 6 procs, 6 disks — paper defaults
W10 = Workload(B=1000, F=617 * MiB, n=10)
W5 = Workload(B=1000, F=617 * MiB, n=5)


# ------------------------------------------------------------------- model
def test_lustre_bw_eq2_eq3():
    # L = min(cN, sN, d_* min(d, cp)); with paper defaults cp=30 < d=44
    assert lustre_read_bw(PAPER) == PAPER.d_r * 30
    assert lustre_write_bw(PAPER) == PAPER.d_w * 30
    # many processes: OST count binds
    cl = PAPER.with_(p=64)
    assert lustre_write_bw(cl) == PAPER.d_w * 44
    # tiny cluster: network binds
    cl = PAPER.with_(N=10 * MiB, c=1, p=64)
    assert lustre_write_bw(cl) == 10 * MiB


def test_workload_volumes():
    assert W10.D_I == 1000 * 617 * MiB
    assert W10.D_m == 9 * 1000 * 617 * MiB
    assert W10.D_f == 1000 * 617 * MiB


def test_sea_tier_volumes_conservation():
    """Spill volumes partition the written/read bytes exactly (Eqs. 8–10)."""
    v = sea_tier_volumes(W10, PAPER)
    assert v["D_tw"] + v["D_gw"] + v["D_Lw"] == pytest.approx(W10.D_m + W10.D_f)
    assert v["D_tr"] + v["D_gr"] + v["D_Lr"] == pytest.approx(W10.D_m)
    assert all(val >= 0 for val in v.values())


def test_bounds_ordering():
    lo_l, hi_l = lustre_bounds(W10, PAPER)
    lo_s, hi_s = sea_bounds(W10, PAPER)
    assert lo_l <= hi_l and lo_s <= hi_s
    # Sea and Lustre share an identical lower bound (paper §3.4)
    assert lo_l == pytest.approx(lo_s)
    # In the data-intensive regime Sea's upper bound beats Lustre's
    assert hi_s < hi_l


def test_flush_all_costs_more():
    assert sea_flush_all_makespan(W10, PAPER) > sea_makespan(W10, PAPER)


@settings(max_examples=50, deadline=None)
@given(
    c=st.integers(1, 16),
    p=st.integers(1, 64),
    g=st.integers(1, 8),
    n=st.integers(1, 20),
)
def test_model_positive_and_monotone_in_data(c, p, g, n):
    cl = ClusterSpec(c=c, p=p, g=g)
    w = Workload(B=100, F=64 * MiB, n=n)
    w2 = Workload(B=200, F=64 * MiB, n=n)
    for fn in (lustre_makespan, lustre_cached_makespan, sea_makespan,
               sea_cached_makespan):
        assert fn(w, cl) > 0
        assert fn(w2, cl) >= fn(w, cl)  # more data never finishes earlier


@settings(max_examples=30, deadline=None)
@given(c=st.integers(1, 8), p=st.integers(1, 16), n=st.integers(2, 12))
def test_cached_bound_below_uncached(c, p, n):
    cl = ClusterSpec(c=c, p=p)
    w = Workload(B=200, F=256 * MiB, n=n)
    assert lustre_cached_makespan(w, cl) <= lustre_makespan(w, cl) * 1.0001
    assert sea_cached_makespan(w, cl) <= sea_makespan(w, cl) * 1.0001


# --------------------------------------------------------------- simulator
@pytest.fixture(scope="module")
def base_sims():
    rl = Simulator(PAPER, W10, "lustre").run()
    rs = Simulator(PAPER, W10, "sea").run()
    return rl, rs


def test_sim_base_speedup_matches_paper(base_sims):
    """Paper §4.1: 2.4x speedup at the fixed condition (5 nodes, 6 procs,
    6 disks, 10 iterations)."""
    rl, rs = base_sims
    speedup = rl.makespan / rs.makespan
    assert 2.0 <= speedup <= 2.9, speedup


def test_sim_within_model_bounds(base_sims):
    """The paper's validity criterion: measurements fall within the model's
    [cached, uncached] bounds at the base condition."""
    rl, rs = base_sims
    lo, hi = lustre_bounds(W10, PAPER)
    assert lo * 0.95 <= rl.makespan <= hi * 1.05
    lo, hi = sea_bounds(W10, PAPER)
    assert lo * 0.95 <= rs.makespan <= hi * 1.10


def test_sim_single_node_parity():
    """Paper §4.1: 'Sea at a single node likely performs equivalently to
    Lustre'."""
    cl = PAPER.with_(c=1)
    rl = Simulator(cl, W10, "lustre").run()
    rs = Simulator(cl, W10, "sea").run()
    assert 0.85 <= rl.makespan / rs.makespan <= 1.2


def test_sim_single_iteration_no_speedup():
    """Paper §4.1: 'Sea at a single iteration can at best perform similarly
    or slightly worse than Lustre' (no intermediate data)."""
    w = Workload(B=1000, F=617 * MiB, n=1)
    rl = Simulator(PAPER, w, "lustre").run()
    rs = Simulator(PAPER, w, "sea").run()
    assert rl.makespan / rs.makespan <= 1.35


def test_sim_single_disk_slowdown():
    """Paper §4.1 (Fig. 2b): Sea underperforms Lustre with one local disk."""
    cl = PAPER.with_(g=1)
    rl = Simulator(cl, W5, "lustre").run()
    rs = Simulator(cl, W5, "sea").run()
    assert rl.makespan / rs.makespan < 1.0


def test_sim_more_disks_more_speedup():
    """Paper §4.1 (Fig. 2b): ~2x speedup by 6 disks, monotone trend."""
    speedups = []
    for g in (1, 4, 6):
        cl = PAPER.with_(g=g)
        rl = Simulator(cl, W5, "lustre").run()
        rs = Simulator(cl, W5, "sea").run()
        speedups.append(rl.makespan / rs.makespan)
    assert speedups == sorted(speedups)
    assert speedups[-1] >= 1.9


def test_sim_process_scaling_peak_speedup():
    """Paper §4.1 (Fig. 2d): largest speedup ~3x in the 16–32 process
    range."""
    best = 0.0
    for p in (16, 32):
        cl = PAPER.with_(p=p)
        rl = Simulator(cl, W5, "lustre").run()
        rs = Simulator(cl, W5, "sea").run()
        best = max(best, rl.makespan / rs.makespan)
    assert best >= 2.5


def test_sim_exp4_lustre_exceeds_model_bounds():
    """Paper §4.2: at 30+ processes Lustre 'declined above model bounds' —
    the simulator reproduces the bound violation."""
    cl = PAPER.with_(p=32)
    rl = Simulator(cl, W5, "lustre").run()
    _lo, hi = lustre_bounds(W5, cl)
    assert rl.makespan > hi


def test_sim_fig3_flush_all_ratios():
    """Paper §4.3 (Fig. 3): flush-all 3.5x slower than in-memory and 1.3x
    slower than Lustre (5 nodes, 64 procs, 6 disks, 5 iters)."""
    cl = PAPER.with_(p=64)
    rl = Simulator(cl, W5, "lustre").run()
    rs = Simulator(cl, W5, "sea").run()
    rf = Simulator(cl, W5, "sea-flushall").run()
    assert 2.8 <= rf.makespan / rs.makespan <= 4.2
    assert 1.1 <= rf.makespan / rl.makespan <= 1.5


def test_sim_conservation_of_bytes():
    rs = Simulator(PAPER, W5, "sea").run()
    app_bytes = sum(
        v for k, v in rs.bytes_by_tier.items() if k != "flush"
    )
    assert app_bytes == pytest.approx(W5.D_m + W5.D_f, rel=1e-6)
    # in-memory mode flushes exactly the final outputs
    assert rs.bytes_by_tier["flush"] == pytest.approx(W5.D_f, rel=1e-6)


def test_sim_compute_masks_flush_overhead():
    """Paper §5.5: flush-all overheads are masked when compute dominates."""
    cl = PAPER.with_(p=4)
    w = Workload(B=100, F=617 * MiB, n=5)
    slow = dict(compute_s_per_iter=30.0)
    rs = Simulator(cl, w, "sea", **slow).run()
    rf = Simulator(cl, w, "sea-flushall", **slow).run()
    assert rf.makespan / rs.makespan < 1.3  # overhead mostly hidden


def test_sim_beyond_paper_eviction_helps_when_tmpfs_small():
    """Beyond-paper: evicting consumed intermediates lets tmpfs absorb more
    writes when capacity is scarce."""
    cl = PAPER.with_(t=8 * GiB)
    w = Workload(B=200, F=617 * MiB, n=10)
    r0 = Simulator(cl, w, "sea").run()
    r1 = Simulator(cl, w, "sea", evict_intermediates=True).run()
    assert r1.makespan <= r0.makespan * 1.001
