"""seacheck layer 1 (AST invariant linter) — rule behaviour on the
known-bad fixtures, suppression + baseline mechanics, and the
acceptance-criteria demos: deliberately introducing each violation class
turns the CI gate (exit code) red, while the real tree lints clean."""

import ast
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from seacheck import cli  # noqa: E402
from seacheck.astutil import annotate_parents  # noqa: E402
from seacheck.rules import (  # noqa: E402
    atomic_commit,
    invalidation,
    lock_discipline,
    reservation,
    telemetry_drift,
)
from seacheck.violations import (  # noqa: E402
    SourceFile,
    Violation,
    filter_baselined,
)

FIXTURES = os.path.join(REPO, "tools", "seacheck", "fixtures")


def run_rule(rule, fixture, fake_path):
    """Lint a fixture as if it lived at ``fake_path`` (the rules are
    path-scoped to the data plane)."""
    with open(os.path.join(FIXTURES, fixture)) as f:
        src = f.read()
    tree = ast.parse(src)
    annotate_parents(tree)
    return rule.check(SourceFile(path=fake_path, source=src), tree)


def symbols(violations):
    return {v.symbol for v in violations}


# ---------------------------------------------------------------- rule (a)
def test_reservation_pairing_rule():
    out = run_rule(
        reservation, "bad_reservation.py", "src/repro/core/fixture.py"
    )
    assert symbols(out) == {"leaked_forever", "leaks_on_exception"}
    assert all(v.rule == "reservation-pairing" for v in out)
    # paired_correctly / escapes_to_caller comply; suppressed_leak is
    # silenced by its inline `# seacheck: ignore[...]`


# ---------------------------------------------------------------- rule (b)
def test_atomic_commit_rule():
    out = run_rule(
        atomic_commit, "bad_atomic_commit.py", "src/repro/core/fixture.py"
    )
    assert symbols(out) == {
        "bare_write_to_tier_path",
        "shutil_copy_bypasses_engine",
        "np_save_in_place",
    }
    # tmp+os.replace, the mount API, and reads are all sanctioned


def test_atomic_commit_rule_is_scoped_to_core():
    out = run_rule(
        atomic_commit, "bad_atomic_commit.py", "src/repro/train/feed.py"
    )
    assert out == []


def test_atomic_commit_tmp_destination_is_sanctioned():
    src = "import shutil\ndef stage(src, dst):\n    shutil.copyfile(src, dst + '.sea_tmp')\n"
    tree = ast.parse(src)
    annotate_parents(tree)
    sf = SourceFile(path="src/repro/core/x.py", source=src)
    assert atomic_commit.check(sf, tree) == []


# ---------------------------------------------------------------- rule (c)
def test_invalidation_completeness_rule():
    out = run_rule(
        invalidation, "bad_invalidation.py", "src/repro/core/seafs.py"
    )
    assert symbols(out) == {
        "BadFS.evict_without_invalidation",
        "BadFS.evict_without_fed",
    }
    msgs = {v.symbol: v.message for v in out}
    assert "resolver" in msgs["BadFS.evict_without_invalidation"]


# ---------------------------------------------------------------- rule (d)
def test_telemetry_drift_rule():
    out = run_rule(
        telemetry_drift, "bad_telemetry.py", "src/repro/core/telemetry.py"
    )
    blob = " ".join(v.message for v in out)
    assert "ghost_counter" in blob  # registered but not a field
    assert "unregistered_field" in blob  # field but not registered
    assert "sneaky_counter" in blob  # increments an unregistered name
    assert any("snapshot" in v.message or "snapshot" in v.symbol for v in out)


def test_telemetry_drift_flags_ad_hoc_increments():
    out = run_rule(
        telemetry_drift, "bad_ad_hoc_counter.py", "src/repro/core/engine.py"
    )
    assert len(out) == 1 and "flushed_bytes" in out[0].message


def test_real_counters_registry_matches_fields():
    """The live COUNTERS table and the Telemetry dataclass agree (the
    lint rule checks this lexically; this checks it at runtime)."""
    import dataclasses

    from repro.core.telemetry import COUNTERS, Telemetry

    scalar = {
        f.name
        for f in dataclasses.fields(Telemetry)
        if not f.name.startswith("_") and f.type in ("int", "float", int, float)
    }
    assert set(COUNTERS) == scalar
    snap = Telemetry().snapshot()
    for name in COUNTERS:
        assert name in snap


# ---------------------------------------------------------------- rule (e)
def test_lock_discipline_rule():
    out = run_rule(
        lock_discipline, "bad_lock_discipline.py", "src/repro/core/seafs.py"
    )
    assert symbols(out) == {
        "BadFS.unlocked_mutation",
        "BadFS.unlocked_method_mutation",
    }
    # locked_mutation is under `with self._lock`; _locked_helper carries
    # `# seacheck: holds-lock`; reads are never checked


# ------------------------------------------------------- baseline mechanics
def test_baseline_filtering_and_staleness():
    v1 = Violation("atomic-commit", "src/a.py", 10, "f", "m")
    v2 = Violation("atomic-commit", "src/b.py", 20, "g", "m")
    baseline = {
        ("atomic-commit", "src/a.py", "f"): "justified",
        ("atomic-commit", "src/gone.py", "h"): "stale entry",
    }
    fresh, stale = filter_baselined([v1, v2], baseline)
    assert fresh == [v2]
    assert stale == [("atomic-commit", "src/gone.py", "h")]


def test_baseline_survives_line_drift():
    # baseline keys are (rule, path, symbol) — moving the code around a
    # file must not resurrect an accepted violation
    v = Violation("atomic-commit", "src/a.py", 999, "f", "m")
    fresh, _ = filter_baselined(
        [v], {("atomic-commit", "src/a.py", "f"): "ok"}
    )
    assert fresh == []


# ------------------------------------------------------------ the CI gate
def test_real_tree_lints_clean():
    rc = cli.main(["lint", "--root", REPO, os.path.join(REPO, "src", "repro")])
    assert rc == 0


def _gate(tmp_path, rel, source):
    """Exit code of the lint gate over a tree containing one bad file
    planted at a data-plane path."""
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    return cli.main(
        ["lint", "--root", str(tmp_path), "--no-baseline", str(tmp_path)]
    )


def test_gate_reddens_on_unreleased_reservation(tmp_path, capsys):
    rc = _gate(
        tmp_path,
        "src/repro/core/bad.py",
        "def f(ledger, root, n):\n"
        "    res = ledger.try_reserve(root, n, capacity=10, required=1)\n"
        "    do_write(root)\n"
        "    return True\n"
        "def do_write(root): ...\n",
    )
    assert rc == 1
    assert "reservation-pairing" in capsys.readouterr().out


def test_gate_reddens_on_bare_write(tmp_path, capsys):
    rc = _gate(
        tmp_path,
        "src/repro/core/bad.py",
        "def f(real, data):\n"
        "    with open(real, 'w') as fh:\n"
        "        fh.write(data)\n",
    )
    assert rc == 1
    assert "atomic-commit" in capsys.readouterr().out


def test_gate_green_on_clean_file(tmp_path):
    rc = _gate(
        tmp_path,
        "src/repro/core/fine.py",
        "import os\n"
        "def f(real, data):\n"
        "    tmp = real + '.sea_tmp'\n"
        "    with open(tmp, 'wb') as fh:\n"
        "        fh.write(data)\n"
        "    os.replace(tmp, real)\n",
    )
    assert rc == 0


def test_gate_reddens_on_syntax_error(tmp_path, capsys):
    rc = _gate(tmp_path, "src/repro/core/broken.py", "def f(:\n")
    assert rc == 1
    assert "parse-error" in capsys.readouterr().out


def test_cli_entrypoint_runs_from_scratch():
    """The CI invocation exactly: stdlib-only module run, clean tree."""
    proc = subprocess.run(
        [sys.executable, "-m", "seacheck", "lint", "src/repro"],
        cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src" + os.pathsep + "tools"},
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_rules_subcommand_lists_all_five(capsys):
    assert cli.main(["rules"]) == 0
    out = capsys.readouterr().out
    for rule_id in (
        "reservation-pairing",
        "atomic-commit",
        "invalidation-completeness",
        "telemetry-drift",
        "lock-discipline",
    ):
        assert rule_id in out


def test_update_baseline_roundtrip(tmp_path, capsys):
    p = tmp_path / "src" / "repro" / "core" / "bad.py"
    p.parent.mkdir(parents=True)
    p.write_text("def f(r, d):\n    with open(r, 'w') as fh:\n        fh.write(d)\n")
    bl = tmp_path / "baseline.json"
    rc = cli.main(
        [
            "lint",
            "--root",
            str(tmp_path),
            "--baseline",
            str(bl),
            "--update-baseline",
            str(tmp_path),
        ]
    )
    assert rc == 0
    entries = json.loads(bl.read_text())
    assert len(entries) == 1 and entries[0]["rule"] == "atomic-commit"
    # with the finding accepted, the gate is green
    rc = cli.main(
        ["lint", "--root", str(tmp_path), "--baseline", str(bl), str(tmp_path)]
    )
    assert rc == 0
